"""Quickstart: the PGAS programming model in 60 lines.

Builds a 4-rank global address space on a CPU mesh, then exercises the
paper's primitives: symmetric heap, one-sided ring PUT, an Active Message
invoking a custom compute handler (the DLA pattern), and an
ART-overlapped distributed matmul.

Run:  PYTHONPATH=src python examples/quickstart.py
(see examples/README.md for the full script table)
"""

import argparse
import os

argparse.ArgumentParser(
    description="PGAS quickstart: symmetric heap, one-sided ring PUT, an "
                "Active Message invoking a custom compute handler, and an "
                "ART-overlapped distributed matmul on a 4-device CPU mesh. "
                "Invocation: PYTHONPATH=src python examples/quickstart.py "
                "(sets XLA_FLAGS=--xla_force_host_platform_device_count=4 "
                "itself; see examples/README.md).",
).parse_args()

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import am, art, pgas

mesh = jax.make_mesh((4,), ("pgas",),
                     axis_types=(jax.sharding.AxisType.Auto,))

# --- 1. a symmetric heap: every rank owns a 64-word partition -------------
heap = pgas.SymmetricHeap(64)
heap.alloc("inbox", 16)
heap.alloc("result", 16)
gas = pgas.GlobalAddressSpace(mesh, "pgas", heap)
g = gas.zeros_global()

# --- 2. one-sided put: rank r writes its vector into rank r+1's inbox ----
def ring_put(h):
    my = jax.lax.axis_index("pgas").astype(jnp.float32)
    payload = jnp.full((16,), my + 1.0)
    return pgas.put(h, payload, heap.addr("inbox"), axis="pgas",
                    perm=[(i, (i + 1) % 4) for i in range(4)])

g = gas.run(ring_put)(g)
print("after ring put, rank1 inbox head:",
      np.asarray(g).reshape(4, 64)[1, :4])     # rank 0 wrote 1.0s

# --- 3. an Active Message with a custom handler (the DLA pattern) --------
reg = am.HandlerRegistry()

def scale_handler(h, args, payload):
    """opcode SCALE: multiply the inbox by args[1] and store to `result`."""
    inbox = jax.lax.dynamic_slice(h, (args[0],), (16,))
    h = jax.lax.dynamic_update_slice(h, inbox * args[1].astype(h.dtype),
                                     (args[2],))
    return h, jnp.int32(0), am.make_args(), jnp.zeros((1,), h.dtype)

SCALE = reg.register_request("SCALE", scale_handler)

def send_compute(h):
    args = am.make_args(heap.addr("inbox"), 10, heap.addr("result"))
    return am.am_request_short(reg, h, SCALE, args, axis="pgas",
                               perm=[(0, 2)])

g = gas.run(send_compute)(g)
print("rank2 result after AM compute:",
      np.asarray(g).reshape(4, 64)[2, heap.addr("result"):
                                    heap.addr("result") + 4])

# --- 4. ART: overlapped distributed matmul (the paper's case study) ------
m = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
n = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
ms = jax.device_put(m, jax.sharding.NamedSharding(mesh, P(None, "pgas")))
ns = jax.device_put(n, jax.sharding.NamedSharding(mesh, P("pgas", None)))
f = jax.jit(jax.shard_map(
    functools.partial(art.art_matmul_reducescatter, axis="pgas", n_chunks=4),
    mesh=mesh, in_specs=(P(None, "pgas"), P("pgas", None)),
    out_specs=P(None, "pgas")))
got = f(ms, ns)
err = np.abs(np.asarray(got) - np.asarray(m) @ np.asarray(n)).max()
print(f"ART matmul max |err| vs local math: {err:.2e}")
assert err < 1e-4
print("quickstart OK")
