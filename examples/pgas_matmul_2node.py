"""The paper's Sec. V case study, end to end: 2-node parallel matmul with
ART partial-sum exchange vs the bulk-synchronous baseline, plus the
kernel-split convolution — functional on a real 2-device mesh, with the
modeled Fig. 7 speedups printed alongside.

Run:  PYTHONPATH=src python examples/pgas_matmul_2node.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import art
from repro.analysis.hlo_cost import summarize

mesh = jax.make_mesh((2,), ("node",),
                     axis_types=(jax.sharding.AxisType.Auto,))
key = jax.random.PRNGKey(0)

for size in (256, 512, 1024):
    m = jax.random.normal(key, (size, size), jnp.float32)
    n = jax.random.normal(jax.random.PRNGKey(1), (size, size), jnp.float32)
    ms = jax.device_put(m, jax.sharding.NamedSharding(mesh, P(None, "node")))
    ns = jax.device_put(n, jax.sharding.NamedSharding(mesh, P("node", None)))

    f_art = jax.jit(jax.shard_map(
        functools.partial(art.art_matmul_reducescatter, axis="node",
                          n_chunks=8),
        mesh=mesh, in_specs=(P(None, "node"), P("node", None)),
        out_specs=P(None, "node")))
    f_bulk = jax.jit(jax.shard_map(
        functools.partial(art.bulk_matmul_reducescatter, axis="node"),
        mesh=mesh, in_specs=(P(None, "node"), P("node", None)),
        out_specs=P(None, "node")))

    want = np.asarray(m) @ np.asarray(n)
    got_art = np.asarray(f_art(ms, ns))
    got_bulk = np.asarray(f_bulk(ms, ns))
    np.testing.assert_allclose(got_art, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got_bulk, want, rtol=2e-4, atol=2e-4)

    # structural check: ART splits the one bulk transfer into 8 chunked
    # permutes — visible in the lowered modules
    s_art = summarize(f_art.lower(ms, ns).compile().as_text())
    s_bulk = summarize(f_bulk.lower(ms, ns).compile().as_text())
    n_art = s_art.coll_count.get("collective-permute", 0)
    n_bulk = sum(s_bulk.coll_count.values())
    print(f"matmul {size}: allclose OK | collective ops: "
          f"bulk={n_bulk}, ART={n_art} (chunked) | "
          f"bytes bulk={s_bulk.total_coll_bytes:.2e} "
          f"ART={s_art.total_coll_bytes:.2e}")

# Fig. 7 modeled speedups (constants documented in benchmarks/casestudy.py)
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.casestudy import modeled_speedups  # noqa: E402

mm, cv = modeled_speedups()
print("modeled 2-node speedups (paper Fig. 7: matmul avg 1.94x, conv 1.98x):")
for k, v in {**mm, **cv}.items():
    print(f"  {k}: {v:.3f}x")
print("pgas_matmul_2node OK")
