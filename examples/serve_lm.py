"""Serve a small model with batched requests: continuous batching over the
sharded decode step (prefill-then-stream, the paper's request/ART pattern).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import param_pspecs, to_shardings
from repro.launch.mesh import make_host_mesh
from repro.models.model import init_params
from repro.runtime.server import Server, ServerConfig

cfg = get_config("smollm-360m").reduced()
mesh = make_host_mesh(2, 2)

params_shape = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.random.PRNGKey(0))
psh = to_shardings(mesh, param_pspecs(cfg, mesh, params_shape))
params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
    jax.random.PRNGKey(0))

srv = Server(cfg, params, mesh,
             srv=ServerConfig(max_batch=4, max_seq=128, max_new_tokens=16))

rng = np.random.default_rng(0)
for i in range(10):
    srv.submit(rng.integers(0, cfg.vocab_size, size=8))

steps = srv.run()
stats = srv.stats()
print(f"serve_lm: {stats['requests']} requests / {stats['tokens']} tokens "
      f"in {steps} decode steps")
print(f"  throughput {stats['throughput_tok_s']:.1f} tok/s  "
      f"mean latency {stats['mean_latency_s']*1e3:.0f} ms  "
      f"ttft {stats['mean_ttft_s']*1e3:.0f} ms")
assert stats["requests"] == 10
assert all(len(r.out_tokens) == 16 for r in srv.done)
print("serve_lm OK")
