"""End-to-end driver: train a ~100M-param SmolLM-family model for a few
hundred steps on a DP×TP CPU mesh, with checkpoints, preemption handling
and the full distributed step (FSDP sharding, sequence-chunked CE,
grad-accumulation microbatching).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; give it a few minutes on CPU. --small runs the CI-size
variant used by the integration test.)
"""

import argparse
import os

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=300)
p.add_argument("--small", action="store_true",
               help="CI-sized: reduced width, fewer steps")
p.add_argument("--resume", action="store_true",
               help="resume from /tmp/repro_train_lm instead of fresh")
args = p.parse_args()

CKPT_DIR = "/tmp/repro_train_lm"
if not args.resume:
    import shutil
    shutil.rmtree(CKPT_DIR, ignore_errors=True)

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import dataclasses

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.steps import StepConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import Trainer, TrainerConfig

base = get_config("smollm-360m")
if args.small:
    cfg = base.reduced()
    seq, gb, steps = 64, 8, min(args.steps, 60)
else:
    # ~100M params: smollm-360m at 16 layers / 768 width
    cfg = dataclasses.replace(
        base, n_layers=16, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, head_dim=64, param_dtype="float32",
        compute_dtype="float32", attn_impl="jnp", remat="none",
        attn_q_chunk=256, attn_kv_chunk=256)
    seq, gb, steps = 256, 16, args.steps

mesh = make_host_mesh(2, 2)
scfg = StepConfig(microbatches=2, seq_chunk=min(256, seq), peak_lr=1e-3,
                  warmup_steps=max(steps // 10, 5), total_steps=steps)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq + 1,
                              global_batch=gb, seed=1))
tcfg = TrainerConfig(total_steps=steps, ckpt_dir=CKPT_DIR,
                     ckpt_interval=max(steps // 3, 20), log_interval=10)

trainer = Trainer(cfg, scfg, tcfg, data, mesh=mesh)
trainer.install_signal_handler()
params, opt, step = trainer.train()

if not trainer.history:
    print(f"\ntrain_lm: already at step {step} (use a fresh run or "
          f"--steps > {step} with --resume)")
else:
    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"\ntrain_lm: {step} steps, loss {first:.3f} -> {last:.3f} "
          f"({(first - last) / first * 100:.1f}% reduction)")
    assert last < first, "loss must decrease"
print("train_lm OK")
