"""Fit the netmodel's small-message constants to measured CPU-mesh walls.

The analytic link models (``repro.core.netmodel``) are calibrated from the
paper's Fig. 5 / Table III; the ROADMAP flags that ``conduit.estimate_time``
has never been checked against a *measured* wall-clock.  This tool closes
the loop with the only hardware the container has: the ``measured-cpu-mesh``
rows of ``BENCH_transport.json`` (two payload sizes per op × transport,
written by ``benchmarks/transport_sweep.py``).

Per (op, transport) the two points give an exact linear fit
``wall = a + b·bytes``.  For the ring-family bandwidth-optimal ops the
netmodel's own algebra identifies the fit with link constants:

* ``all_gather``/``reduce_scatter`` over ``ring`` cost
  ``(n−1) · put(S/n)`` — so the intercept is ``(n−1)`` per-message
  latencies (``put_long = a/(n−1)``) and the slope is ``(n−1)/n`` divided
  by the link bandwidth (only ``ring`` rows enter the fit: ``bidir``
  halves the per-direction bytes, a different algebra);
* a two-point fit identifies exactly *one* latency and *one* bandwidth —
  the split of ``put_long`` into the five AM stages is convention (the
  QSFP+ stage *ratios* are reused), and per-packet overhead is not
  observable on a host mesh (set to 0).

The fitted :class:`~repro.core.netmodel.LinkParams` then re-runs
``conduit.auto_select`` so the *fitted* xla→ring crossovers land next to
the modeled ones in ``BENCH_overlap.json``
(``benchmarks/overlap_pipeline.py`` embeds :func:`fit_report`).  CPU-mesh
walls are scheduling, not link, performance — the point is the *method*
(the same fit re-runs per real topology) and the small-message end the
ROADMAP says is the part that needs pinning.

Run standalone: ``python tools/fit_netmodel.py`` (prints the report and,
when ``BENCH_overlap.json`` exists, refreshes its ``netmodel_fit``
section).
"""

from __future__ import annotations

import json
import os
import statistics
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: ops whose ring cost is (n−1)·put(S/n) — the fit's identifiable surface
FIT_OPS = ("all_gather", "reduce_scatter")
#: crossover scan sizes (bytes)
SCAN_SIZES = tuple(1 << p for p in range(8, 25))


def _rows(path, source="measured-cpu-mesh"):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        payload = json.load(f)
    return [r for r in payload.get("rows", []) if r.get("source") == source]


def _linfit(points):
    """Exact/least-squares ``(intercept, slope)`` of ``wall_s = a + b·bytes``."""
    n = len(points)
    mx = sum(p[0] for p in points) / n
    my = sum(p[1] for p in points) / n
    var = sum((p[0] - mx) ** 2 for p in points)
    if var == 0:
        return my, 0.0
    slope = sum((p[0] - mx) * (p[1] - my) for p in points) / var
    return my - slope * mx, slope


def fit_link(transport_rows):
    """A fitted ``LinkParams`` (+ per-op fit table) from measured rows.

    Returns ``None`` when the artifact has no usable measured rows (CI's
    ``--model-only`` sweeps).
    """
    from repro.core import netmodel as nm

    fits = {}
    for op in FIT_OPS:
        # ring rows only: the (n−1)·put(S/n) algebra below is the
        # unidirectional schedule's — bidir halves the per-direction bytes
        # (and serializes its two permutes per hop on a host mesh), so its
        # rows would bias put_long/bandwidth by ~2×
        t = "ring"
        pts = sorted(
            (r["bytes"], r["wall_us"] * 1e-6) for r in transport_rows
            if r["op"] == op and r["transport"] == t)
        ns = {r["axis_size"] for r in transport_rows
              if r["op"] == op and r["transport"] == t}
        if len(pts) < 2 or len(ns) != 1:
            continue
        n = ns.pop()
        a, b = _linfit(pts)
        if a <= 0 or b <= 0:
            continue                      # noise swamped the fit: skip
        hops = n - 1
        fits[f"{op}/{t}"] = {
            "axis_size": n,
            "intercept_us": 1e6 * a,
            "slope_us_per_mb": 1e6 * b * (1 << 20),
            "put_long_us": 1e6 * a / hops,
            "bandwidth_gb_s": ((n - 1) / n) / b / 1e9,
        }
    if not fits:
        return None, {}

    put_long = statistics.median(f["put_long_us"] for f in fits.values()) / 1e6
    bw = statistics.median(f["bandwidth_gb_s"] for f in fits.values()) * 1e9
    # stage split: reuse the QSFP+ ratios — only the put_long *sum* and the
    # line rate are identifiable from a two-point fit (module docstring)
    ref = nm.FSHMEM_QSFP.latency
    scale = put_long / ref.put_long
    link = nm.LinkParams(
        name="cpu-mesh-fit",
        line_rate=bw,
        line_efficiency=1.0,
        packet_overhead_bytes={4096: 0.0},
        latency=nm.LatencyParams(
            t_host_cmd=ref.t_host_cmd * scale,
            t_dma=ref.t_dma * scale,
            t_header=ref.t_header * scale,
            t_handler=ref.t_handler * scale,
            t_sched=ref.t_sched * scale,
        ),
    )
    return link, fits


def _crossovers(link, axis_size=4):
    """Smallest scanned payload where ``auto`` leaves ``xla``, per op."""
    from repro.core import conduit

    out = {}
    for op in ("all_reduce", "all_to_all", "all_gather"):
        flip = None
        for size in SCAN_SIZES:
            choice, _ = conduit.auto_select(
                op, size_bytes=size, axis_size=axis_size, link=link)
            if choice != "xla":
                flip = size
                break
        out[op] = flip
    return out


def fit_hop_overhead(overlap_rows) -> dict:
    """Per-hop launch overhead fitted from measured fused-vs-streamed walls.

    The fused in-kernel schedule and the XLA-level streamed schedule run
    the identical pipeline except for the per-hop launch/repack boundary
    (``netmodel.hop_launch_overhead``): the streamed wall pays it ``n−1``
    times, the fused wall once.  So each measured (op, axis_size) pair
    identifies it as ``(wall_streamed − wall_fused) / (n − 1)`` (clamped
    at 0 — CPU-mesh walls are noisy scheduling time, not link time; the
    *method* is what re-runs per real topology).  Rows come from the
    ``fused_tp`` measured suite of ``BENCH_overlap.json``
    (``benchmarks/overlap_pipeline.py``).
    """
    rows = [r for r in overlap_rows
            if r.get("suite") == "fused_tp"
            and r.get("source") == "measured-cpu-mesh"]
    walls = {}
    for r in rows:
        walls.setdefault((r["op"], r["axis_size"]), {})[r["schedule"]] = (
            r["wall_us"])
    samples = []
    for (op, n), w in sorted(walls.items()):
        if "streamed" in w and "fused" in w and n > 1:
            samples.append(
                {"op": op, "axis_size": n,
                 "hop_overhead_us": max(
                     0.0, (w["streamed"] - w["fused"]) / (n - 1))})
    report = {"available": bool(samples), "samples": samples}
    if samples:
        report["fitted_hop_overhead_us"] = statistics.median(
            s["hop_overhead_us"] for s in samples)
        from repro.core import netmodel as nm

        report["modeled_hop_overhead_us"] = {
            "qsfp": 1e6 * nm.hop_launch_overhead(nm.FSHMEM_QSFP),
            "ici": 1e6 * nm.hop_launch_overhead(nm.TPU_ICI),
        }
    else:
        report["note"] = ("no measured fused_tp rows (model-only sweep) — "
                          "run benchmarks/overlap_pipeline.py without "
                          "--model-only first")
    return report


def fit_report(transport_path, moe_path) -> dict:
    """The ``netmodel_fit`` section ``BENCH_overlap.json`` embeds."""
    from repro.core import netmodel as nm

    transport_rows = _rows(transport_path)
    link, fits = fit_link(transport_rows)
    report = {
        "available": link is not None,
        "n_measured_rows": len(transport_rows),
        "fits": fits,
        "modeled_crossovers_bytes": {
            "qsfp_n4": _crossovers(nm.FSHMEM_QSFP),
            "ici_n4": _crossovers(nm.TPU_ICI),
        },
    }
    if link is None:
        report["note"] = ("no measured-cpu-mesh rows in the transport "
                          "artifact (model-only sweep) — run "
                          "benchmarks/transport_sweep.py without "
                          "--model-only first")
        return report
    report["fitted_link"] = {
        "line_rate_gb_s": link.line_rate / 1e9,
        "put_long_us": 1e6 * link.latency.put_long,
    }
    report["fitted_crossovers_bytes"] = {"cpu_mesh_n4": _crossovers(link)}
    # the MoE layer walls are a single size — recorded as ratios, not fit
    moe_rows = _rows(moe_path)
    dense = [r["wall_us"] for r in moe_rows if r.get("op") == "moe_layer"
             and r["transport"] == "dense-gspmd"]
    if dense:
        report["moe_wall_ratio_vs_dense"] = {
            r["transport"]: r["wall_us"] / dense[0]
            for r in moe_rows if r.get("op") == "moe_layer"}
    return report


def main() -> int:
    transport = os.path.join(REPO_ROOT, "BENCH_transport.json")
    moe = os.path.join(REPO_ROOT, "BENCH_moe.json")
    report = fit_report(transport, moe)
    print(json.dumps(report, indent=1))
    overlap = os.path.join(REPO_ROOT, "BENCH_overlap.json")
    if os.path.exists(overlap):
        with open(overlap) as f:
            payload = json.load(f)
        report["hop_overhead"] = fit_hop_overhead(payload.get("rows", []))
        payload["netmodel_fit"] = report
        with open(overlap, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"refreshed netmodel_fit in {overlap}")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    sys.exit(main())
