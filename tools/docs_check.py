"""Docs hygiene checker: markdown links + docstring coverage.

Two checks, both exit-code gated (CI's docs job runs this file):

1. **Links** — every relative markdown link in ``docs/``, ``DESIGN.md``,
   ``ROADMAP.md`` and ``examples/README.md`` must resolve to an existing
   file, and every ``#anchor`` must match a heading slug in its target
   (GitHub slug rules: lowercase, punctuation dropped, spaces → dashes).
   External ``http(s)`` links are not fetched.

2. **Docstrings** — every public module / class / function / method in
   ``src/repro/core`` and ``src/repro/dist`` must carry a docstring (the
   AST mirror of ruff's D100–D103, so the gate also runs where ruff is
   not installed; CI additionally runs the real ruff D-subset).

3. **API symbols** — every name exported via ``__all__`` from
   ``repro.dist`` and ``repro.runtime`` must appear in ``docs/api.md``.
   The ``__all__`` lists are read with ``ast`` (no import — the CI docs
   job has no jax), so adding a public symbol without documenting it
   fails the docs job, not just review.

4. **Serving matrix** — the arch × serving-feature table in
   ``docs/serving.md`` must mirror the capability table
   (``repro.configs.base.chunk_carry_spec`` / ``serving_features``) in
   both directions: every registry arch has exactly one row with the
   right carry kind and feature marks, and every row names a registry
   arch.  This check imports ``repro.configs``, which transitively
   needs jax; in a no-jax environment (the CI docs job) it is skipped
   with a notice — tier-1 re-runs it with jax via
   ``tests/test_docs.py``, so drift still fails CI.

Run:  python tools/docs_check.py
"""

from __future__ import annotations

import ast
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_FILES = ["DESIGN.md", "ROADMAP.md", "examples/README.md"]
DOCSTRING_ROOTS = ["src/repro/core", "src/repro/dist"]
API_EXPORT_MODULES = ["src/repro/dist/__init__.py",
                      "src/repro/runtime/__init__.py",
                      "src/repro/kernels/cc_matmul/__init__.py"]
API_DOC = "docs/api.md"

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    h = heading.strip().lower()
    h = re.sub(r"[^\w\s-]", "", h, flags=re.UNICODE)
    return re.sub(r"\s", "-", h)


def _anchors(md_path: str) -> set:
    with open(md_path, encoding="utf-8") as f:
        text = f.read()
    return {_slug(m.group(1)) for m in _HEADING_RE.finditer(text)}


def check_links() -> list:
    files = list(LINK_FILES)
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        files += [os.path.join("docs", f) for f in sorted(os.listdir(docs_dir))
                  if f.endswith(".md")]
    errors = []
    for rel in files:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            continue
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            base, _, anchor = target.partition("#")
            if base:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), base))
                if not os.path.exists(dest):
                    errors.append(f"{rel}: broken link -> {target}")
                    continue
            else:
                dest = path
            if anchor and dest.endswith(".md"):
                if _slug(anchor) not in _anchors(dest):
                    errors.append(f"{rel}: broken anchor -> {target}")
    return errors


def _missing_docstrings(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, REPO)
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{rel}: module docstring")

    def visit(node, prefix: str, in_class: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                name = child.name
                public = not name.startswith("_")
                qual = f"{prefix}{name}"
                if public and ast.get_docstring(child) is None:
                    kind = ("class" if isinstance(child, ast.ClassDef)
                            else "method" if in_class else "function")
                    missing.append(f"{rel}: {kind} {qual}")
                if isinstance(child, ast.ClassDef):
                    visit(child, qual + ".", True)
                # nested defs are private implementation detail: skip

    visit(tree, "", False)
    return missing


def check_docstrings() -> list:
    errors = []
    for root in DOCSTRING_ROOTS:
        for dirpath, _, names in os.walk(os.path.join(REPO, root)):
            for name in sorted(names):
                if name.endswith(".py"):
                    errors += _missing_docstrings(os.path.join(dirpath, name))
    return errors


def _module_all(path: str) -> list:
    """Read ``__all__`` from a module via ast (no import, no jax)."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                    return list(ast.literal_eval(node.value))
    return []


def check_api_symbols() -> list:
    """Every ``__all__`` export of dist/runtime must appear in api.md."""
    doc_path = os.path.join(REPO, API_DOC)
    if not os.path.exists(doc_path):
        return [f"{API_DOC}: missing (API symbol gate has no target)"]
    with open(doc_path, encoding="utf-8") as f:
        text = f.read()
    errors = []
    for rel in API_EXPORT_MODULES:
        path = os.path.join(REPO, rel)
        names = _module_all(path)
        if not names:
            errors.append(f"{rel}: no __all__ found")
            continue
        for name in names:
            if not re.search(rf"\b{re.escape(name)}\b", text):
                errors.append(f"{API_DOC}: public symbol {name} "
                              f"(from {rel}) is undocumented")
    return errors


SERVING_DOC = "docs/serving.md"

#: serving.md matrix column -> serving_features key (order must match the
#: table header)
_MATRIX_COLS = (("chunked", "chunked"), ("bit-exact", "chunked_exact"),
                ("paged", "paged"), ("prefix cache", "prefix_cache"),
                ("EP decode", "ep_decode"))


def _parse_serving_matrix(text: str):
    """Rows of the ``| arch | carry | ... |`` table as
    ``{arch: (carry, {feature: bool})}``."""
    lines = text.splitlines()
    try:
        start = next(i for i, ln in enumerate(lines)
                     if ln.strip().startswith("| arch | carry |"))
    except StopIteration:
        return None
    header = [c.strip() for c in lines[start].strip("|").split("|")]
    assert header[2:] == [c for c, _ in _MATRIX_COLS], header
    rows = {}
    for ln in lines[start + 2:]:
        if not ln.strip().startswith("|"):
            break
        cells = [c.strip() for c in ln.strip("|").split("|")]
        arch = cells[0].strip("`")
        rows[arch] = (cells[1], {key: cells[2 + i] == "✓"
                                 for i, (_, key) in
                                 enumerate(_MATRIX_COLS)})
    return rows


def check_serving_matrix() -> list:
    """The serving.md matrix mirrors the capability table, both ways."""
    try:
        sys.path.insert(0, os.path.join(REPO, "src"))
        from repro.configs import ARCH_NAMES, get_config
        from repro.configs.base import chunk_carry_spec, serving_features
    except ImportError as e:
        print(f"docs-check: serving matrix skipped (no jax here: {e}); "
              f"tier-1 runs it via tests/test_docs.py")
        return []
    with open(os.path.join(REPO, SERVING_DOC), encoding="utf-8") as f:
        rows = _parse_serving_matrix(f.read())
    if rows is None:
        return [f"{SERVING_DOC}: arch × serving-feature matrix not found"]
    errors = []
    for arch in ARCH_NAMES:
        if arch not in rows:
            errors.append(f"{SERVING_DOC}: registry arch {arch} missing "
                          f"from the serving matrix")
            continue
        cfg = get_config(arch).reduced()
        carry, feats = rows[arch]
        want_carry = chunk_carry_spec(cfg).kind
        if carry != want_carry:
            errors.append(f"{SERVING_DOC}: {arch} carry is {carry!r}, "
                          f"capability table says {want_carry!r}")
        want = serving_features(cfg)
        for col, key in _MATRIX_COLS:
            if feats[key] != want[key]:
                errors.append(
                    f"{SERVING_DOC}: {arch} column {col!r} is "
                    f"{feats[key]}, capability table says {want[key]}")
    for arch in rows:
        if arch not in ARCH_NAMES:
            errors.append(f"{SERVING_DOC}: matrix row {arch!r} is not a "
                          f"registry arch (stale?)")
    return errors


def main() -> int:
    errors = (check_links() + check_docstrings() + check_api_symbols()
              + check_serving_matrix())
    for e in errors:
        print(f"docs-check: {e}")
    if errors:
        print(f"docs-check: {len(errors)} problem(s)")
        return 1
    print("docs-check: links + docstrings + API symbols + serving matrix OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
