"""CI gate over BENCH_overlap.json: streamed must never model slower than bulk.

``benchmarks/overlap_pipeline.py`` writes, per EP preset operating point
and link model, the modeled bulk and best-streamed wall times.  This gate
fails (exit 1) if any preset operating point's **best-link** streamed
schedule regresses below 1.0× of bulk — i.e. if a change to the scheduler,
the conduit cost model, or the netmodel makes the pipeline the *wrong*
choice at an operating point the presets actually ship.  (The stronger
> 1.2× acceptance claim is asserted inside the benchmark itself; the gate
is the regression floor.)

Usage: ``python tools/bench_gate.py [path-to-BENCH_overlap.json]``
"""

from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLOOR = 1.0


def check(path: str) -> int:
    """Exit code: 0 when every preset operating point clears the floor."""
    with open(path) as f:
        payload = json.load(f)
    rows = [r for r in payload.get("rows", [])
            if r.get("source") == "preset-model"]
    if not rows:
        print(f"bench_gate: no preset-model rows in {path}")
        return 1

    points = {}
    for r in rows:
        key = (r["preset"], r["tokens_per_rank"])
        points.setdefault(key, []).append(r)
    failures = []
    for (preset, tokens), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {preset} @ {tokens} tok/rank: best "
              f"{best['speedup']:.2f}x on {best['link']} "
              f"({best['transport']}, {best['stream_chunks']} chunks) "
              f"[{status}]")
        if best["speedup"] < FLOOR:
            failures.append((preset, tokens, best["speedup"]))

    claim = payload.get("claims", {}).get("ep_min_speedup_best_link")
    print(f"bench_gate: worst best-link speedup across presets: {claim}")
    if failures:
        print(f"bench_gate: {len(failures)} operating point(s) below "
              f"{FLOOR}x: {failures}")
        return 1
    print("bench_gate: all preset operating points clear the floor")
    return 0


if __name__ == "__main__":
    target = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO_ROOT, "BENCH_overlap.json")
    sys.exit(check(target))
