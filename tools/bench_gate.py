"""CI gate over the modeled perf artifacts: streamed must never lose.

Three artifacts, one floor:

* ``BENCH_overlap.json`` (``benchmarks/overlap_pipeline.py``) — per EP
  preset operating point, the best-link streamed EP schedule must model
  ≥ 1.0× of bulk (the stronger > 1.2× acceptance claim is asserted inside
  the benchmark itself; the gate is the regression floor).
* ``BENCH_overlap.json``, ``fused_tp`` suite — per TP preset operating
  point (tokens/rank × edge op), the best-link fused collective matmul
  (``kernels/cc_matmul``) must model ≥ 1.0× of the best XLA-level
  streamed schedule (the strict > 1.0× claim lives in the benchmark).
* ``BENCH_serve.json`` (``benchmarks/serve_bench.py``) — per serve preset
  operating point (arch × prompt length), the best-link chunked-prefill
  TTFT must model ≥ 1.0× of bulk prefill (the ≥ 1.3× QSFP acceptance
  claim lives in the benchmark).
* ``BENCH_elastic.json`` (``benchmarks/elastic_bench.py``) — per elastic
  operating point, shorter checkpoint intervals must never model slower
  train recovery, and prefix-reusing re-admission must never model
  slower than full re-prefill (the ≥ 1.3× QSFP acceptance claim lives
  in the benchmark).

The gate fails (exit 1) if any preset operating point regresses below the
floor — i.e. if a change to the scheduler, the conduit cost model, or the
netmodel makes the pipeline the *wrong* choice at an operating point the
presets actually ship.

Usage: ``python tools/bench_gate.py [overlap.json [serve.json [elastic.json]]]``
"""

from __future__ import annotations

import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLOOR = 1.0


def check(path: str) -> int:
    """Overlap gate: every EP preset operating point clears the floor."""
    with open(path) as f:
        payload = json.load(f)
    rows = [r for r in payload.get("rows", [])
            if r.get("source") == "preset-model"]
    if not rows:
        print(f"bench_gate: no preset-model rows in {path}")
        return 1

    points = {}
    for r in rows:
        key = (r["preset"], r["tokens_per_rank"])
        points.setdefault(key, []).append(r)
    failures = []
    for (preset, tokens), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {preset} @ {tokens} tok/rank: best "
              f"{best['speedup']:.2f}x on {best['link']} "
              f"({best['transport']}, {best['stream_chunks']} chunks) "
              f"[{status}]")
        if best["speedup"] < FLOOR:
            failures.append((preset, tokens, best["speedup"]))

    claim = payload.get("claims", {}).get("ep_min_speedup_best_link")
    print(f"bench_gate: worst best-link speedup across presets: {claim}")
    if failures:
        print(f"bench_gate: {len(failures)} operating point(s) below "
              f"{FLOOR}x: {failures}")
        return 1
    print("bench_gate: all preset operating points clear the floor")
    return 0


def check_fused(path: str) -> int:
    """Fused gate: every TP preset operating point clears the floor."""
    with open(path) as f:
        payload = json.load(f)
    rows = [r for r in payload.get("rows", [])
            if r.get("source") == "tp-preset-model"]
    if not rows:
        print(f"bench_gate: no tp-preset-model rows in {path}")
        return 1

    points = {}
    for r in rows:
        key = (r["preset"], r["tokens_per_rank"], r["op"])
        points.setdefault(key, []).append(r)
    failures = []
    for (preset, tokens, op), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {preset} {op} @ {tokens} tok/rank: fused "
              f"{best['speedup']:.2f}x vs {best['streamed_transport']} "
              f"on {best['link']} [{status}]")
        if best["speedup"] < FLOOR:
            failures.append((preset, tokens, op, best["speedup"]))

    claim = payload.get("claims", {}).get("fused_min_speedup_best_link")
    print(f"bench_gate: worst best-link fused speedup across presets: "
          f"{claim}")
    if failures:
        print(f"bench_gate: {len(failures)} fused operating point(s) below "
              f"{FLOOR}x: {failures}")
        return 1
    print("bench_gate: all fused operating points clear the floor")
    return 0


def check_serve(path: str) -> int:
    """Serve gate: every chunked-prefill operating point clears the floor."""
    with open(path) as f:
        payload = json.load(f)
    rows = [r for r in payload.get("rows", [])
            if r.get("suite") == "chunked_prefill"]
    if not rows:
        print(f"bench_gate: no chunked_prefill rows in {path}")
        return 1

    points = {}
    for r in rows:
        points.setdefault((r["arch"], r["prompt_len"]), []).append(r)
    failures = []
    for (arch, s), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {arch} @ {s} prompt: TTFT "
              f"{best['speedup']:.2f}x on {best['link']} "
              f"({best['n_chunks']} chunks) [{status}]")
        if best["speedup"] < FLOOR:
            failures.append((arch, s, best["speedup"]))

    claim = payload.get("claims", {}).get("ttft_max_speedup_qsfp")
    print(f"bench_gate: best qsfp TTFT speedup: {claim}")

    # paged prefix-cache rows (PR 6): a hit must never model slower than
    # the cold admission it replaces, at any swept hit depth
    prefix = [r for r in payload.get("rows", [])
              if r.get("suite") == "paged_prefix"]
    if not prefix:
        print(f"bench_gate: no paged_prefix rows in {path}")
        return 1
    points = {}
    for r in prefix:
        points.setdefault((r["arch"], r["prompt_len"], r["hit_frac"]),
                          []).append(r)
    for (arch, s, hf), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {arch} @ {s} prompt, {hf:.0%} hit: TTFT "
              f"{best['speedup']:.2f}x on {best['link']} "
              f"({best['n_shared_blocks']} shared blocks) [{status}]")
        if best["speedup"] < FLOOR:
            failures.append((arch, s, hf, best["speedup"]))
    hit_claim = payload.get("claims", {}).get("prefix_hit_max_speedup_qsfp")
    print(f"bench_gate: best qsfp prefix-hit speedup: {hit_claim}")

    if failures:
        print(f"bench_gate: {len(failures)} serve operating point(s) "
              f"below {FLOOR}x: {failures}")
        return 1
    print("bench_gate: all serve operating points clear the floor")
    return 0


def check_elastic(path: str) -> int:
    """Elastic gate: recovery must never model slower than its baseline.

    Two floors over ``BENCH_elastic.json``: per (arch, ckpt interval),
    the best-link train recovery vs the longest swept interval (shorter
    intervals can never cost more); per (arch, prompt, surviving
    fraction), the best-link tail-only re-admission vs full re-prefill
    (prefix COW reuse can never lose).  Plus the detector gates: measured
    detection latency within the ``lease_period x (K+1)`` closed-form
    bound, and a zero false-positive rate under the ``delay_am`` jitter
    sweep."""
    with open(path) as f:
        payload = json.load(f)
    failures = []

    train = [r for r in payload.get("rows", [])
             if r.get("suite") == "train_recovery"]
    if not train:
        print(f"bench_gate: no train_recovery rows in {path}")
        return 1
    points = {}
    for r in train:
        points.setdefault((r["arch"], r["ckpt_interval"]), []).append(r)
    for (arch, interval), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {arch} ckpt@{interval}: recovery "
              f"{best['recovery_s']:.2f}s ({best['speedup']:.2f}x vs "
              f"longest interval) on {best['link']} [{status}]")
        if best["speedup"] < FLOOR:
            failures.append((arch, interval, best["speedup"]))

    serve = [r for r in payload.get("rows", [])
             if r.get("suite") == "serve_recovery"]
    if not serve:
        print(f"bench_gate: no serve_recovery rows in {path}")
        return 1
    points = {}
    for r in serve:
        points.setdefault((r["arch"], r["prompt_len"], r["survive_frac"]),
                          []).append(r)
    for (arch, s, f_), rs in sorted(points.items()):
        best = max(rs, key=lambda r: r["speedup"])
        status = "ok" if best["speedup"] >= FLOOR else "FAIL"
        print(f"bench_gate: {arch} @ {s} prompt, {f_:.0%} surviving: "
              f"re-admit {best['speedup']:.2f}x vs full re-prefill on "
              f"{best['link']} [{status}]")
        if best["speedup"] < FLOOR:
            failures.append((arch, s, f_, best["speedup"]))

    detect = [r for r in payload.get("rows", [])
              if r.get("suite") == "detection"]
    if not detect:
        print(f"bench_gate: no detection rows in {path}")
        return 1
    for r in detect:
        if r["link"] != "qsfp":
            continue
        lat, bound, fp = (r["detection_latency_s"], r["bound_s"],
                          r["fp_rate"])
        ok = lat <= bound and fp == 0.0
        status = "ok" if ok else "FAIL"
        print(f"bench_gate: detector p={r['lease_period_s']*1e3:.0f}ms "
              f"K={r['k_misses']}: latency {lat*1e3:.1f}ms "
              f"(bound {bound*1e3:.1f}ms), fp {fp:.0%} [{status}]")
        if not ok:
            failures.append(("detection", r["lease_period_s"],
                             r["k_misses"], lat, fp))

    claim = payload.get("claims", {}).get("serve_recovery_max_speedup_qsfp")
    print(f"bench_gate: best qsfp re-admission speedup: {claim}")
    if failures:
        print(f"bench_gate: {len(failures)} elastic operating point(s) "
              f"below {FLOOR}x: {failures}")
        return 1
    print("bench_gate: all elastic operating points clear the floor")
    return 0


if __name__ == "__main__":
    overlap = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        REPO_ROOT, "BENCH_overlap.json")
    serve = sys.argv[2] if len(sys.argv) > 2 else os.path.join(
        REPO_ROOT, "BENCH_serve.json")
    elastic = sys.argv[3] if len(sys.argv) > 3 else os.path.join(
        REPO_ROOT, "BENCH_elastic.json")
    rc = check(overlap)
    rc = check_fused(overlap) or rc
    rc = check_serve(serve) or rc
    rc = check_elastic(elastic) or rc
    sys.exit(rc)
