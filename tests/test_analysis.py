"""The HLO cost walker: exact FLOPs on known programs, loop multipliers,
collective operand accounting."""


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_cost import parse_module, summarize
from repro.analysis.roofline import (
    CollectiveStats, model_flops_for, roofline_from_parts)


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestDotFlops:
    def test_single_matmul_exact(self):
        x = jnp.zeros((128, 64))
        w = jnp.zeros((64, 32))
        s = summarize(_text(lambda a, b: a @ b, x, w))
        assert s.flops == 2 * 128 * 64 * 32

    def test_batched_matmul(self):
        x = jnp.zeros((4, 32, 16))
        w = jnp.zeros((4, 16, 8))
        s = summarize(_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                            x, w))
        assert s.flops == 2 * 4 * 32 * 16 * 8


class TestLoopMultipliers:
    def test_scan_multiplies_by_trip_count(self):
        x = jnp.zeros((64, 64))

        def f(a):
            def body(c, _):
                return c @ x, None
            out, _ = jax.lax.scan(body, a, None, length=7)
            return out

        s = summarize(_text(f, x))
        assert s.flops == 7 * 2 * 64 * 64 * 64

    def test_nested_scans_multiply(self):
        x = jnp.zeros((32, 32))

        def f(a):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ x, None
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, None
            out, _ = jax.lax.scan(outer, a, None, length=5)
            return out

        s = summarize(_text(f, x))
        assert s.flops == 15 * 2 * 32 ** 3


class TestCollectives:
    def test_psum_operand_bytes(self, mesh4):
        x = jnp.zeros((4, 256), jnp.float32)
        xs = jax.device_put(x, jax.sharding.NamedSharding(mesh4, P("x")))
        f = jax.jit(jax.shard_map(lambda v: jax.lax.psum(v, "x"),
                                  mesh=mesh4, in_specs=(P("x"),),
                                  out_specs=P("x")))
        s = summarize(f.lower(xs).compile().as_text())
        # per-device operand: (1, 256) f32 = 1024 B
        assert s.coll_bytes.get("all-reduce", 0) == 1024

    def test_permute_in_loop_multiplied(self, mesh4):
        def f(v):
            def body(c, _):
                c = jax.lax.ppermute(c, "x", [(i, (i + 1) % 4)
                                              for i in range(4)])
                return c, None
            out, _ = jax.lax.scan(body, v, None, length=6)
            return out

        x = jnp.zeros((4, 128), jnp.float32)
        xs = jax.device_put(x, jax.sharding.NamedSharding(mesh4, P("x")))
        g = jax.jit(jax.shard_map(f, mesh=mesh4, in_specs=(P("x"),),
                                  out_specs=P("x")))
        s = summarize(g.lower(xs).compile().as_text())
        assert s.coll_bytes.get("collective-permute", 0) == 6 * 128 * 4


class TestParseRobustness:
    def test_entry_detected(self):
        x = jnp.zeros((8, 8))
        comps, entry = parse_module(_text(lambda a: a @ a, x))
        assert entry is not None
        assert entry in comps


class TestRoofline:
    def test_dominant_term(self):
        coll = CollectiveStats({"all-reduce": int(1e12)}, {"all-reduce": 3})
        r = roofline_from_parts(
            arch="a", shape="s", mesh="m", chips=4,
            per_device_flops=1e12, per_device_bytes=1e9,
            coll=coll, model_flops=2e12)
        assert r.dominant == "collective"
        assert abs(r.compute_s - 1e12 / 197e12) < 1e-9
        assert abs(r.useful_ratio - 0.5) < 1e-9

    def test_model_flops_decode_vs_train(self):
        from repro.configs import get_config, shape_cell
        cfg = get_config("smollm-360m")
        tr = model_flops_for(cfg, shape_cell("train_4k"))
        de = model_flops_for(cfg, shape_cell("decode_32k"))
        assert tr / de == (6 * 256 * 4096) / (2 * 128)
