"""Runtime: fault-tolerant trainer (restart, preemption, watchdog),
elastic re-meshing, continuous-batching server."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.steps import StepConfig
from repro.runtime.elastic import ElasticMesh, remesh, viable_mesh_shapes
from repro.runtime.server import Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _trainer(tmp_path, total=6, interval=2, mesh=None, seed=0):
    cfg = get_config("smollm-360m").reduced()
    scfg = StepConfig(microbatches=1, seq_chunk=8, warmup_steps=2,
                      total_steps=total, peak_lr=1e-3)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                  global_batch=4, seed=seed))
    tcfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path / "ck"),
                         ckpt_interval=interval, log_interval=100)
    return Trainer(cfg, scfg, tcfg, data, mesh=mesh, log_fn=lambda s: None)


class TestTrainerRestart:
    def test_restart_resumes_from_checkpoint(self, tmp_path, mesh22):
        t1 = _trainer(tmp_path, total=4, interval=2, mesh=mesh22)
        t1.train()
        losses_a = [h["loss"] for h in t1.history]

        # a "crashed and restarted" trainer picks up at step 4 (last ckpt)
        t2 = _trainer(tmp_path, total=6, interval=2, mesh=mesh22)
        t2.train()
        assert t2.history[0]["step"] == 5      # resumed after step-4 ckpt
        assert len(t2.history) == 2            # only steps 5..6 run

    def test_restart_trajectory_identical(self, tmp_path, mesh22):
        """Determinism: (run 6) == (run 4, restart, run to 6) losses."""
        t_full = _trainer(tmp_path / "a", total=6, interval=100, mesh=mesh22)
        t_full.train()
        full = [round(h["loss"], 5) for h in t_full.history]

        t1 = _trainer(tmp_path / "b", total=4, interval=4, mesh=mesh22)
        t1.train()
        t2 = _trainer(tmp_path / "b", total=6, interval=4, mesh=mesh22)
        t2.train()
        resumed = [round(h["loss"], 5) for h in t1.history] + \
                  [round(h["loss"], 5) for h in t2.history]
        np.testing.assert_allclose(full, resumed[: len(full)], rtol=1e-3)

    def test_preemption_checkpoints_and_exits(self, tmp_path, mesh22):
        t = _trainer(tmp_path, total=50, interval=100, mesh=mesh22)
        steps_seen = []

        def on_step(step, m):
            steps_seen.append(step)
            if step == 3:
                t._preempted = True     # simulate SIGTERM

        t.train(on_step=on_step)
        assert max(steps_seen) == 3
        assert t.ckpt.latest_step() == 3


class TestWatchdog:
    def test_flags_stragglers(self, tmp_path, mesh22):
        t = _trainer(tmp_path, mesh=mesh22)
        t.tcfg = t.tcfg
        for _ in range(10):
            assert not t._watch_step_time(0.1)
        # three consecutive 10x-slow steps exhaust the budget
        assert not t._watch_step_time(1.0)
        assert not t._watch_step_time(1.0)
        assert t._watch_step_time(1.0)

    def test_recovers_after_normal_step(self, tmp_path, mesh22):
        t = _trainer(tmp_path, mesh=mesh22)
        for _ in range(10):
            t._watch_step_time(0.1)
        t._watch_step_time(1.0)
        t._watch_step_time(0.1)       # strike reset
        assert t._straggler_strikes == 0


class TestElastic:
    def test_viable_shapes(self):
        shapes = viable_mesh_shapes(8, model=2)
        assert shapes[0] == (4, 2)

    def test_remesh_drops_devices(self):
        devs = jax.devices()
        m = remesh(devs, model=2)
        assert m.shape["model"] == 2
        assert m.shape["data"] == len(devs) // 2

    def test_elastic_fail_shrinks_data_axis(self):
        em = ElasticMesh(model=2)
        m0 = em.mesh()
        m1 = em.fail(0, 1)
        assert m1.shape["data"] == m0.shape["data"] - 1

    def test_fail_below_tp_raises(self):
        n = len(jax.devices())
        em = ElasticMesh(model=n)       # TP spans every device
        with pytest.raises(RuntimeError):
            em.fail(0)      # n-1 devices cannot keep TP=n


class TestServer:
    def _server(self, mesh, **kw):
        from repro.dist.sharding import param_pspecs, to_shardings
        from repro.models.model import init_params
        cfg = get_config("smollm-360m").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        return cfg, params, Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=4, **kw))

    def test_all_requests_complete(self, mesh22):
        cfg, params, srv = self._server(mesh22)
        rng = np.random.default_rng(0)
        for _ in range(5):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6))
        srv.run()
        assert len(srv.done) == 5
        assert all(len(r.out_tokens) == 4 for r in srv.done)
        s = srv.stats()
        assert s["tokens"] == 20 and s["throughput_tok_s"] > 0

    def test_output_matches_unbatched_greedy(self, mesh22):
        """Continuous batching must not change any request's tokens —
        including with mixed prompt lengths in flight (per-slot positions)
        and chunked prefill admission."""
        from repro.models.decode import decode_step
        from repro.models.prefill import prefill
        cfg, params, srv = self._server(mesh22, prefill_chunk=4)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab_size, size=s)
                   for s in (6, 9, 5)]
        for p in prompts:
            srv.submit(p)
        srv.run()

        params_local = jax.device_get(params)
        by_rid = {r.rid: r for r in srv.done}
        for rid, p in enumerate(prompts):
            cache, logits = prefill(cfg, params_local,
                                    jnp.asarray(p[None, :]), cache_len=64)
            out = []
            for _ in range(4):
                nxt = int(jnp.argmax(logits, -1)[0])
                out.append(nxt)
                cache, logits = decode_step(cfg, params_local, cache,
                                            jnp.asarray([nxt], jnp.int32))
            assert out == by_rid[rid].out_tokens, (out, by_rid[rid])

    def test_chunked_admission_equals_bulk(self, mesh22):
        """Chunked prefill admission must be token-identical to bulk
        per-slot admission (the bit-identity claim at the scheduler
        level)."""
        outs = {}
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, 1000, size=s) for s in (11, 4, 7)]
        for chunk in (None, 3):
            cfg, params, srv = self._server(mesh22, prefill_chunk=chunk)
            for p in prompts:
                srv.submit(p % cfg.vocab_size)
            srv.run()
            outs[chunk] = {r.rid: r.out_tokens for r in srv.done}
        assert outs[None] == outs[3]

    def test_ttft_stamped_at_first_decode_token(self, mesh22):
        """``first_token`` stamps when the first decode token id exists —
        not at prefill completion, and never before the final prefill
        chunk under chunked admission."""
        cfg, params, srv = self._server(mesh22, prefill_chunk=3)
        rng = np.random.default_rng(3)
        srv.submit(rng.integers(0, cfg.vocab_size, size=8))  # 3 chunks
        # two ticks run two prefill chunks; no token exists yet
        srv.step()
        srv.step()
        req = srv.slots[0]
        assert req is not None and req.phase == "prefill"
        assert req.first_token is None and not req.out_tokens
        before = time.perf_counter()
        srv.step()          # final chunk: first token sampled here
        assert req.out_tokens and req.first_token is not None
        assert req.first_token >= before
        srv.run()
        assert req.finished is not None
        assert req.submitted <= req.first_token <= req.finished
        s = srv.stats()
        assert s["mean_ttft_s"] > 0 and s["mean_itl_s"] >= 0
