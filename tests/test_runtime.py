"""Runtime: fault-tolerant trainer (restart, preemption, watchdog),
elastic re-meshing, continuous-batching server."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist.steps import StepConfig
from repro.runtime.elastic import ElasticMesh, remesh, viable_mesh_shapes
from repro.runtime.server import Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _trainer(tmp_path, total=6, interval=2, mesh=None, seed=0):
    cfg = get_config("smollm-360m").reduced()
    scfg = StepConfig(microbatches=1, seq_chunk=8, warmup_steps=2,
                      total_steps=total, peak_lr=1e-3)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                  global_batch=4, seed=seed))
    tcfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path / "ck"),
                         ckpt_interval=interval, log_interval=100)
    return Trainer(cfg, scfg, tcfg, data, mesh=mesh, log_fn=lambda s: None)


class TestTrainerRestart:
    def test_restart_resumes_from_checkpoint(self, tmp_path, mesh22):
        t1 = _trainer(tmp_path, total=4, interval=2, mesh=mesh22)
        t1.train()
        losses_a = [h["loss"] for h in t1.history]

        # a "crashed and restarted" trainer picks up at step 4 (last ckpt)
        t2 = _trainer(tmp_path, total=6, interval=2, mesh=mesh22)
        t2.train()
        assert t2.history[0]["step"] == 5      # resumed after step-4 ckpt
        assert len(t2.history) == 2            # only steps 5..6 run

    def test_restart_trajectory_identical(self, tmp_path, mesh22):
        """Determinism: (run 6) == (run 4, restart, run to 6) losses."""
        t_full = _trainer(tmp_path / "a", total=6, interval=100, mesh=mesh22)
        t_full.train()
        full = [round(h["loss"], 5) for h in t_full.history]

        t1 = _trainer(tmp_path / "b", total=4, interval=4, mesh=mesh22)
        t1.train()
        t2 = _trainer(tmp_path / "b", total=6, interval=4, mesh=mesh22)
        t2.train()
        resumed = [round(h["loss"], 5) for h in t1.history] + \
                  [round(h["loss"], 5) for h in t2.history]
        np.testing.assert_allclose(full, resumed[: len(full)], rtol=1e-3)

    def test_preemption_checkpoints_and_exits(self, tmp_path, mesh22):
        t = _trainer(tmp_path, total=50, interval=100, mesh=mesh22)
        steps_seen = []

        def on_step(step, m):
            steps_seen.append(step)
            if step == 3:
                t._preempted = True     # simulate SIGTERM

        t.train(on_step=on_step)
        assert max(steps_seen) == 3
        assert t.ckpt.latest_step() == 3


class TestWatchdog:
    def test_flags_stragglers(self, tmp_path, mesh22):
        t = _trainer(tmp_path, mesh=mesh22)
        t.tcfg = t.tcfg
        for _ in range(10):
            assert not t._watch_step_time(0.1)
        # three consecutive 10x-slow steps exhaust the budget
        assert not t._watch_step_time(1.0)
        assert not t._watch_step_time(1.0)
        assert t._watch_step_time(1.0)

    def test_recovers_after_normal_step(self, tmp_path, mesh22):
        t = _trainer(tmp_path, mesh=mesh22)
        for _ in range(10):
            t._watch_step_time(0.1)
        t._watch_step_time(1.0)
        t._watch_step_time(0.1)       # strike reset
        assert t._straggler_strikes == 0


class TestElastic:
    def test_viable_shapes(self):
        shapes = viable_mesh_shapes(8, model=2)
        assert shapes[0] == (4, 2)

    def test_remesh_drops_devices(self):
        devs = jax.devices()
        m = remesh(devs, model=2)
        assert m.shape["model"] == 2
        assert m.shape["data"] == len(devs) // 2

    def test_elastic_fail_shrinks_data_axis(self):
        em = ElasticMesh(model=2)
        m0 = em.mesh()
        m1 = em.fail(0, 1)
        assert m1.shape["data"] == m0.shape["data"] - 1

    def test_fail_below_tp_raises(self):
        n = len(jax.devices())
        em = ElasticMesh(model=n)       # TP spans every device
        with pytest.raises(RuntimeError):
            em.fail(0)      # n-1 devices cannot keep TP=n


class TestServer:
    def _server(self, mesh):
        from repro.dist.sharding import param_pspecs, to_shardings
        from repro.models.model import init_params
        cfg = get_config("smollm-360m").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        return cfg, params, Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=4))

    def test_all_requests_complete(self, mesh22):
        cfg, params, srv = self._server(mesh22)
        rng = np.random.default_rng(0)
        for _ in range(5):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6))
        srv.run()
        assert len(srv.done) == 5
        assert all(len(r.out_tokens) == 4 for r in srv.done)
        s = srv.stats()
        assert s["tokens"] == 20 and s["throughput_tok_s"] > 0

    def test_output_matches_unbatched_greedy(self, mesh22):
        """Continuous batching must not change any request's tokens."""
        from repro.models.decode import decode_step, init_cache
        cfg, params, srv = self._server(mesh22)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab_size, size=6) for _ in range(3)]
        for p in prompts:
            srv.submit(p)
        srv.run()

        params_local = jax.device_get(params)
        for req in srv.done:
            cache = init_cache(cfg, 1, 64)
            toks = list(req.prompt)
            logits = None
            for t in toks:
                cache, logits = decode_step(cfg, params_local, cache,
                                            jnp.asarray([t], jnp.int32))
            out = []
            for _ in range(4):
                nxt = int(jnp.argmax(logits, -1)[0])
                out.append(nxt)
                cache, logits = decode_step(cfg, params_local, cache,
                                            jnp.asarray([nxt], jnp.int32))
            assert out == req.out_tokens, (out, req.out_tokens)
