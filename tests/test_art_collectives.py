"""ART schedules + GASNet extended-API collectives vs dense references."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from hypothesis import given, settings, strategies as st

from repro.core import art, collectives as col


def _shard(mesh, x, spec):
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))


class TestARTMatmul:
    @pytest.mark.parametrize("n_chunks", [1, 2, 4, 8])
    def test_matches_dense(self, mesh4, n_chunks):
        key = jax.random.PRNGKey(n_chunks)
        m = jax.random.normal(key, (32, 16))
        n = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        ms = _shard(mesh4, m, P(None, "x"))
        ns = _shard(mesh4, n, P("x", None))
        f = jax.jit(jax.shard_map(
            functools.partial(art.art_matmul_reducescatter, axis="x",
                              n_chunks=n_chunks),
            mesh=mesh4, in_specs=(P(None, "x"), P("x", None)),
            out_specs=P(None, "x")))
        np.testing.assert_allclose(np.asarray(f(ms, ns)),
                                   np.asarray(m) @ np.asarray(n),
                                   rtol=1e-4, atol=1e-4)

    def test_bulk_baseline_matches(self, mesh4):
        m = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        n = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        ms = _shard(mesh4, m, P(None, "x"))
        ns = _shard(mesh4, n, P("x", None))
        f = jax.jit(jax.shard_map(
            functools.partial(art.bulk_matmul_reducescatter, axis="x"),
            mesh=mesh4, in_specs=(P(None, "x"), P("x", None)),
            out_specs=P(None, "x")))
        np.testing.assert_allclose(np.asarray(f(ms, ns)),
                                   np.asarray(m) @ np.asarray(n),
                                   rtol=1e-4, atol=1e-4)

    def test_art_chunks_visible_in_hlo(self, mesh4):
        """ART = more, smaller messages: the chunked schedule must contain
        ≥ n_chunks× the permutes of the bulk schedule."""
        from repro.analysis.hlo_cost import summarize

        m = jnp.zeros((32, 16))
        n = jnp.zeros((16, 64))
        ms = _shard(mesh4, m, P(None, "x"))
        ns = _shard(mesh4, n, P("x", None))

        def build(fn):
            f = jax.jit(jax.shard_map(
                fn, mesh=mesh4, in_specs=(P(None, "x"), P("x", None)),
                out_specs=P(None, "x")))
            return summarize(f.lower(ms, ns).compile().as_text())

        s_art = build(functools.partial(art.art_matmul_reducescatter,
                                        axis="x", n_chunks=4))
        s_bulk = build(functools.partial(art.bulk_matmul_reducescatter,
                                         axis="x"))
        n_art = s_art.coll_count.get("collective-permute", 0)
        n_bulk = max(sum(s_bulk.coll_count.values()), 1)
        assert n_art >= 4 * n_bulk or n_art >= 12


class TestARTSend:
    def test_accumulate(self, mesh4):
        def compute_chunk(k):
            my = jax.lax.axis_index("x").astype(jnp.float32)
            return jnp.full((8,), my + k.astype(jnp.float32))

        run = art.art_send(compute_chunk, n_chunks=3, axis="x")
        f = jax.jit(jax.shard_map(lambda: run(), mesh=mesh4, in_specs=(),
                                  out_specs=P("x")))
        out = np.asarray(f()).reshape(4, 8)
        for r in range(4):
            src = (r - 1) % 4
            want = sum(src + k for k in range(3))
            np.testing.assert_allclose(out[r], want)


class TestSplitConv:
    def test_matches_dense(self, mesh4):
        imgs = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 12, 4))
        kern = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
        ks = _shard(mesh4, kern, P(None, None, None, "x"))
        f = jax.jit(jax.shard_map(
            functools.partial(art.split_conv_allgather, axis="x"),
            mesh=mesh4, in_specs=(P(), P(None, None, None, "x")),
            out_specs=P(), check_vma=False))
        want = jax.lax.conv_general_dilated(
            imgs, kern, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(f(imgs, ks)), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestCollectives:
    def test_barrier(self, mesh4):
        # per-rank out_specs: the ring-relay barrier count is identical on
        # every rank but not statically provably replicated (no psum), so
        # assert the stronger per-rank property instead of P().
        f = jax.jit(jax.shard_map(lambda: col.barrier("x")[None],
                                  mesh=mesh4, in_specs=(),
                                  out_specs=P("x")))
        assert np.asarray(f()).tolist() == [4, 4, 4, 4]

    @given(root=st.integers(0, 3))
    @settings(max_examples=4, deadline=None)
    def test_broadcast(self, root):
        mesh = jax.make_mesh((4,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)
        xs = _shard(mesh, x, P("x"))
        f = jax.jit(jax.shard_map(
            functools.partial(col.broadcast, root=root, axis="x"),
            mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
        out = np.asarray(f(xs)).reshape(4, 6)
        for r in range(4):
            np.testing.assert_allclose(out[r], np.asarray(x)[root])

    def test_ring_all_gather(self, mesh4):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 3))
        xs = _shard(mesh4, x, P("x"))
        f = jax.jit(jax.shard_map(
            functools.partial(col.ring_all_gather, axis="x"),
            mesh=mesh4, in_specs=(P("x"),), out_specs=P("x")))
        out = np.asarray(f(xs)).reshape(4, 8, 3)
        for r in range(4):
            np.testing.assert_allclose(out[r], np.asarray(x), rtol=1e-6)

    def test_ring_reduce_scatter(self, mesh4):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 3))
        xs = _shard(mesh4, x.reshape(4 * 8, 3), P("x"))
        f = jax.jit(jax.shard_map(
            functools.partial(col.ring_reduce_scatter, axis="x"),
            mesh=mesh4, in_specs=(P("x"),), out_specs=P("x")))
        out = np.asarray(f(xs)).reshape(4, 2, 3)
        want = np.asarray(x).reshape(4, 4, 2, 3).sum(0)  # sum over ranks
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)

    @given(shape=st.sampled_from([(5,), (8, 3), (2, 3, 4), (7, 2)]))
    @settings(max_examples=8, deadline=None)
    def test_ring_all_reduce_matches_psum(self, shape):
        mesh = jax.make_mesh((4,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jax.random.normal(jax.random.PRNGKey(0), (4,) + shape)
        xs = _shard(mesh, x.reshape((4 * shape[0],) + shape[1:]), P("x"))
        ours = jax.jit(jax.shard_map(
            functools.partial(col.ring_all_reduce, axis="x"),
            mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
        ref = jax.jit(jax.shard_map(
            lambda v: jax.lax.psum(v, "x"),
            mesh=mesh, in_specs=(P("x"),), out_specs=P("x")))
        np.testing.assert_allclose(np.asarray(ours(xs)), np.asarray(ref(xs)),
                                   rtol=1e-5, atol=1e-5)

    def test_all_to_all(self, mesh4):
        x = jnp.arange(4 * 4 * 2, dtype=jnp.float32).reshape(4, 4, 2)
        xs = _shard(mesh4, x.reshape(16, 2), P("x"))

        def f(v):
            return col.all_to_all_chunked(v.reshape(4, 1, 2),
                                          axis="x").reshape(4, 2)

        out = np.asarray(jax.jit(jax.shard_map(
            f, mesh=mesh4, in_specs=(P("x"),), out_specs=P("x")))(xs))
        out = out.reshape(4, 4, 2)
        want = np.asarray(x).transpose(1, 0, 2)   # transpose of blocks
        np.testing.assert_allclose(out, want)
