"""Fault injection + elastic recovery: scripted rank kills, retrying
conduits, viable-shape enumeration, step-config re-fit, reshard-on-restore,
BlockPool partition loss, and the two end-to-end identity guarantees —
mid-serve token identity and mid-train loss-trajectory identity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import conduit
from repro.core import netmodel as nm
from repro.data import DataConfig, SyntheticLM
from repro.dist.bucketing import span_scaled_target
from repro.dist.sharding import param_pspecs, to_shardings
from repro.dist.steps import StepConfig, refit_step_config
from repro.models.model import init_params
from repro.runtime.elastic import (reform_conduits, scaled_microbatches,
                                   viable_mesh_shapes)
from repro.runtime.faults import FaultEvent, FaultPlan, RankFailure
from repro.runtime.server import BlockPool, Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _mesh1d(n):
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("x",))


def _params_on(cfg, mesh, key=0):
    shape = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.random.PRNGKey(key))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
    return jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(key)), shape, psh


# ---------------------------------------------------------------------------
# FaultPlan semantics
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_event_validation(self):
        with pytest.raises(ValueError):
            FaultEvent("melt_rack")
        with pytest.raises(ValueError):
            FaultEvent("kill_rank")            # needs a rank
        with pytest.raises(ValueError):
            FaultEvent("drop_op", op="all_reduce", count=0)

    def test_kill_fires_once_at_step(self):
        plan = FaultPlan().kill_rank(1, at_step=3)
        for s in range(3):
            plan.on_step(s)                    # steps 0..2: healthy
        assert plan.dead_ranks() == frozenset()
        with pytest.raises(RankFailure) as ei:
            plan.on_step(3)
        assert ei.value.rank == 1
        assert plan.dead_ranks() == frozenset({1})
        plan.on_step(4)                        # announced once, not again

    def test_dead_rank_poisons_conduit_hook(self):
        plan = FaultPlan().kill_rank(0, at_step=0)
        with pytest.raises(RankFailure):
            plan.on_step(0)
        with pytest.raises(RankFailure):
            plan("all_reduce", "data")         # every op on the axis fails
        plan.repair(0)
        plan("all_reduce", "data")             # survivor re-form succeeds

    def test_drop_op_budget(self):
        plan = FaultPlan().drop_op(op="all_gather", count=2)
        for _ in range(2):
            with pytest.raises(RankFailure):
                plan("all_gather", "x")
        plan("all_gather", "x")                # budget spent: transient over
        plan("all_reduce", "x")                # other ops never dropped

    def test_from_cli(self):
        assert FaultPlan.from_cli(None, 1) is None
        plan = FaultPlan.from_cli(4, 2)
        assert plan.events[0].kind == "kill_rank"
        assert plan.events[0].step == 4 and plan.events[0].rank == 2

    def test_install_context_manager(self):
        plan = FaultPlan().kill_rank(0, at_step=0)
        with pytest.raises(RankFailure):
            plan.on_step(0)
        with plan:
            with pytest.raises(RankFailure):
                conduit.check_failure("barrier", "data")
        conduit.check_failure("barrier", "data")   # uninstalled: no-op


# ---------------------------------------------------------------------------
# retrying conduit (satellite: transient vs permanent failures)
# ---------------------------------------------------------------------------


class TestRetryingConduit:
    def test_attempts_validated(self):
        with pytest.raises(ValueError):
            conduit.Conduit("x").with_retry(attempts=0)

    def test_transient_drop_succeeds_on_retry(self):
        n = min(4, len(jax.devices()))
        mesh = _mesh1d(n)
        cd = conduit.Conduit("x", "xla")
        rc = cd.with_retry(attempts=3)
        x = jax.random.normal(jax.random.PRNGKey(0), (n * 4, 6))
        want = np.asarray(jax.shard_map(
            lambda v: cd.all_gather(v), mesh=mesh,
            in_specs=P("x"), out_specs=P("x"))(x))
        plan = FaultPlan().drop_op(op="all_gather", count=2)
        with plan:
            got = np.asarray(jax.shard_map(
                lambda v: rc.all_gather(v), mesh=mesh,
                in_specs=P("x"), out_specs=P("x"))(x))
        np.testing.assert_array_equal(got, want)

    def test_permanent_loss_exhausts_attempts(self):
        n = min(4, len(jax.devices()))
        mesh = _mesh1d(n)
        rc = conduit.Conduit("x", "xla").with_retry(attempts=2)
        x = jnp.ones((n * 2, 3))
        plan = FaultPlan().kill_rank(1, at_step=0)   # dead until repaired
        with plan:
            with pytest.raises(RankFailure) as ei:
                jax.shard_map(lambda v: rc.all_gather(v), mesh=mesh,
                              in_specs=P("x"), out_specs=P("x"))(x)
        assert ei.value.rank == 1


# ---------------------------------------------------------------------------
# viable shapes + re-fit arithmetic (satellite: clean division only)
# ---------------------------------------------------------------------------


class TestViableShapes:
    def test_only_cleanly_dividing_shapes(self):
        # 8 devices, TP=2: data spans that divide 4 — never (3, 2)
        assert viable_mesh_shapes(8, model=2) == [(4, 2), (2, 2), (1, 2)]
        assert viable_mesh_shapes(6, model=2) == [(3, 2), (1, 2)]
        assert viable_mesh_shapes(7, model=1) == [(7, 1), (1, 1)]

    def test_model_exceeding_devices_raises(self):
        with pytest.raises(RuntimeError):
            viable_mesh_shapes(2, model=4)
        with pytest.raises(RuntimeError):
            viable_mesh_shapes(4, model=0)

    def test_scaled_microbatches(self):
        assert scaled_microbatches(2, 4, 2) == 4
        assert scaled_microbatches(1, 4, 1) == 4
        with pytest.raises(RuntimeError):
            scaled_microbatches(1, 3, 2)       # global batch can't survive

    def test_span_scaled_target(self):
        assert span_scaled_target(4 << 20, 4, 2) == 2 << 20
        assert span_scaled_target(4 << 20, 2, 2) == 4 << 20
        assert span_scaled_target(7, 8, 1) >= 1          # floor at 1 byte
        with pytest.raises(ValueError):
            span_scaled_target(1 << 20, 0, 2)

    def test_refit_step_config(self):
        s = StepConfig(microbatches=2, grad_bucket_bytes=4 << 20)
        r = refit_step_config(s, 4, 2)
        assert r.microbatches == 4                       # global batch held
        assert r.grad_bucket_bytes == 2 << 20            # per-hop msg held
        assert refit_step_config(StepConfig(), 2, 1).grad_bucket_bytes is None
        with pytest.raises(RuntimeError):
            refit_step_config(s, 3, 2)


# ---------------------------------------------------------------------------
# conduit re-form + recovery-cost model
# ---------------------------------------------------------------------------


class TestReformConduits:
    def test_plans_cover_multi_extent_axes(self, mesh22):
        plans = reform_conduits(mesh22)
        assert set(plans) == {"data", "model"}
        for axis, plan in plans.items():
            assert plan.size == 2
            assert set(plan.op_transports) == {
                "all_gather", "reduce_scatter", "all_reduce", "all_to_all"}
            assert plan.matmul_family in ("ring", "bidir", "fused")
            assert plan.conduit.axis == axis

    def test_recovery_cost_model(self):
        link = nm.FSHMEM_QSFP
        pkt = max(link.packet_overhead_bytes)
        # re-form is a few short control rounds: grows with rank count
        assert nm.reform_time(link, 8, pkt) > nm.reform_time(link, 4, pkt) > 0
        assert nm.reprefill_time(link, 1e-4, 0, 256, 4, pkt) == 0.0
        assert (nm.reprefill_time(link, 1e-4, 128, 256, 4, pkt)
                > nm.reprefill_time(link, 1e-4, 16, 256, 4, pkt))
        s = nm.serve_recovery_time(link, n_ranks=4, t_compute_per_tok=1e-4,
                                   reprefill_tokens=64, kv_bytes_per_tok=4096,
                                   n_chunks=4, packet_size=pkt)
        assert s > nm.reform_time(link, 4, pkt)
        # shorter checkpoint interval -> less replay -> faster recovery
        fast = nm.train_recovery_time(link, n_ranks=4, ckpt_bytes=1 << 30,
                                      ckpt_interval_steps=10, step_time=0.5,
                                      packet_size=pkt)
        slow = nm.train_recovery_time(link, n_ranks=4, ckpt_bytes=1 << 30,
                                      ckpt_interval_steps=100, step_time=0.5,
                                      packet_size=pkt)
        assert fast < slow


# ---------------------------------------------------------------------------
# BlockPool partition loss (conservation under drain)
# ---------------------------------------------------------------------------


class TestBlockPoolPartition:
    def test_partitions_tile_the_pool(self):
        pool = BlockPool(32, reserved=4)
        ids = [b for r in range(3) for b in pool.partition(r, 3)]
        assert ids == list(range(32))          # disjoint, exhaustive

    def test_fail_partition_conserves_blocks(self):
        pool = BlockPool(16, reserved=2)
        a = pool.alloc(4)                       # live on various partitions
        b = pool.alloc(3)
        pool.cache_insert(b"k", b)              # pinned by a cache entry too
        lost = pool.fail_partition(1, 2)        # ids [8, 16) go dark
        assert lost == frozenset(range(8, 16))
        pool.check_conservation()
        # nothing allocatable from the dead partition anymore
        assert not set(pool._free) & lost
        # releasing a lost live block quarantines it instead of freeing it
        pool.release(a)
        pool.check_conservation()
        assert not set(pool._free) & lost

    def test_entries_on_lost_blocks_are_purged(self):
        pool = BlockPool(16, reserved=0)
        bids = pool.alloc(3)
        pool.cache_insert(b"prefix", bids)
        pool.release(bids)                      # entry pin is the only ref
        assert pool.cached_entries == 1
        pool.fail_partition(0, 2)               # low ids die with rank 0
        assert pool.cached_entries == 0         # entry gone, not dangling
        pool.check_conservation()


# ---------------------------------------------------------------------------
# checkpoint reshard-on-restore (satellite: save (n,1) -> restore shrunk)
# ---------------------------------------------------------------------------


class TestCheckpointReshard:
    @pytest.mark.parametrize("new_model", [1, 2])
    def test_restore_resharded_bitwise(self, tmp_path, new_model):
        """Save params + opt state on an (n, 1) mesh, restore onto the
        shrunk (n/2, model) variants: every leaf bitwise-equal after
        regather (checkpoints store logical arrays; the mesh only maps
        them physically)."""
        from repro.checkpoint import load_checkpoint, save_checkpoint
        from repro.dist.steps import build_init
        n = len(jax.devices())
        if n < 4:
            pytest.skip("needs >= 4 host devices")
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig(microbatches=1, seq_chunk=8)

        def mk(data, model):
            return jax.make_mesh(
                (data, model), ("data", "model"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)

        init_fn, _ = build_init(cfg, mk(n, 1), scfg)
        state = init_fn(jax.random.PRNGKey(0))    # (params, opt)
        save_checkpoint(str(tmp_path), 0, state)
        want = jax.tree.map(np.asarray, jax.device_get(state))

        mesh2 = mk(n // 2, new_model)             # half the ranks survive
        init_fn2, (pspecs2, ospecs2) = build_init(cfg, mesh2, scfg)
        template = jax.eval_shape(init_fn2, jax.random.PRNGKey(0))
        sh2 = (to_shardings(mesh2, pspecs2), to_shardings(mesh2, ospecs2))
        got, manifest = load_checkpoint(str(tmp_path), template,
                                        shardings=sh2)
        assert manifest["step"] == 0
        flat_w, td = jax.tree.flatten(want)
        flat_g = td.flatten_up_to(jax.tree.map(np.asarray,
                                               jax.device_get(got)))
        for w, g in zip(flat_w, flat_g):
            assert w.dtype == g.dtype
            np.testing.assert_array_equal(w, g)   # bitwise after regather


# ---------------------------------------------------------------------------
# end-to-end identity guarantees
# ---------------------------------------------------------------------------


class TestServeRecovery:
    def _serve(self, mesh, prompts, plan):
        cfg = get_config("smollm-360m").reduced()
        params, _, _ = _params_on(cfg, mesh)
        srv = Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=6, prefill_chunk=4,
            paged=True, block_size=4), fault_plan=plan)
        for p in prompts:
            srv.submit(p)
        srv.run()
        return srv

    def test_decode_rank_loss_tokens_identical(self, mesh22):
        """Kill a decode rank mid-stream: every in-flight request still
        completes with tokens bitwise-identical to an unfailed run."""
        rng = np.random.default_rng(0)
        cfg = get_config("smollm-360m").reduced()
        prompts = [rng.integers(0, cfg.vocab_size, size=s)
                   for s in (8, 11, 7)]
        clean = self._serve(mesh22, prompts, None)
        failed = self._serve(mesh22, prompts,
                             FaultPlan().kill_rank(1, at_step=6))
        want = {r.rid: r.out_tokens for r in clean.done}
        got = {r.rid: r.out_tokens for r in failed.done}
        assert got == want                      # bitwise token identity
        s = failed.stats()
        assert s["recoveries"] >= 1
        assert s["reprefilled_tokens"] > 0
        assert s["lost_blocks"] > 0
        failed.pool.check_conservation()        # holds after full drain


class TestTrainRecovery:
    def _trainer(self, tmp_path, mesh, total, plan=None):
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig(microbatches=1, seq_chunk=8, warmup_steps=2,
                          total_steps=total, peak_lr=1e-3)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=4, seed=0))
        tcfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path / "ck"),
                             ckpt_interval=2, log_interval=100)
        return Trainer(cfg, scfg, tcfg, data, mesh=mesh,
                       log_fn=lambda s: None, fault_plan=plan)

    def test_rank_loss_trajectory_identical(self, tmp_path, mesh22):
        """Kill a rank mid-run: the survivors re-form, restore the last
        checkpoint resharded, scale grad accumulation, and the resumed loss
        trajectory matches an unfailed run step for step."""
        t_clean = self._trainer(tmp_path / "a", mesh22, total=6)
        t_clean.train()
        clean = {h["step"]: round(h["loss"], 5) for h in t_clean.history}

        plan = FaultPlan().kill_rank(3, at_step=4)
        t = self._trainer(tmp_path / "b", mesh22, total=6, plan=plan)
        t.train()
        assert t.elastic is not None            # the recovery path ran
        report = t.elastic.reports[0]
        assert dict(report.new_shape)["data"] == 1
        assert t.scfg.microbatches == 2         # global batch held constant
        got = {h["step"]: round(h["loss"], 5) for h in t.history}
        for step in range(5, 7):                # post-recovery steps
            assert got[step] == clean[step], (step, got[step], clean[step])


# ---------------------------------------------------------------------------
# retry budget + wrapped schedules (satellite: RetryingConduit gaps)
# ---------------------------------------------------------------------------


class TestRetryBudget:
    def test_max_elapsed_validated(self):
        with pytest.raises(ValueError):
            conduit.Conduit("x").with_retry(max_elapsed_s=-1.0)

    def test_backoff_schedule_deterministic(self, monkeypatch):
        """Backoff doubles per attempt: backoff, 2*backoff, 4*backoff..."""
        slept = []
        monkeypatch.setattr(conduit.time, "sleep", slept.append)
        rc = conduit.Conduit("x").with_retry(attempts=4, backoff=0.1)
        plan = FaultPlan().kill_rank(1, at_step=0)
        with plan:
            with pytest.raises(RankFailure):
                rc._attempt(conduit.check_failure, "all_reduce", "x")
        assert slept == [0.1, 0.2, 0.4]        # no sleep after last attempt

    def test_total_deadline_budget_caps_attempts(self, monkeypatch):
        """max_elapsed_s bounds the summed backoff: an attempt whose
        preceding sleep would blow the budget is never made."""
        slept = []
        monkeypatch.setattr(conduit.time, "sleep", slept.append)
        rc = conduit.Conduit("x").with_retry(attempts=10, backoff=1.0,
                                             max_elapsed_s=4.0)
        plan = FaultPlan().kill_rank(1, at_step=0)
        with plan:
            with pytest.raises(RankFailure):
                rc._attempt(conduit.check_failure, "all_reduce", "x")
        # delays 1, 2 fit (3 <= 4); the next delay 4 would reach 7 > 4
        assert slept == [1.0, 2.0]

    def test_streamed_retries_per_chunk(self):
        n = min(4, len(jax.devices()))
        mesh = _mesh1d(n)
        cd = conduit.Conduit("x", "xla")
        rc = cd.with_retry(attempts=3)
        x = jax.random.normal(jax.random.PRNGKey(1), (n * 4, 6))

        def run(c):
            def f(v):
                chunks = jnp.split(v, 2)
                return jnp.concatenate(c.streamed("all_gather", chunks))
            return np.asarray(jax.shard_map(
                f, mesh=mesh, in_specs=P("x"), out_specs=P("x"))(x))

        want = run(cd)
        plan = FaultPlan().drop_op(op="all_gather", count=2)
        with plan:
            got = run(rc)                      # each chunk retries its drop
        np.testing.assert_array_equal(got, want)

    def test_matmul_schedule_retries(self):
        rc = conduit.Conduit("x", "ring").with_retry(attempts=2)
        plan = FaultPlan().drop_op(op="matmul_schedule", count=1)
        with plan:
            assert rc.matmul_schedule("matmul_ag", 1 << 20) == "ring"
        # budget exhausted mid-way re-raises
        plan = FaultPlan().drop_op(op="matmul_schedule", count=5)
        with plan:
            with pytest.raises(RankFailure):
                rc.matmul_schedule("matmul_ag", 1 << 20)


# ---------------------------------------------------------------------------
# quarantine backpressure (satellite: no cache wipe on doomed allocs)
# ---------------------------------------------------------------------------


class TestQuarantineBackpressure:
    def test_doomed_alloc_preserves_prefix_cache(self):
        """Regression: an alloc the pool can never cover must raise
        WITHOUT first evicting the whole prefix cache."""
        pool = BlockPool(12, reserved=0)
        bids = pool.alloc(4)
        pool.cache_insert(b"hot", bids)
        pool.release(bids)                     # entry pin is the only ref
        assert pool.cached_entries == 1
        with pytest.raises(MemoryError):
            pool.alloc(13)                     # beyond free + evictable
        assert pool.cached_entries == 1        # cache SURVIVED the failure
        assert pool.evictions == 0
        pool.check_conservation()

    def test_feasible_alloc_still_evicts(self):
        pool = BlockPool(8, reserved=0)
        bids = pool.alloc(4)
        pool.cache_insert(b"hot", bids)
        pool.release(bids)
        got = pool.alloc(6)                    # needs the entry's blocks
        assert len(got) == 6 and pool.evictions == 1
        pool.check_conservation()

    def test_capacity_shrinks_under_quarantine(self):
        pool = BlockPool(16, reserved=0)
        pool.fail_partitions([0, 1], 4)        # half the pool goes dark
        assert pool.quarantined_blocks == 8
        assert pool.usable_blocks() == 8
        assert pool.can_cover(8) and not pool.can_cover(9)
        with pytest.raises(MemoryError):
            pool.alloc(9)
        pool.check_conservation()
        # restore one span: capacity grows back by exactly its size
        pool.restore_partition(0, 4)
        assert pool.quarantined_blocks == 4 and pool.can_cover(12)
        pool.check_conservation()

    def test_restore_waits_for_straggler_refs(self):
        pool = BlockPool(8, reserved=0)
        held = pool.alloc(8)                   # every block live
        pool.fail_partition(0, 2)              # span [0, 4) lost, still held
        assert pool.quarantined_blocks == 0    # nothing drained yet
        pool.restore_partition(0, 2)           # un-lose the span
        pool.release(held)
        pool.check_conservation()
        assert pool.free_blocks == 8           # held blocks freed normally

    def test_server_burst_defers_instead_of_oom(self, mesh22):
        """An admission burst while a partition is quarantined must defer
        (requests stay queued) rather than MemoryError — and complete
        once capacity allows."""
        cfg = get_config("smollm-360m").reduced()
        params, _, _ = _params_on(cfg, mesh22)
        srv = Server(cfg, params, mesh22, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=4, prefill_chunk=4,
            paged=True, block_size=4))
        srv.fail_decode_rank(1, n_ranks=2)     # half the pool quarantined
        assert srv.pool.quarantined_blocks > 0
        rng = np.random.default_rng(2)
        for s in (8, 9, 7, 10):                # burst past the shrunk target
            srv.submit(rng.integers(0, cfg.vocab_size, size=s))
        srv.run()                              # must not raise MemoryError
        assert len(srv.done) == 4              # everyone completed
        assert srv.stats()["quarantined_blocks"] > 0
        srv.pool.check_conservation()


# ---------------------------------------------------------------------------
# scale-out growth (satellite: join path arithmetic + runtime)
# ---------------------------------------------------------------------------


class TestScaleOut:
    def test_scaled_microbatches_growth(self):
        assert scaled_microbatches(4, 1, 2) == 2    # joiner takes shards back
        assert scaled_microbatches(6, 2, 6) == 2
        with pytest.raises(RuntimeError):
            scaled_microbatches(3, 2, 4)            # does not split evenly
        with pytest.raises(RuntimeError):
            scaled_microbatches(4, 2, 3)            # not clean either way

    def test_refit_step_config_growth(self):
        s = StepConfig(microbatches=4, grad_bucket_bytes=2 << 20)
        r = refit_step_config(s, 2, 4)
        assert r.microbatches == 2                  # global batch held
        assert r.grad_bucket_bytes == 4 << 20       # per-hop msg held
        with pytest.raises(RuntimeError):
            refit_step_config(StepConfig(microbatches=3), 2, 4)

    def test_elastic_mesh_join_and_spares(self):
        from repro.runtime.elastic import ElasticMesh
        devs = jax.devices()
        if len(devs) < 4:
            pytest.skip("needs >= 4 host devices")
        em = ElasticMesh(model=1, devices=list(devs[:3]))
        em.fail(2)
        assert [d.id for d in em.spares()] == [d.id for d in devs[2:]]
        mesh = em.join(devs[2])
        assert mesh.shape["data"] == 3
        assert [d.id for d in em.spares()] == [d.id for d in devs[3:]]
        em.join(devs[2])                            # idempotent re-join
        assert len(em.devices) == 3

    def test_multi_rank_failure_one_report(self):
        from repro.runtime.elastic import ElasticRuntime
        devs = jax.devices()
        if len(devs) < 3:
            pytest.skip("needs >= 3 host devices")
        rt = ElasticRuntime(model=1, devices=list(devs[:3]))
        failure = RankFailure(1, "membership", "batch", ranks=(1, 2))
        report = rt.on_failure(failure, microbatches=1)
        assert len(rt.reports) == 1                 # ONE recovery, not two
        assert report.dead_ranks == (1, 2)
        assert dict(report.new_shape)["data"] == 1
        assert report.microbatches == 3             # global batch held

    def test_on_join_expands_and_refits(self):
        from repro.runtime.elastic import ElasticRuntime
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >= 2 host devices")
        rt = ElasticRuntime(model=1, devices=list(devs[:1]))
        report = rt.on_join(microbatches=2)         # picks the first spare
        assert report.joined_rank == 1
        assert report.dead_ranks == () and report.dead_rank is None
        assert dict(report.new_shape)["data"] == 2
        assert report.microbatches == 1             # divided by the growth
        assert set(report.conduits) == {"data"}
