"""Optimizer substrate: AdamW vs a numpy reference, schedules, clipping,
8-bit error-feedback compression properties."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, clip_by_global_norm,
    compress_8bit, decompress_8bit, ef_compress_update, ef_init,
    global_norm, warmup_cosine)


class TestAdamW:
    def test_matches_numpy_reference(self):
        cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                          weight_decay=0.01)
        p0 = np.random.default_rng(0).normal(size=(5, 3)).astype(np.float32)
        params = {"w": jnp.asarray(p0)}
        state = adamw_init(params, cfg)

        # numpy reference
        m = np.zeros_like(p0)
        v = np.zeros_like(p0)
        p_ref = p0.copy()
        for t in range(1, 4):
            g = (p_ref * 0.1 + t).astype(np.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mh = m / (1 - cfg.b1 ** t)
            vh = v / (1 - cfg.b2 ** t)
            p_ref = p_ref - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps)
                                      + cfg.weight_decay * p_ref)

        ours = params
        for t in range(1, 4):
            g = {"w": ours["w"] * 0.1 + t}
            ours, state = adamw_update(g, state, ours, cfg, cfg.lr)
        np.testing.assert_allclose(np.asarray(ours["w"]), p_ref,
                                   rtol=1e-5, atol=1e-6)

    def test_bf16_params_keep_fp32_master(self):
        cfg = AdamWConfig(lr=1e-4, master_fp32=True)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params, cfg)
        for _ in range(10):
            g = {"w": jnp.full((4,), 1e-6, jnp.bfloat16)}
            params, state = adamw_update(g, state, params, cfg, 1e-6)
        # tiny updates accumulate in the master even below bf16 resolution
        assert float(jnp.sum(jnp.abs(
            state["master"]["w"] - 1.0))) > 0


class TestSchedule:
    def test_warmup_then_decay(self):
        lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                                   total_steps=100)) for s in range(101)]
        assert lrs[0] == 0.0
        assert abs(lrs[10] - 1.0) < 1e-6
        assert lrs[10] >= max(lrs)                # peak at warmup end
        assert abs(lrs[100] - 0.1) < 1e-6         # final_frac·peak
        assert all(b <= a + 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


class TestClip:
    def test_noop_below_threshold(self):
        t = {"a": jnp.ones((3,))}
        c, n = clip_by_global_norm(t, 100.0)
        np.testing.assert_allclose(np.asarray(c["a"]), 1.0)
        np.testing.assert_allclose(float(n), np.sqrt(3), rtol=1e-6)

    def test_scales_to_threshold(self):
        t = {"a": jnp.full((4,), 10.0)}
        c, n = clip_by_global_norm(t, 1.0)
        np.testing.assert_allclose(float(global_norm(c)), 1.0, rtol=1e-5)


class TestCompression:
    @given(n=st.integers(1, 2000))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bounded(self, n):
        x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 10
        q, s = compress_8bit(x, block=256)
        y = decompress_8bit(q, s, x.shape, block=256)
        # per-block error bounded by scale/2 = max|x_block|/254
        err = np.abs(np.asarray(x) - np.asarray(y)).max()
        assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6

    def test_wire_bytes_4x_smaller(self):
        from repro.optim.compress import compressed_bytes
        n = 1 << 20
        assert compressed_bytes(n) < n * 4 / 3.8   # vs fp32

    def test_error_feedback_reinjects(self):
        """EF: the quantization residual of step k enters step k+1, so the
        *cumulative* applied update tracks the cumulative true gradient."""
        g = {"w": jnp.full((256,), 0.001)}      # tiny vs block scale
        ef = ef_init(g)
        applied = np.zeros((256,), np.float32)
        for _ in range(50):
            deq, ef = ef_compress_update(g, ef, block=256)
            applied += np.asarray(deq["w"])
        true = 0.001 * 50
        np.testing.assert_allclose(applied.mean(), true, rtol=0.05)

    def test_without_ef_tiny_grads_vanish(self):
        """Motivates EF: tiny uniform grads + one outlier quantize to zero."""
        x = jnp.full((256,), 1e-4).at[0].set(1.0)
        q, s = compress_8bit(x, block=256)
        y = decompress_8bit(q, s, x.shape, block=256)
        assert np.all(np.asarray(y)[1:] == 0)   # lost without EF
