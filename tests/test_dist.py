"""Distribution layer: sharding specs, chunked CE, train/serve/prefill
steps on a real (2,2) mesh, microbatch equivalence."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM, batch_specs
from repro.dist.loss import chunked_ce_loss
from repro.dist.sharding import (
    MeshAxes, batch_pspecs, cache_pspecs, opt_pspecs, param_pspecs)
from repro.dist.steps import (
    StepConfig, build_init, build_prefill_step, build_serve_step,
    build_train_step)
from repro.models.model import init_params, loss_fn


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-360m").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


class TestShardingSpecs:
    def test_param_rules(self, mesh22, smollm):
        cfg, params = smollm
        specs = param_pspecs(cfg, mesh22, params)
        flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
                for path, s in jax.tree_util.tree_flatten_with_path(specs)[0]}
        # vocab-parallel embed (vocab 256 divisible by 2)
        assert flat["embed"] == P("model", "data")
        # column-parallel QKV on the stacked layer axis
        assert flat["layers/attn/wq"] == P(None, "data", "model")
        assert flat["layers/attn/wo"] == P(None, "model", "data")
        assert flat["layers/mlp/w_down"] == P(None, "model", "data")
        # norms replicated
        assert flat["layers/ln1/scale"] == P()

    def test_divisibility_fallback(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        cfg = dataclasses.replace(cfg, vocab_size=255)   # prime-ish
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        specs = param_pspecs(cfg, mesh22, shape)
        flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
                for path, s in jax.tree_util.tree_flatten_with_path(specs)[0]}
        assert flat["embed"][0] is None     # 255 % 2 != 0 -> dropped axis

    def test_opt_state_mirrors_params(self, mesh22, smollm):
        from repro.optim import AdamWConfig, adamw_init
        cfg, params = smollm
        pspecs = param_pspecs(cfg, mesh22, params)
        opt = jax.eval_shape(
            functools.partial(adamw_init, cfg=AdamWConfig()), params)
        ospecs = opt_pspecs(cfg, mesh22, opt, pspecs)
        assert ospecs["mu"]["embed"] == pspecs["embed"]
        assert ospecs["master"]["layers"]["attn"]["wq"] == \
            pspecs["layers"]["attn"]["wq"]
        assert ospecs["step"] == P()

    def test_cache_specs(self, mesh22):
        from repro.models.decode import init_cache
        cfg = get_config("smollm-360m").reduced()
        shape = jax.eval_shape(functools.partial(init_cache, cfg, 4, 32))
        specs = cache_pspecs(cfg, mesh22, shape)
        assert specs["k"] == P(None, "data", None, "model", None)
        assert specs["pos"] == P()

    def test_batch_specs(self, mesh22):
        b = batch_specs(16, 8, 100)
        specs = batch_pspecs(mesh22, b)
        assert specs["tokens"] == P("data", None)

    def test_multipod_axes(self):
        ax = MeshAxes(data=("pod", "data"))
        assert ax.model == "model"


class TestChunkedCE:
    def test_matches_full_loss(self, smollm):
        cfg, params = smollm
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        full, m_full = loss_fn(cfg, params, batch)
        for chunk in (4, 5, 16, 64):
            got, m = chunked_ce_loss(cfg, params, batch, seq_chunk=chunk)
            np.testing.assert_allclose(float(got), float(full),
                                       rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(float(m["ce"]), float(m_full["ce"]),
                                       rtol=1e-5, atol=1e-6)

    def test_masked_labels_ignored(self, smollm):
        cfg, params = smollm
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab_size)
        labels = jnp.full_like(toks, -1).at[:, :4].set(toks[:, :4])
        loss, m = chunked_ce_loss(cfg, params,
                                  {"tokens": toks, "labels": labels},
                                  seq_chunk=8)
        assert float(m["tokens"]) == 8.0
        assert np.isfinite(float(loss))


class TestTrainStep:
    def _bundle(self, mesh, cfg, m=1, gb=8, s=16):
        scfg = StepConfig(microbatches=m, seq_chunk=8, warmup_steps=2,
                          total_steps=20, peak_lr=1e-3)
        bshape = batch_specs(s, gb, cfg.vocab_size)
        return build_train_step(cfg, mesh, scfg, bshape), scfg

    def test_loss_decreases(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        bundle, scfg = self._bundle(mesh22, cfg)
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, opt = init_fn(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        losses = []
        for step in range(8):
            params, opt, metrics = bundle.fn(params, opt,
                                             data.global_batch(step),
                                             jnp.int32(step))
            losses.append(float(metrics["loss"]))
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0]

    def test_microbatch_equivalence(self, mesh22):
        """m=1 and m=4 must produce the same update (grad averaging)."""
        cfg = get_config("smollm-360m").reduced()
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        batch = data.global_batch(0)
        outs = []
        for m in (1, 4):
            bundle, scfg = self._bundle(mesh22, cfg, m=m)
            init_fn, _ = build_init(cfg, mesh22, scfg)
            params, opt = init_fn(jax.random.PRNGKey(0))
            p2, o2, metrics = bundle.fn(params, opt, batch, jnp.int32(0))
            outs.append((p2, float(metrics["loss"])))
        l1, l4 = outs[0][1], outs[1][1]
        np.testing.assert_allclose(l1, l4, rtol=1e-5)
        p1 = jax.tree.leaves(outs[0][0])
        p4 = jax.tree.leaves(outs[1][0])
        for a, b in zip(p1, p4):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-5)

    def test_moe_train_step(self, mesh22):
        cfg = get_config("grok-1-314b").reduced()
        bundle, scfg = self._bundle(mesh22, cfg, m=2)
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, opt = init_fn(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        params, opt, metrics = bundle.fn(params, opt, data.global_batch(0),
                                         jnp.int32(0))
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["moe_aux"]) > 0


class TestServePrefill:
    def test_serve_step_runs_sharded(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig()
        bundle = build_serve_step(cfg, mesh22, scfg, batch=4, max_seq=32)
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        from repro.dist.sharding import to_shardings
        from repro.models.decode import init_cache
        csh = to_shardings(mesh22, bundle.in_specs[1])
        cache = jax.jit(lambda: init_cache(cfg, 4, 32),
                        out_shardings=csh)()
        toks = jnp.zeros((4,), jnp.int32)
        for _ in range(3):
            cache, logits = bundle.fn(params, cache, toks)
        assert np.asarray(cache["pos"]).tolist() == [3] * 4
        assert logits.shape == (4, cfg.vocab_size)

    def test_prefill_step_matches_unsharded(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig()
        bundle = build_prefill_step(cfg, mesh22, scfg, batch=4, seq_len=16)
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                                  cfg.vocab_size)
        cache, logits = bundle.fn(params, toks)
        from repro.models.prefill import prefill
        params_local = jax.device_get(params)
        cache_ref, logits_ref = prefill(cfg, params_local, toks,
                                        cache_len=16)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_ref),
                                   rtol=2e-4, atol=2e-4)


class TestArtTP:
    """The paper's technique as a training feature: ART ring schedules for
    TP collectives must be numerically identical to the GSPMD baseline and
    structurally all-reduce-free at the layer level."""

    def test_art_tp_matches_baseline(self, mesh22):
        cfg = get_config("nemotron-4-340b").reduced()
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        batch = data.global_batch(0)
        bshape = batch_specs(16, 8, cfg.vocab_size)
        outs = {}
        for art in (False, True):
            scfg = StepConfig(microbatches=2, seq_chunk=8, art_tp=art,
                              warmup_steps=2, total_steps=10)
            bundle = build_train_step(cfg, mesh22, scfg, bshape)
            init_fn, _ = build_init(cfg, mesh22, scfg)
            params, opt = init_fn(jax.random.PRNGKey(0))
            _, _, m = bundle.fn(params, opt, batch, jnp.int32(0))
            outs[art] = (float(m["loss"]), float(m["grad_norm"]))
        np.testing.assert_allclose(outs[False][0], outs[True][0], rtol=1e-4)
        np.testing.assert_allclose(outs[False][1], outs[True][1], rtol=2e-3)

    def test_art_layer_eliminates_all_reduce(self):
        from benchmarks.artlayer import LayerDims, compare
        d = LayerDims(d_model=256, n_heads=8, n_kv=4, head_dim=32,
                      d_ff=512, seq=128, batch=1)
        out = compare(d)
        assert out["art"]["by_op"].get("all-reduce", 0) == 0
        assert out["gspmd"]["by_op"].get("all-reduce", 0) > 0


class TestCrossPodGradSync:
    """Compressed cross-pod gradient sync: correctness + int8 wire."""

    @pytest.fixture(scope="class")
    def podmesh(self):
        return jax.make_mesh((2, 2), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def test_uncompressed_matches_mean(self, podmesh):
        from repro.dist.grad_sync import cross_pod_all_reduce
        g = {"w": jnp.arange(8.0).reshape(2, 4)}
        gs = jax.device_put(g["w"], jax.sharding.NamedSharding(
            podmesh, P("pod", None)))
        out, _ = cross_pod_all_reduce({"w": gs}, podmesh)
        want = (np.asarray(g["w"][:1]) + np.asarray(g["w"][1:])) / 2
        got = np.asarray(out["w"])
        np.testing.assert_allclose(got[0], want[0])
        np.testing.assert_allclose(got[1], want[0])

    def test_compressed_close_and_ef_tracks(self, podmesh):
        from repro.dist.grad_sync import cross_pod_all_reduce
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (2, 256))
        gs = jax.device_put(g, jax.sharding.NamedSharding(
            podmesh, P("pod", None)))
        out, ef = cross_pod_all_reduce({"w": gs}, podmesh, compressed=True)
        want = np.broadcast_to(np.asarray(g).mean(0, keepdims=True), (2, 256))
        got = np.asarray(out["w"])
        err = np.abs(got - want).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err <= 2 * scale + 1e-6, (err, scale)
        assert np.abs(np.asarray(ef["w"])).max() <= scale + 1e-6

    def test_int8_on_the_wire(self, podmesh):
        from repro.dist.grad_sync import cross_pod_all_reduce
        g = jnp.zeros((2, 512))
        gs = jax.device_put(g, jax.sharding.NamedSharding(
            podmesh, P("pod", None)))
        lowered = jax.jit(lambda t: cross_pod_all_reduce(
            {"w": t}, podmesh, compressed=True)[0]).lower(gs)
        txt = lowered.compile().as_text()
        assert "s8[" in txt, "compressed sync must move int8 payloads"

    def test_wire_bytes_saving(self):
        from repro.dist.grad_sync import wire_bytes
        n = 1 << 20
        ratio = wire_bytes(n, compressed=False) / wire_bytes(n, compressed=True)
        assert ratio > 3.8
