"""PGAS semantics: symmetric heap, one-sided put/get, addressing."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from hypothesis import given, settings, strategies as st

from repro.core import pgas


def _heap_gas(mesh, size=64):
    heap = pgas.SymmetricHeap(size)
    return heap, pgas.GlobalAddressSpace(mesh, "x", heap)


class TestSymmetricHeap:
    def test_alloc_layout(self):
        h = pgas.SymmetricHeap(32)
        a = h.alloc("a", 8)
        b = h.alloc("b", 16)
        assert (a.offset, a.size) == (0, 8)
        assert (b.offset, b.size) == (8, 16)
        assert h.addr("b") == 8

    def test_overflow(self):
        h = pgas.SymmetricHeap(8)
        h.alloc("a", 8)
        with pytest.raises(MemoryError):
            h.alloc("b", 1)

    def test_duplicate(self):
        h = pgas.SymmetricHeap(8)
        h.alloc("a", 4)
        with pytest.raises(ValueError):
            h.alloc("a", 2)


class TestPut:
    def test_single_pair(self, mesh4):
        heap, gas = _heap_gas(mesh4)
        g = gas.zeros_global()

        def f(h):
            payload = jnp.arange(8, dtype=jnp.float32) + 1
            return pgas.put(h, payload, 5, axis="x", perm=[(0, 2)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        np.testing.assert_allclose(out[2, 5:13], np.arange(8) + 1)
        assert np.all(out[1] == 0) and np.all(out[3] == 0)
        # one-sided: rank 0 (the sender) does not see its own write
        assert np.all(out[0] == 0)

    @given(shift=st.integers(1, 3), offset=st.integers(0, 48))
    @settings(max_examples=10, deadline=None)
    def test_ring_every_rank_receives(self, shift, offset):
        import jax
        mesh = jax.make_mesh((4,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        heap, gas = _heap_gas(mesh)
        g = gas.zeros_global()

        def f(h):
            my = jax.lax.axis_index("x").astype(jnp.float32)
            payload = jnp.full((16,), my)
            return pgas.put_ring(h, payload, offset, axis="x", shift=shift)

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        for r in range(4):
            src = (r - shift) % 4
            np.testing.assert_allclose(out[r, offset:offset + 16], src)

    def test_traced_offset(self, mesh4):
        """The destination offset is message data (AM header), not static."""
        heap, gas = _heap_gas(mesh4)
        g = gas.zeros_global()

        def f(h):
            my = jax.lax.axis_index("x")
            payload = jnp.ones((4,), jnp.float32)
            return pgas.put(h, payload, my * 4, axis="x",
                            perm=[(i, (i + 1) % 4) for i in range(4)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        for r in range(4):
            src = (r - 1) % 4
            np.testing.assert_allclose(out[r, src * 4: src * 4 + 4], 1.0)


class TestBlockSegment:
    def test_address_translation(self):
        h = pgas.SymmetricHeap(64)
        h.alloc("pad", 8)
        h.alloc("pool", 48)
        mesh = jax.make_mesh((4,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        gas = pgas.GlobalAddressSpace(mesh, "x", h)
        seg = gas.block_segment("pool", 12)
        assert seg.blocks_per_rank == 4 and seg.n_blocks == 16
        # owner-major striping: block 9 -> rank 2, local index 1
        assert seg.addr(9) == (2, 8 + 1 * 12)
        assert seg.owner(0) == 0 and seg.owner(15) == 3
        # traced ids translate too (two integer ops, jit-composable)
        off = seg.local_offset(jnp.asarray([0, 5, 9], jnp.int32))
        np.testing.assert_array_equal(np.asarray(off), [8, 8 + 12, 8 + 12])

    def test_indivisible_rejected(self):
        h = pgas.SymmetricHeap(64)
        h.alloc("pool", 48)
        mesh = jax.make_mesh((4,), ("x",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        gas = pgas.GlobalAddressSpace(mesh, "x", h)
        with pytest.raises(ValueError):
            gas.block_segment("pool", 7)

    def test_write_block_one_sided(self, mesh4):
        """write_block routes a traced global block id to the owner's
        local offset — the sender resolves the address, not the receiver."""
        h = pgas.SymmetricHeap(64)
        h.alloc("pool", 64)
        gas = pgas.GlobalAddressSpace(mesh4, "x", h)
        g = gas.zeros_global()
        seg = gas.block_segment("pool", 8)          # 8 blocks/rank, 32 global
        w = gas.write_block("pool", 8, perm=[(0, 2)])
        payload = jnp.arange(8, dtype=jnp.float32) + 1
        bid = 2 * seg.blocks_per_rank + 3           # rank 2 owns it, index 3
        out = np.asarray(w(g, jnp.tile(payload, 4), bid)).reshape(4, 64)
        np.testing.assert_allclose(out[2, 3 * 8: 4 * 8], np.arange(8) + 1)
        assert np.all(out[0] == 0) and np.all(out[1] == 0)
        assert np.all(out[3] == 0)


class TestGet:
    def test_remote_read(self, mesh4):
        heap, gas = _heap_gas(mesh4)
        g = gas.zeros_global()

        def f(h):
            my = jax.lax.axis_index("x").astype(jnp.float32)
            h = h.at[:8].set(my * 10 + jnp.arange(8.0))
            chunk = pgas.get(h, 0, 8, axis="x",
                             perm=[(i, (i + 1) % 4) for i in range(4)])
            return h, chunk

        _, chunks = gas.run(f, extra_out_specs=P("x"))(g)
        c = np.asarray(chunks).reshape(4, 8)
        for r in range(4):
            src = (r + 1) % 4
            np.testing.assert_allclose(c[r], src * 10 + np.arange(8))

    def test_get_nonparticipant_zero(self, mesh4):
        heap, gas = _heap_gas(mesh4)
        g = gas.zeros_global()

        def f(h):
            h = h.at[:4].set(7.0)
            chunk = pgas.get(h, 0, 4, axis="x", perm=[(0, 1)])
            return h, chunk

        _, chunks = gas.run(f, extra_out_specs=P("x"))(g)
        c = np.asarray(chunks).reshape(4, 4)
        np.testing.assert_allclose(c[0], 7.0)       # requester got data
        assert np.all(c[1:] == 0)                   # others untouched
