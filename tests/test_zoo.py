"""Cross-arch serving conformance: the chunk-carry contract (PR 8).

Every arch in the registry streams its prefill — the carry is typed per
family (ring K/V rows, MLA latent rows, constant-size SSD state, the
hybrid pair, encoder-once + decoder chunks) — and chunked ≡ bulk is
asserted *bitwise* at the model layer and token-exact end-to-end on a
real :class:`Server`, for every ``get_config`` name.  MoE rides the ring
carry under the chunk-local capacity bound
(:func:`repro.models.prefill.moe_chunk_agree_mask`): exact when no row
overflows either program (the identity runs assert it at
``capacity_factor >= n_experts``), and the bound's contrapositive is
asserted too — a tight capacity makes the keep decisions disagree and
the mask names the rows.

Also here: the ``prefill_chunk_cuts`` tiling property (both spellings,
carry multiples, ragged tails), and the no-silent-fallback regression —
requesting chunked admission on an arch the gate rejects warns once at
build time with the reason and surfaces ``bulk`` in ``stats()``.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import chunk_carry_spec, serving_features
from repro.models.decode import decode_step, supports_paged
from repro.models.model import init_params
from repro.models.prefill import (
    chunk_support,
    init_prefill_scratch,
    moe_chunk_agree_mask,
    prefill,
    prefill_chunk_cuts,
    prefill_chunked,
)
from repro.runtime.server import Server, ServerConfig

@pytest.fixture(scope="module", autouse=True)
def _fresh_jax_caches():
    """The zoo sweep compiles every arch's programs on top of whatever
    the rest of the suite already compiled in this process; dropping the
    accumulated executables first keeps the long single-process tier-1
    run stable (observed XLA CPU segfaults in backend_compile without
    this, never when the module runs alone)."""
    import gc

    jax.clear_caches()
    gc.collect()
    yield
    _PARAMS.clear()
    jax.clear_caches()
    gc.collect()


ZOO = list(ARCH_NAMES)
MOE_ARCHS = tuple(n for n in ZOO if get_config(n).family == "moe")
STATE_ARCHS = tuple(n for n in ZOO
                    if chunk_carry_spec(get_config(n).reduced()).kind
                    in ("state", "hybrid"))
PAGED_ARCHS = tuple(n for n in ZOO
                    if supports_paged(get_config(n).reduced()))

#: MoE identity runs pin capacity_factor >= n_experts so no row overflows
#: in either the bulk or the chunk-local program — the exactness condition
#: of moe_chunk_agree_mask's bound.
_NO_OVERFLOW = {"capacity_factor": 8.0}

_PARAMS = {}


def _zoo_cfg(arch):
    cfg = get_config(arch).reduced()
    if arch in MOE_ARCHS:
        cfg = dataclasses.replace(cfg, **_NO_OVERFLOW)
    return cfg


def _setup(arch):
    """(cfg, params), cached module-wide — the zoo sweep re-enters per
    test and param init dominates otherwise."""
    if arch not in _PARAMS:
        cfg = _zoo_cfg(arch)
        _PARAMS[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _PARAMS[arch]


def _tokens(cfg, b, s, key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)


def _frontend(cfg, b=None, key=2):
    if not cfg.frontend:
        return None
    shape = (cfg.frontend_tokens, cfg.frontend_dim)
    if b is not None:
        shape = (b,) + shape
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _assert_tree_equal(a, b, msg=""):
    assert set(a) == set(b), f"{msg}: leaf sets differ"
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{msg} leaf {k!r}")


_MESH = None


def _mesh():
    global _MESH
    if _MESH is None:
        from repro.launch.mesh import make_host_mesh
        _MESH = make_host_mesh(1, 1)
    return _MESH


def _server_params(arch):
    """Params jitted onto the serving mesh (cached)."""
    key = (arch, "srv")
    if key not in _PARAMS:
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = _zoo_cfg(arch)
        mesh = _mesh()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        _PARAMS[key] = (cfg, params)
    return _PARAMS[key]


class TestZooModelConformance:
    """prefill_chunked ≡ prefill, bit for bit, for every registry arch —
    cache leaves, logits, and the decode step that follows."""

    @pytest.mark.parametrize("arch", ZOO)
    def test_chunked_bit_identical_and_split_invariant(self, arch):
        cfg, params = _setup(arch)
        assert chunk_support(cfg)[0], chunk_support(cfg)[1]
        b, s = 2, 13
        toks = _tokens(cfg, b, s)
        fe = _frontend(cfg, b)
        cl = 32
        bulk_cache, bulk_logits = prefill(cfg, params, toks, fe,
                                          cache_len=cl)
        for kw in ({"n_chunks": 2}, {"n_chunks": 3}, {"chunk_len": 5}):
            cache, logits = prefill_chunked(cfg, params, toks, fe,
                                            cache_len=cl, **kw)
            _assert_tree_equal(bulk_cache, cache, f"{arch} {kw}")
            np.testing.assert_array_equal(np.asarray(bulk_logits),
                                          np.asarray(logits),
                                          err_msg=f"{arch} {kw}")

    @pytest.mark.parametrize("arch", ZOO)
    def test_decode_continues_identically(self, arch):
        cfg, params = _setup(arch)
        toks = _tokens(cfg, 1, 9)
        fe = _frontend(cfg, 1)
        ca, la = prefill(cfg, params, toks, fe, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, fe, cache_len=16,
                                 n_chunks=3)
        nxt = jnp.argmax(la, -1).astype(jnp.int32)
        ca, la2 = decode_step(cfg, params, ca, nxt)
        cb, lb2 = decode_step(cfg, params, cb, nxt)
        np.testing.assert_array_equal(np.asarray(la2), np.asarray(lb2),
                                      err_msg=arch)

    @pytest.mark.parametrize("arch", STATE_ARCHS)
    def test_ssm_carry_is_constant_size(self, arch):
        """The streamed-SSM selling point: the carry (SSD state + conv
        tail) does not grow with the prompt."""
        cfg = _zoo_cfg(arch)
        short = jax.eval_shape(lambda: init_prefill_scratch(cfg, 1, 16))
        long = jax.eval_shape(lambda: init_prefill_scratch(cfg, 1, 256))
        for k in ("ssm_state", "conv_state"):
            assert short[k].shape == long[k].shape, (arch, k)

    @settings(max_examples=6, deadline=None)
    @given(s=st.integers(2, 24), n=st.integers(2, 6),
           arch=st.sampled_from(("mamba2-2.7b", "h2o-danube-1.8b",
                                 "whisper-tiny")))
    def test_drawn_lengths_and_cuts(self, s, n, arch):
        """Hypothesis sweep over the tricky carries: SSD multiple
        snapping (mamba2), SWA ring wraparound (danube window < s), the
        capped whisper decoder."""
        cfg, params = _setup(arch)
        if cfg.family == "encdec":
            s = min(s, cfg.decoder_max_seq)
        toks = _tokens(cfg, 1, s, key=50 + s)
        fe = _frontend(cfg, 1)
        ca, la = prefill(cfg, params, toks, fe, cache_len=s)
        cb, lb = prefill_chunked(cfg, params, toks, fe, cache_len=s,
                                 n_chunks=n)
        _assert_tree_equal(ca, cb, f"{arch} s={s} n={n}")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestChunkCutsProperty:
    """prefill_chunk_cuts tiles [0, s_total) exactly once — both
    spellings, ragged tails, carry multiples, s_total < chunk_len."""

    @staticmethod
    def _assert_tiling(cuts, s, multiple):
        assert cuts[0][0] == 0 and cuts[-1][1] == s
        assert all(a[1] == b[0] for a, b in zip(cuts, cuts[1:]))
        assert all(lo < hi for lo, hi in cuts)
        covered = [p for lo, hi in cuts for p in range(lo, hi)]
        assert covered == list(range(s))
        # every interior boundary lands on the carry multiple
        for _, hi in cuts[:-1]:
            assert hi % multiple == 0

    @settings(max_examples=40, deadline=None)
    @given(s=st.integers(1, 64), c=st.integers(1, 80),
           m=st.sampled_from((1, 4, 8)))
    def test_chunk_len_spelling(self, s, c, m):
        cuts = prefill_chunk_cuts(s, chunk_len=c, multiple=m)
        self._assert_tiling(cuts, s, m)
        if c >= s:
            assert cuts == [(0, s)]

    @settings(max_examples=40, deadline=None)
    @given(s=st.integers(1, 64), n=st.integers(1, 9),
           m=st.sampled_from((1, 4, 8)))
    def test_n_chunks_spelling(self, s, n, m):
        cuts = prefill_chunk_cuts(s, n_chunks=n, multiple=m)
        self._assert_tiling(cuts, s, m)
        assert len(cuts) <= n


class TestZooServing:
    """Every arch serves end-to-end on a real Server: chunked admission
    produces exactly the bulk tokens, and paged exactly the contiguous
    ones where the arch pages."""

    def _serve(self, arch, *, prefill_chunk, paged=False, n_req=2,
               max_new=4):
        cfg, params = _server_params(arch)
        srv = Server(cfg, params, _mesh(), srv=ServerConfig(
            max_batch=2, max_seq=32, max_new_tokens=max_new,
            prefill_chunk=prefill_chunk, paged=paged, block_size=8))
        rng = np.random.default_rng(7)
        for i in range(n_req):
            plen = (11, 7)[i % 2]
            if cfg.family == "encdec":
                plen = min(plen, cfg.decoder_max_seq - max_new)
            prompt = rng.integers(0, cfg.vocab_size, size=plen)
            fe = (rng.standard_normal((cfg.frontend_tokens,
                                       cfg.frontend_dim),
                                      dtype=np.float32)
                  if cfg.frontend else None)
            srv.submit(prompt, frontend_embeds=fe)
        srv.run()
        assert len(srv.done) == n_req
        return {r.rid: r.out_tokens for r in srv.done}, srv.stats()

    @pytest.mark.parametrize("arch", ZOO)
    def test_chunked_tokens_equal_bulk(self, arch):
        chunked, stc = self._serve(arch, prefill_chunk=4)
        bulk, stb = self._serve(arch, prefill_chunk=None)
        assert chunked == bulk, arch
        assert str(stc["admission_mode"]).startswith("chunked("), arch
        assert stc["admission_fallback"] == ""
        assert stb["admission_mode"] == "bulk"

    @pytest.mark.parametrize("arch", PAGED_ARCHS)
    def test_paged_tokens_equal_contiguous(self, arch):
        paged, stp = self._serve(arch, prefill_chunk=4, paged=True)
        cont, _ = self._serve(arch, prefill_chunk=4, paged=False)
        assert paged == cont, arch

    def test_eff_chunk_rounds_to_carry_multiple(self):
        """A requested chunk below the SSD multiple admits at the rounded
        size (cuts must land on ssm_chunk boundaries for the state
        hand-off to be exact) — and stats says so."""
        _, stats = self._serve("mamba2-2.7b", prefill_chunk=4, n_req=1)
        mult = chunk_carry_spec(_zoo_cfg("mamba2-2.7b")).chunk_multiple
        assert stats["admission_mode"] == f"chunked({mult})"


class TestAdmissionFallback:
    """No silent bulk fallback: requesting chunked admission on a gated
    arch warns once at build time naming arch + reason, and the mode is
    queryable from stats()."""

    def _pallas_server(self):
        cfg, params = _server_params("smollm-360m")
        cfg = dataclasses.replace(cfg, attn_impl="pallas")
        return cfg, params

    def test_gated_arch_warns_with_reason(self):
        cfg, params = self._pallas_server()
        with pytest.warns(UserWarning, match="smollm-360m.*pallas"):
            srv = Server(cfg, params, _mesh(), srv=ServerConfig(
                max_batch=2, max_seq=32, max_new_tokens=2,
                prefill_chunk=4))
        stats = srv.stats()
        assert stats["admission_mode"] == "bulk"
        assert "pallas" in str(stats["admission_fallback"])

    def test_bulk_request_does_not_warn(self):
        """prefill_chunk=None is an explicit bulk ask — no warning, and
        the fallback reason says disabled-not-unsupported."""
        cfg, params = self._pallas_server()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = Server(cfg, params, _mesh(), srv=ServerConfig(
                max_batch=2, max_seq=32, max_new_tokens=2,
                prefill_chunk=None))
        assert not [w for w in rec if "chunked prefill" in str(w.message)]
        assert srv.stats()["admission_mode"] == "bulk"
        assert srv.stats()["admission_fallback"] == "prefill_chunk disabled"

    def test_supported_arch_does_not_warn(self):
        cfg, params = _server_params("smollm-360m")
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            srv = Server(cfg, params, _mesh(), srv=ServerConfig(
                max_batch=2, max_seq=32, max_new_tokens=2,
                prefill_chunk=4))
        assert not [w for w in rec if "chunked prefill" in str(w.message)]
        assert srv.stats()["admission_mode"] == "chunked(4)"


class TestMoEChunkBound:
    """Both directions of the chunk-local capacity bound."""

    def test_no_overflow_keeps_agree_and_exact(self):
        """capacity_factor >= n_experts: keep decisions agree everywhere
        (the identity precondition the conformance runs rely on)."""
        cfg, params = _setup("grok-1-314b")
        from repro.models.model import _embed
        toks = _tokens(cfg, 2, 13)
        x = _embed(cfg, params, toks, None)
        moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
        cuts = prefill_chunk_cuts(13, n_chunks=3)
        agree, _, _ = moe_chunk_agree_mask(cfg, moe_p, x, cuts)
        assert bool(jnp.all(agree))

    def test_tight_capacity_disagrees_and_mask_names_rows(self):
        """Contrapositive: a tight capacity makes chunk-local drop sets
        differ from bulk, the mask reports the rows, and serving_features
        already declared the arch chunked-but-inexact."""
        cfg = get_config("grok-1-314b").reduced()
        cfg = dataclasses.replace(cfg, capacity_factor=0.25)
        params = init_params(cfg, jax.random.PRNGKey(0))
        from repro.models.model import _embed
        toks = _tokens(cfg, 2, 16, key=3)
        x = _embed(cfg, params, toks, None)
        moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
        agree, keep_bulk, keep_chunk = moe_chunk_agree_mask(
            cfg, moe_p, x, prefill_chunk_cuts(16, n_chunks=4))
        assert not bool(jnp.all(agree)), \
            "tight capacity should make bulk and chunk-local drops differ"
        assert keep_bulk.shape == keep_chunk.shape
        assert not serving_features(cfg)["chunked_exact"]


class TestCapabilityTable:
    """The jax-free capability table is total and self-consistent."""

    @pytest.mark.parametrize("arch", ZOO)
    def test_spec_total_and_consistent(self, arch):
        cfg = get_config(arch).reduced()
        spec = chunk_carry_spec(cfg)
        feats = serving_features(cfg)
        assert spec.kind in ("ring", "latent", "state", "hybrid", "encdec")
        assert feats["chunked"]
        assert feats["chunked_exact"] == spec.exact
        assert spec.constant_size == (spec.kind == "state")
        if spec.kind in ("state", "hybrid"):
            assert spec.chunk_multiple == cfg.ssm_chunk
        assert supports_paged(cfg) == feats["paged"]
        if feats["prefix_cache"]:
            assert feats["paged"]
