"""Live failure detection and membership epochs.

The detector (``runtime/membership.MembershipService``) declares rank
death from missed heartbeat leases — ``FaultPlan`` only *suppresses*
victims' leases (``deliver="lease"``), it never raises the kill itself —
and every membership change is a versioned epoch that conduit/AM handles
carry and check.  The suite covers the detector's deterministic
arithmetic, the epoch plumbing (``StaleEpoch`` on every collective and
AM delivery built against a stale view), the hypothesis invariants over
random churn interleavings, the on-wire heartbeat segment against the
host mirror, and the end-to-end acceptance churn: a paged serve run that
loses two decode ranks in one lease window (exactly one epoch bump) and
later re-admits a joiner — token-identical to an unfailed run.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import conduit, pgas
from repro.core.conduit import StaleEpoch
from repro.dist.sharding import param_pspecs, to_shardings
from repro.models.model import init_params
from repro.runtime.faults import FaultPlan, RankFailure
from repro.runtime.membership import (LeaseConfig, MembershipService,
                                      build_heartbeat_wire)
from repro.runtime.server import Server, ServerConfig


def _run_to(svc, last_step):
    """Drive the detector to ``last_step``; returns every event raised."""
    evs = []
    for s in range(last_step + 1):
        ev = svc.on_step(s)
        if ev is not None:
            evs.append(ev)
    return evs


# ---------------------------------------------------------------------------
# detector semantics
# ---------------------------------------------------------------------------


class TestLeaseConfig:
    def test_validation(self):
        with pytest.raises(ValueError):
            LeaseConfig(lease_period=0)
        with pytest.raises(ValueError):
            LeaseConfig(k_misses=0)
        with pytest.raises(ValueError):
            LeaseConfig(step_time_s=0.0)

    def test_raise_mode_plan_rejected(self):
        # a raise-mode plan would deliver kills itself — the detector
        # must be the only declaring authority
        with pytest.raises(ValueError):
            MembershipService(4, fault_plan=FaultPlan())


class TestDetector:
    def test_healthy_ranks_never_declared(self):
        svc = MembershipService(4, LeaseConfig(lease_period=2, k_misses=3))
        assert _run_to(svc, 40) == []
        assert svc.epoch == 0 and svc.view().ranks == (0, 1, 2, 3)

    def test_kill_detected_within_bound(self):
        p, k, kill_at = 2, 3, 5
        plan = FaultPlan(deliver="lease").kill_rank(1, at_step=kill_at)
        svc = MembershipService(4, LeaseConfig(lease_period=p, k_misses=k),
                                fault_plan=plan)
        evs = _run_to(svc, 40)
        assert len(evs) == 1 and evs[0].died == (1,)
        # detection strictly inside the lease_period x (K+1) bound
        assert evs[0].step - kill_at < p * (k + 1)
        assert svc.epoch == 1 and not svc.alive(1)

    def test_double_loss_one_epoch_bump(self):
        plan = (FaultPlan(deliver="lease")
                .kill_rank(1, at_step=5).kill_rank(3, at_step=5))
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=2),
                                fault_plan=plan)
        evs = _run_to(svc, 30)
        assert len(evs) == 1                   # ONE view change, not two
        assert evs[0].died == (1, 3) and svc.epoch == 1
        assert svc.view().ranks == (0, 2)

    def test_pacing_independence(self):
        """Jumping the clock in one call equals stepping one-by-one."""
        def mk():
            plan = (FaultPlan(deliver="lease")
                    .kill_rank(2, at_step=4).miss_lease(0, at_step=9,
                                                        count=1))
            return MembershipService(4, LeaseConfig(lease_period=2,
                                                    k_misses=2),
                                     fault_plan=plan)
        paced = mk()
        evs_paced = _run_to(paced, 25)
        jumped = mk()
        ev = jumped.on_step(25)               # one call, same clock
        assert ev == evs_paced[-1]
        assert jumped.epoch == paced.epoch
        assert jumped.view() == paced.view()

    def test_transient_misses_below_k_tolerated(self):
        plan = FaultPlan(deliver="lease").miss_lease(1, at_step=6, count=2)
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=3),
                                fault_plan=plan)
        assert _run_to(svc, 30) == []          # 2 misses < K=3: no declare
        assert svc.alive(1)

    def test_am_delay_burst_no_false_positive(self):
        # a 2-period delay burst lags every arrival; misses stay < K
        cfg = LeaseConfig(lease_period=1, k_misses=3, step_time_s=1e-3)
        plan = FaultPlan(deliver="lease").delay_am(2e-3, at_step=4)
        svc = MembershipService(4, cfg, fault_plan=plan)
        assert _run_to(svc, 40) == []
        assert svc.epoch == 0

    def test_join_admitted_at_boundary(self):
        svc = MembershipService(3, LeaseConfig(lease_period=2, k_misses=2))
        svc.schedule_join(3, at_step=7)
        evs = _run_to(svc, 20)
        assert len(evs) == 1 and evs[0].joined == (3,)
        assert evs[0].step >= 7               # never before the announce
        assert svc.view().ranks == (0, 1, 2, 3) and svc.alive(3)

    def test_victim_rejoins_after_repair(self):
        plan = FaultPlan(deliver="lease").kill_rank(2, at_step=3)
        svc = MembershipService(3, LeaseConfig(lease_period=1, k_misses=2),
                                fault_plan=plan)
        evs = _run_to(svc, 10)
        assert evs[-1].died == (2,)
        svc.schedule_join(2, at_step=12)
        evs = _run_to(svc, 30)
        assert evs[-1].joined == (2,)
        # declaration repaired the plan, so the rejoined rank's leases
        # publish again and it stays a member
        assert svc.alive(2) and svc.epoch == 2

    def test_failure_for_carries_batch(self):
        plan = (FaultPlan(deliver="lease")
                .kill_rank(1, at_step=2).kill_rank(2, at_step=2))
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=2),
                                fault_plan=plan)
        ev = _run_to(svc, 10)[0]
        failure = svc.failure_for(ev)
        assert isinstance(failure, RankFailure)
        assert failure.ranks == (1, 2) and failure.rank == 1


# ---------------------------------------------------------------------------
# epoch plumbing (StaleEpoch on stale handles)
# ---------------------------------------------------------------------------


class TestEpochs:
    def test_check_epoch_without_provider_is_noop(self):
        conduit.clear_epoch_provider()
        conduit.check_epoch("all_reduce", 7)   # no provider: opt-out
        assert conduit.current_epoch() is None

    def test_stale_epoch_typed(self):
        conduit.install_epoch_provider(lambda: 3)
        try:
            conduit.check_epoch("all_reduce", 3)   # current: fine
            with pytest.raises(StaleEpoch) as ei:
                conduit.check_epoch("all_reduce", 2)
            assert ei.value.built == 2 and ei.value.current == 3
            assert isinstance(ei.value, RankFailure)
        finally:
            conduit.clear_epoch_provider()

    def test_bound_conduit_raises_after_bump(self, mesh4):
        plan = FaultPlan(deliver="lease").kill_rank(1, at_step=3)
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=2),
                                fault_plan=plan)
        x = np.ones((8, 4), np.float32)
        with svc:
            cd = svc.bind(conduit.Conduit("x", "xla"))
            assert cd.epoch == 0
            jax.shard_map(lambda v: cd.all_gather(v), mesh=mesh4,
                          in_specs=P("x"), out_specs=P("x"))(x)
            _run_to(svc, 12)
            assert svc.epoch == 1
            with pytest.raises(StaleEpoch):
                jax.shard_map(lambda v: cd.all_gather(v), mesh=mesh4,
                              in_specs=P("x"), out_specs=P("x"))(x)
            # a re-bound handle is current again
            cd2 = svc.bind(conduit.Conduit("x", "xla"))
            jax.shard_map(lambda v: cd2.all_gather(v), mesh=mesh4,
                          in_specs=P("x"), out_specs=P("x"))(x)

    def test_retrying_conduit_never_retries_stale(self):
        """StaleEpoch passes straight through the retry loop: retrying a
        collective built against a dead view can never succeed."""
        calls = []
        conduit.install_epoch_provider(lambda: 1)
        try:
            rc = conduit.Conduit("x", "xla", epoch=0).with_retry(attempts=5)

            def op(*a, **k):
                calls.append(1)
                conduit.check_epoch("all_gather", 0)
            with pytest.raises(StaleEpoch):
                rc._attempt(op)
            assert len(calls) == 1             # no second attempt
        finally:
            conduit.clear_epoch_provider()

    def test_am_delivery_checks_epoch(self, mesh4):
        import jax.numpy as jnp

        from repro.core.am import (MAX_ARGS, HandlerRegistry,
                                   am_request_short, make_args)

        heap = pgas.SymmetricHeap(16)
        gas = pgas.GlobalAddressSpace(mesh4, "x", heap)
        seg = heap.alloc("slot", 1)
        reg = HandlerRegistry()

        def _h(heap_local, args, payload):
            return (heap_local, jnp.int32(0),
                    jnp.zeros((MAX_ARGS,), jnp.int32),
                    jnp.zeros_like(payload))

        opcode = reg.register_request("poke", _h)
        conduit.install_epoch_provider(lambda: 2)
        try:
            def _send(epoch):
                def _f(h):
                    return am_request_short(
                        reg, h, opcode, make_args(np.int32(seg.offset)),
                        axis="x", perm=[(i, (i + 1) % 4) for i in range(4)],
                        epoch=epoch)
                return gas.run(_f)(gas.zeros_global())
            _send(2)                           # current epoch: delivers
            with pytest.raises(StaleEpoch):
                _send(1)                       # stale epoch: refused
        finally:
            conduit.clear_epoch_provider()


# ---------------------------------------------------------------------------
# hypothesis: churn interleavings preserve the epoch invariants
# ---------------------------------------------------------------------------


class TestChurnProperties:
    @settings(max_examples=20, deadline=None)
    @given(events=st.lists(
        st.tuples(st.sampled_from(["kill", "join", "miss"]),
                  st.integers(0, 5), st.integers(1, 30)),
        min_size=0, max_size=6),
        p=st.integers(1, 3), k=st.integers(2, 3))
    def test_epoch_monotone_one_bump_per_deadline(self, events, p, k):
        """Random kill/join/miss interleavings: epochs bump by exactly one
        per view change, every change lands on a lease deadline, and all
        ranks declared at the same deadline share one bump."""
        plan = FaultPlan(deliver="lease")
        svc = MembershipService(4, LeaseConfig(lease_period=p, k_misses=k),
                                fault_plan=plan)
        joined = set()
        for kind, rank, step in events:
            if kind == "kill" and rank < 4:
                plan.kill_rank(rank, at_step=step)
            elif kind == "miss" and rank < 4:
                plan.miss_lease(rank, at_step=step, count=1)
            elif kind == "join" and rank >= 4 and rank not in joined:
                joined.add(rank)
                svc.schedule_join(rank, at_step=step)
        evs = _run_to(svc, 40 + p * (k + 2))
        # (a) epochs are contiguous and strictly monotone
        assert [ev.epoch for ev in evs] == list(range(1, len(evs) + 1))
        assert svc.epoch == len(evs)
        for ev in evs:
            # (b) every view change lands on a lease deadline; a stale
            # handle from before it can never complete a collective
            assert ev.step % p == 0
            assert ev.died or ev.joined
            with pytest.raises(StaleEpoch):
                conduit.install_epoch_provider(lambda: svc.epoch)
                try:
                    conduit.check_epoch("all_reduce", ev.epoch - 1)
                finally:
                    conduit.clear_epoch_provider()
        # (c) no step carries two view changes — simultaneous losses and
        # joins batch into one bump
        steps = [ev.step for ev in evs]
        assert len(steps) == len(set(steps))
        # every scripted kill was eventually declared (dead stays dead)
        killed = {e.rank for e in plan.events if e.kind == "kill_rank"}
        declared = {r for ev in evs for r in ev.died}
        rejoined = {r for ev in evs for r in ev.joined}
        assert killed <= declared | rejoined
        for r in killed - rejoined:
            assert not svc.alive(r)


# ---------------------------------------------------------------------------
# the on-wire heartbeat segment vs the host mirror
# ---------------------------------------------------------------------------


class TestHeartbeatWire:
    def test_publish_fans_leases_out(self, mesh4):
        heap = pgas.SymmetricHeap(32)
        gas = pgas.GlobalAddressSpace(mesh4, "x", heap)
        seg, publish, announce = build_heartbeat_wire(gas)
        leases = np.arange(10, 14, dtype=np.float32)   # rank r -> 10 + r
        g = publish(gas.zeros_global(), leases)
        view = np.asarray(g).reshape(4, heap.size)
        base = seg.symbol.offset
        for rank in range(4):
            # every rank's segment holds every peer's freshest lease
            np.testing.assert_array_equal(
                view[rank, base:base + 4], leases)
            # and no join flags yet
            assert not view[rank, base + 4:base + 8].any()

    def test_announce_sets_flag_everywhere(self, mesh4):
        heap = pgas.SymmetricHeap(32)
        gas = pgas.GlobalAddressSpace(mesh4, "x", heap)
        seg, publish, announce = build_heartbeat_wire(gas)
        g = announce(2)(gas.zeros_global())
        view = np.asarray(g).reshape(4, heap.size)
        for rank in range(4):
            flags = view[rank, seg.join_offset(0):seg.join_offset(0) + 4]
            np.testing.assert_array_equal(flags, [0.0, 0.0, 1.0, 0.0])

    def test_segment_is_idempotent_and_sized(self, mesh4):
        heap = pgas.SymmetricHeap(32)
        gas = pgas.GlobalAddressSpace(mesh4, "x", heap)
        a = gas.heartbeat_segment()
        b = gas.heartbeat_segment()            # second call reuses the alloc
        assert a.symbol.offset == b.symbol.offset
        assert a.words == 8
        assert a.lease_offset(3) == a.symbol.offset + 3
        assert a.join_offset(0) == a.symbol.offset + 4


# ---------------------------------------------------------------------------
# acceptance: detector-driven double loss + rejoin, token-identical
# ---------------------------------------------------------------------------


class TestChurnServe:
    def _serve(self, mesh, prompts, plan=None, membership=None,
               conserve_every_tick=False):
        cfg = get_config("smollm-360m").reduced()
        shape = jax.eval_shape(lambda kk: init_params(cfg, kk),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda kk: init_params(cfg, kk),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        srv = Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=6, prefill_chunk=4,
            paged=True, block_size=4), fault_plan=plan,
            membership=membership)
        for p in prompts:
            srv.submit(p)
        steps = 0
        while ((srv.queue or any(s is not None for s in srv.slots))
               and steps < 300):
            srv.step()
            steps += 1
            if conserve_every_tick:
                srv.pool.check_conservation()
        if membership is not None:
            while (not any(ev.joined for ev in membership.events)
                   and steps < 300):
                srv.step()
                steps += 1
                if conserve_every_tick:
                    srv.pool.check_conservation()
        return srv

    def test_double_loss_and_rejoin_tokens_identical(self, mesh22):
        """Two decode ranks lose their lease in the same window; the
        detector (not the script) declares both in ONE epoch bump, the
        server drains/re-admits, a victim later rejoins at an epoch
        boundary — and the tokens match the unfailed run bit for bit,
        with pool conservation asserted at every tick."""
        rng = np.random.default_rng(0)
        cfg = get_config("smollm-360m").reduced()
        prompts = [rng.integers(0, cfg.vocab_size, size=s)
                   for s in (8, 11, 7)]
        clean = self._serve(mesh22, prompts)
        want = {r.rid: r.out_tokens for r in clean.done}

        plan = (FaultPlan(deliver="lease")
                .kill_rank(1, at_step=6).kill_rank(2, at_step=6)
                .delay_am(1e-3, at_step=2))    # jitter burst: no FP
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=2,
                                               step_time_s=1e-3),
                                fault_plan=plan)
        svc.schedule_join(1, at_step=16)
        churned = self._serve(mesh22, prompts, plan=plan, membership=svc,
                              conserve_every_tick=True)
        got = {r.rid: r.out_tokens for r in churned.done}
        assert got == want                     # bitwise token identity

        deaths = [ev for ev in svc.events if ev.died]
        joins = [ev for ev in svc.events if ev.joined]
        assert len(deaths) == 1 and deaths[0].died == (1, 2)
        assert len(joins) == 1 and joins[0].joined == (1,)
        assert svc.epoch == 2                  # one bump per view change
        s = churned.stats()
        assert s["recoveries"] >= 1
        # the rejoin restored rank 1's span: rank 2's stays quarantined
        assert s["quarantined_blocks"] > 0
        churned.pool.check_conservation()
