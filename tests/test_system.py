"""End-to-end system tests: the examples run, the dry-run lowers, the
technique's before/after is visible in the compiled artifacts."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
ENV.pop("XLA_FLAGS", None)   # each script sets its own device count


def _run(args, timeout=900):
    return subprocess.run(args, cwd=ROOT, env=ENV, timeout=timeout,
                          capture_output=True, text=True)


class TestExamples:
    def test_quickstart(self):
        r = _run([sys.executable, "examples/quickstart.py"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "quickstart OK" in r.stdout

    def test_train_lm_small(self):
        r = _run([sys.executable, "examples/train_lm.py", "--small"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "train_lm OK" in r.stdout

    def test_serve_lm(self):
        r = _run([sys.executable, "examples/serve_lm.py"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "serve_lm OK" in r.stdout

    def test_pgas_matmul_2node(self):
        r = _run([sys.executable, "examples/pgas_matmul_2node.py"])
        assert r.returncode == 0, r.stdout + r.stderr
        assert "pgas_matmul_2node OK" in r.stdout


class TestDryRunSmoke:
    """One representative cell per step kind lowers + compiles on the
    512-device production mesh (the full 80-cell sweep is the deliverable
    run; this keeps it guarded in CI)."""

    @pytest.mark.parametrize("arch,shape", [
        ("smollm-360m", "decode_32k"),
        ("whisper-tiny", "train_4k"),
    ])
    def test_cell(self, arch, shape, tmp_path):
        r = _run([sys.executable, "-m", "repro.launch.dryrun",
                  "--arch", arch, "--shape", shape,
                  "--out", str(tmp_path), "--quiet"])
        assert r.returncode == 0, r.stdout + r.stderr
        tag = f"{arch}__{shape}__pod1.json"
        rec = json.load(open(tmp_path / tag))
        assert rec["status"] == "ok", rec
        assert rec["chips"] == 256
        assert rec["flops"] > 0 and rec["coll_bytes"] > 0
