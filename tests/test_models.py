"""Per-arch smoke tests (reduced configs) + cross-path consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models.decode import cache_bytes, decode_step, init_cache
from repro.models.model import (
    count_params_analytic, forward, forward_hidden, init_params, loss_fn)
from repro.models.prefill import prefill


def _setup(name, **overrides):
    cfg = get_config(name).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _inputs(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    tokens = jax.random.randint(k, (b, s), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend:
        fe = jax.random.normal(k, (b, cfg.frontend_tokens, cfg.frontend_dim))
    return tokens, fe


@pytest.mark.parametrize("name", ARCH_NAMES)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, name):
        cfg, params = _setup(name)
        tokens, fe = _inputs(cfg)
        logits, aux = forward(cfg, params, tokens, fe)
        s_out = tokens.shape[1] + (cfg.frontend_tokens
                                   if cfg.frontend and cfg.family == "vlm"
                                   else 0)
        assert logits.shape == (2, s_out, cfg.vocab_size)
        assert not np.any(np.isnan(np.asarray(logits)))

    def test_train_step_no_nan(self, name):
        cfg, params = _setup(name)
        tokens, fe = _inputs(cfg, s=17)
        batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if fe is not None:
            batch["frontend_embeds"] = fe
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        assert np.isfinite(float(loss))
        gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                 for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0

    def test_param_count_matches_analytic(self, name):
        cfg, params = _setup(name)
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert count_params_analytic(cfg) == actual

    def test_decode_step(self, name):
        cfg, params = _setup(name)
        tokens, fe = _inputs(cfg)
        if cfg.family == "encdec":
            from repro.models.model import encode
            enc = encode(cfg, params, fe)
            cache = init_cache(cfg, 2, 32, enc_out=enc.astype(jnp.float32),
                               params=params)
        else:
            cache = init_cache(cfg, 2, 32)
        cache, logits = decode_step(cfg, params, cache, tokens[:, 0])
        assert logits.shape == (2, cfg.vocab_size)
        assert not np.any(np.isnan(np.asarray(logits)))
        # per-slot positions: every row advanced independently to 1
        assert np.asarray(cache["pos"]).tolist() == [1, 1]


@pytest.mark.parametrize("name", ["smollm-360m", "minicpm3-4b",
                                  "mamba2-2.7b", "zamba2-7b",
                                  "h2o-danube-1.8b", "whisper-tiny"])
class TestPrefillDecodeConsistency:
    def test_prefill_matches_stepwise_decode(self, name):
        cfg, params = _setup(name)
        B, S = 2, 12
        toks, fe = _inputs(cfg, b=B, s=S + 1)
        if cfg.family == "encdec":
            from repro.models.model import encode
            enc = encode(cfg, params, fe)
            cache = init_cache(cfg, B, 32, enc_out=enc.astype(jnp.float32),
                               params=params)
        else:
            cache = init_cache(cfg, B, 32)
        for t in range(S):
            cache, la = decode_step(cfg, params, cache, toks[:, t])
        cache_b, lb = prefill(cfg, params, toks[:, :S], fe, cache_len=32)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-5, atol=2e-5)
        # continue one more step from both caches
        cache, la2 = decode_step(cfg, params, cache, toks[:, S])
        cache_b, lb2 = decode_step(cfg, params, cache_b, toks[:, S])
        np.testing.assert_allclose(np.asarray(la2), np.asarray(lb2),
                                   rtol=2e-5, atol=2e-5)


class TestMoEPaths:
    def test_prefill_matches_decode_with_ample_capacity(self):
        cfg, params = _setup("llama4-scout-17b-a16e", capacity_factor=16.0)
        B, S = 2, 10
        toks, _ = _inputs(cfg, b=B, s=S)
        cache = init_cache(cfg, B, 32)
        for t in range(S):
            cache, la = decode_step(cfg, params, cache, toks[:, t])
        _, lb = prefill(cfg, params, toks[:, :S], cache_len=32)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-5, atol=2e-5)

    def test_dense_combine_equals_dispatch_when_no_drops(self):
        from repro.models import layers as L
        cfg, params = _setup("grok-1-314b", capacity_factor=16.0)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        y1 = L.moe(cfg, lp["moe"], x, dense_combine=False)
        y2 = L.moe(cfg, lp["moe"], x, dense_combine=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)

    def test_capacity_drops_fall_through_residual(self):
        """With capacity 0 every token overflows: MoE output ≈ shared only."""
        from repro.models import layers as L
        cfg, params = _setup("llama4-scout-17b-a16e", capacity_factor=1e-9)
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
        y = L.moe(cfg, lp["moe"], x)
        # routed contribution zero except the 1-token-per-expert capacity
        # floor; with shared expert it's still finite and non-NaN
        assert np.all(np.isfinite(np.asarray(y)))


class TestSWA:
    def test_window_limits_attention(self):
        """A token beyond the *stacked* receptive field (n_layers·(window−1))
        must not influence the output; one inside the window must."""
        cfg, params = _setup("h2o-danube-1.8b")   # reduced: window 8, 2 layers
        s = 24
        reach = cfg.n_layers * (cfg.window - 1)   # 14
        assert s - 1 - reach > 0
        toks, _ = _inputs(cfg, b=1, s=s)
        toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % cfg.vocab_size)
        l1, _ = forward(cfg, params, toks)
        l2, _ = forward(cfg, params, toks2)
        # last position is beyond the stacked reach of token 0
        np.testing.assert_allclose(np.asarray(l1[0, -1]),
                                   np.asarray(l2[0, -1]),
                                   rtol=1e-5, atol=1e-5)
        # but a position inside the window does differ
        assert np.abs(np.asarray(l1[0, 1]) - np.asarray(l2[0, 1])).max() > 1e-4


class TestCacheFootprint:
    def test_swa_cache_bounded(self):
        cfg = get_config("h2o-danube-1.8b")
        small = cache_bytes(cfg, batch=1, max_seq=8192)
        big = cache_bytes(cfg, batch=1, max_seq=1 << 19)
        assert big == small       # ring buffer capped at window=4096

    def test_ssm_cache_constant_in_seq(self):
        cfg = get_config("mamba2-2.7b")
        assert cache_bytes(cfg, 1, 1024) == cache_bytes(cfg, 1, 1 << 19)

    def test_mla_cache_much_smaller_than_gqa(self):
        mla = get_config("minicpm3-4b")
        gqa = get_config("h2o-danube-1.8b")
        # per layer per token: MLA latent (256+32) vs GQA 2·8·80
        mla_pl = (mla.kv_lora_rank + mla.qk_rope_dim)
        gqa_pl = 2 * gqa.n_kv_heads * 80
        assert mla_pl * 4 < gqa_pl


class TestHybridStructure:
    def test_shared_blocks_alternate(self):
        """zamba2: two alternating shared blocks — perturbing block 0's
        params changes groups 0,2,… but leaves a pure-ssm prefix alone."""
        cfg, params = _setup("zamba2-7b")
        toks, _ = _inputs(cfg, b=1, s=8)
        h1, _ = forward_hidden(cfg, params, toks)
        p2 = jax.tree.map(lambda x: x, params)
        wq = p2["shared_blocks"]["attn"]["wq"]
        p2["shared_blocks"]["attn"]["wq"] = wq.at[0].add(1.0)
        h2, _ = forward_hidden(cfg, p2, toks)
        assert np.abs(np.asarray(h1) - np.asarray(h2)).max() > 1e-6
