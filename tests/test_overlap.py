"""Collective-matmul (ART-on-TP) schedules vs dense references."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


from repro.core import overlap


def _shard(mesh, x, spec):
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))


@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("b,k,n", [(8, 16, 32), (16, 8, 8), (32, 32, 64)])
class TestAllGatherMatmul:
    def test_matches(self, mesh4, bidir, b, k, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        xs = _shard(mesh4, x, P("x", None))
        ws = _shard(mesh4, w, P(None, "x"))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.allgather_matmul, axis="x",
                              bidirectional=bidir),
            mesh=mesh4, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        np.testing.assert_allclose(
            np.asarray(f(xs, ws)), np.asarray(x) @ np.asarray(w),
            rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("b,k,n", [(8, 16, 32), (16, 32, 8), (32, 64, 16)])
class TestMatmulReduceScatter:
    def test_matches(self, mesh4, bidir, b, k, n):
        x = jax.random.normal(jax.random.PRNGKey(2), (b, k))
        w = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        xs = _shard(mesh4, x, P(None, "x"))
        ws = _shard(mesh4, w, P("x", None))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.matmul_reducescatter, axis="x",
                              bidirectional=bidir),
            mesh=mesh4, in_specs=(P(None, "x"), P("x", None)),
            out_specs=P("x", None)))
        np.testing.assert_allclose(
            np.asarray(f(xs, ws)), np.asarray(x) @ np.asarray(w),
            rtol=1e-4, atol=1e-4)


class TestOverlapStructure:
    def test_permute_count_scales_with_ranks(self, mesh4):
        """n−1 hops per direction: the ring structure must be visible."""
        from repro.analysis.hlo_cost import summarize

        x = jnp.zeros((8, 16))
        w = jnp.zeros((16, 32))
        xs = _shard(mesh4, x, P("x", None))
        ws = _shard(mesh4, w, P(None, "x"))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.allgather_matmul, axis="x",
                              bidirectional=True),
            mesh=mesh4, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        s = summarize(f.lower(xs, ws).compile().as_text())
        # bidirectional: 2 directions × (n−1)=3 hops = 6 permutes
        assert s.coll_count.get("collective-permute", 0) >= 6


class TestFusedMatchesOverlap:
    """kernels/cc_matmul consumes the identical ring inside the kernel —
    the XLA-level overlap schedule is the bit-exactness oracle (the full
    odd/even × uni/bidir × unaligned matrix lives in tests/test_kernels)."""

    def test_allgather_matmul_bitwise(self, mesh4):
        from repro.kernels.cc_matmul import allgather_matmul_pallas

        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (8, 24))
        xs = _shard(mesh4, x, P("x", None))
        ws = _shard(mesh4, w, P(None, None))
        runs = {}
        for name, fn in (
                ("overlap", functools.partial(
                    overlap.allgather_matmul, axis="x", bidirectional=True)),
                ("fused", functools.partial(
                    allgather_matmul_pallas, axis="x", bidirectional=True))):
            f = jax.jit(jax.shard_map(
                fn, mesh=mesh4, in_specs=(P("x", None), P(None, None)),
                out_specs=P(None, None), check_vma=False))
            runs[name] = np.asarray(f(xs, ws))
        np.testing.assert_array_equal(runs["fused"], runs["overlap"])

    def test_matmul_reducescatter_bitwise(self, mesh4):
        from repro.kernels.cc_matmul import matmul_reducescatter_pallas

        x = jax.random.normal(jax.random.PRNGKey(2), (32, 8))
        w = jax.random.normal(jax.random.PRNGKey(3), (8, 24))
        xs = _shard(mesh4, x, P(None, None))
        ws = _shard(mesh4, w, P(None, None))
        runs = {}
        for name, fn in (
                ("overlap", functools.partial(
                    overlap.matmul_reducescatter, axis="x",
                    bidirectional=True)),
                ("fused", functools.partial(
                    matmul_reducescatter_pallas, axis="x",
                    bidirectional=True))):
            f = jax.jit(jax.shard_map(
                fn, mesh=mesh4, in_specs=(P(None, None), P(None, None)),
                out_specs=P("x", None), check_vma=False))
            runs[name] = np.asarray(f(xs, ws))
        np.testing.assert_array_equal(runs["fused"], runs["overlap"])


class TestFusedTransportPolicy:
    """TransportPolicy.tp="fused" is a validated spelling that pins the
    in-kernel schedules at the artblock TP edges."""

    def test_policy_validates_and_binds(self):
        from repro.core.conduit import transports
        from repro.dist.steps import TransportPolicy

        assert "fused" in transports("all_gather")
        assert "fused" in transports("reduce_scatter")
        pol = TransportPolicy(tp="fused")
        c = pol.tp_conduit("model")
        assert c.transport == "fused"
        # explicit transports pass straight through the schedule picker
        assert c.matmul_schedule("all_gather", 1 << 20, 1e-4) == "fused"
        assert c.matmul_schedule("reduce_scatter", 1 << 20, 1e-4) == "fused"

    def test_fused_not_valid_for_moe(self):
        from repro.dist.steps import TransportPolicy

        with pytest.raises(ValueError, match="moe"):
            TransportPolicy(moe="fused")

    def test_tp_presets_resolve(self):
        from repro.configs import TP_PRESETS, get_tp_preset
        from repro.models.artblock import supports_art_tp

        for name in TP_PRESETS:
            preset = get_tp_preset(name)
            assert supports_art_tp(preset.config, preset.tp_axis)
            assert preset.step.transport.tp == "fused"


class TestArtBlockFused:
    """The artblock TP edges under a fused conduit: forward bit-identical
    to the streamed overlap schedule, grads match the dense reference."""

    def _mlp_inputs(self, n):
        d, f = 16, 32
        h = jax.random.normal(jax.random.PRNGKey(0), (2, n * 4, d))
        m_in = jax.random.normal(jax.random.PRNGKey(1), (2, n * 4, d))
        w_up = jax.random.normal(jax.random.PRNGKey(2), (d, f)) * 0.1
        w_down = jax.random.normal(jax.random.PRNGKey(3), (f, d)) * 0.1
        return h, m_in, w_up, w_down

    def _cfg(self):
        import dataclasses

        from repro.configs import get_config

        return dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                                   compute_dtype="float32")

    def _run(self, mesh, cfg, transport, h, m_in, w_up, w_down, grad=False):
        from repro.core.conduit import Conduit
        from repro.models import artblock

        n = mesh.shape["x"]

        def part(h_, m_, wu, wd):
            conduit = Conduit(axis="x", transport=transport)
            return artblock.art_mlp_part(cfg, h_, m_, wu, None, wd,
                                         conduit=conduit)

        f = jax.shard_map(
            part, mesh=mesh,
            in_specs=(P(None, "x", None), P(None, "x", None),
                      P(None, "x"), P("x", None)),
            out_specs=P(None, "x", None), check_vma=False)
        if not grad:
            return np.asarray(jax.jit(f)(h, m_in, w_up, w_down))

        def loss(wu, wd):
            return jnp.sum(f(h, m_in, wu, wd) ** 2)

        gu, gd = jax.jit(jax.grad(loss, argnums=(0, 1)))(w_up, w_down)
        return np.asarray(gu), np.asarray(gd)

    def test_forward_bitwise_vs_streamed(self, mesh4):
        cfg = self._cfg()
        h, m_in, w_up, w_down = self._mlp_inputs(mesh4.shape["x"])
        fused = self._run(mesh4, cfg, "fused", h, m_in, w_up, w_down)
        bidir = self._run(mesh4, cfg, "bidir", h, m_in, w_up, w_down)
        np.testing.assert_array_equal(fused, bidir)

    def test_grads_match_reference(self, mesh4):
        from repro.models import layers as L

        cfg = self._cfg()
        h, m_in, w_up, w_down = self._mlp_inputs(mesh4.shape["x"])
        gu, gd = self._run(mesh4, cfg, "fused", h, m_in, w_up, w_down,
                           grad=True)

        def ref_loss(wu, wd):
            act = L._act(cfg.activation, m_in @ wu)
            return jnp.sum((h + act @ wd) ** 2)

        ru, rd = jax.grad(ref_loss, argnums=(0, 1))(w_up, w_down)
        np.testing.assert_allclose(gu, np.asarray(ru), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gd, np.asarray(rd), rtol=1e-4, atol=1e-4)
