"""Collective-matmul (ART-on-TP) schedules vs dense references."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


from repro.core import overlap


def _shard(mesh, x, spec):
    return jax.device_put(x, jax.sharding.NamedSharding(mesh, spec))


@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("b,k,n", [(8, 16, 32), (16, 8, 8), (32, 32, 64)])
class TestAllGatherMatmul:
    def test_matches(self, mesh4, bidir, b, k, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        xs = _shard(mesh4, x, P("x", None))
        ws = _shard(mesh4, w, P(None, "x"))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.allgather_matmul, axis="x",
                              bidirectional=bidir),
            mesh=mesh4, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        np.testing.assert_allclose(
            np.asarray(f(xs, ws)), np.asarray(x) @ np.asarray(w),
            rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("b,k,n", [(8, 16, 32), (16, 32, 8), (32, 64, 16)])
class TestMatmulReduceScatter:
    def test_matches(self, mesh4, bidir, b, k, n):
        x = jax.random.normal(jax.random.PRNGKey(2), (b, k))
        w = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        xs = _shard(mesh4, x, P(None, "x"))
        ws = _shard(mesh4, w, P("x", None))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.matmul_reducescatter, axis="x",
                              bidirectional=bidir),
            mesh=mesh4, in_specs=(P(None, "x"), P("x", None)),
            out_specs=P("x", None)))
        np.testing.assert_allclose(
            np.asarray(f(xs, ws)), np.asarray(x) @ np.asarray(w),
            rtol=1e-4, atol=1e-4)


class TestOverlapStructure:
    def test_permute_count_scales_with_ranks(self, mesh4):
        """n−1 hops per direction: the ring structure must be visible."""
        from repro.analysis.hlo_cost import summarize

        x = jnp.zeros((8, 16))
        w = jnp.zeros((16, 32))
        xs = _shard(mesh4, x, P("x", None))
        ws = _shard(mesh4, w, P(None, "x"))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.allgather_matmul, axis="x",
                              bidirectional=True),
            mesh=mesh4, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        s = summarize(f.lower(xs, ws).compile().as_text())
        # bidirectional: 2 directions × (n−1)=3 hops = 6 permutes
        assert s.coll_count.get("collective-permute", 0) >= 6
