"""Checkpoint atomicity/roundtrip + data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.checkpoint import list_checkpoints
from repro.data import DataConfig, SyntheticLM


@pytest.fixture
def tmpdir(tmp_path):
    return str(tmp_path / "ckpt")


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step_count": jnp.asarray(7, jnp.int32)}


class TestCheckpoint:
    def test_roundtrip(self, tmpdir):
        t = _tree()
        save_checkpoint(tmpdir, 5, t, extra={"loss": 1.5})
        got, manifest = load_checkpoint(tmpdir, jax.eval_shape(lambda: t))
        assert manifest["step"] == 5
        assert manifest["extra"]["loss"] == 1.5
        np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                                   np.asarray(t["params"]["w"]))
        assert got["params"]["b"].dtype == jnp.bfloat16

    def test_latest_selected(self, tmpdir):
        t = _tree()
        for s in (1, 3, 2):
            save_checkpoint(tmpdir, s, t)
        _, manifest = load_checkpoint(tmpdir, jax.eval_shape(lambda: t))
        assert manifest["step"] == 3

    def test_partial_write_invisible(self, tmpdir):
        """A .tmp directory (simulated crash mid-write) is never loaded."""
        t = _tree()
        save_checkpoint(tmpdir, 1, t)
        crash = os.path.join(tmpdir, "step_00000002.tmp")
        os.makedirs(crash)
        with open(os.path.join(crash, "leaf_00000.npy"), "wb") as f:
            f.write(b"garbage")
        _, manifest = load_checkpoint(tmpdir, jax.eval_shape(lambda: t))
        assert manifest["step"] == 1
        assert list_checkpoints(tmpdir) == [
            (1, os.path.join(tmpdir, "step_00000001"))]

    def test_gc_keeps_last(self, tmpdir):
        mgr = CheckpointManager(tmpdir, interval=1, keep_last=2)
        for s in range(1, 6):
            mgr.save(s, _tree())
        assert [s for s, _ in list_checkpoints(tmpdir)] == [4, 5]

    def test_shape_mismatch_raises(self, tmpdir):
        save_checkpoint(tmpdir, 1, _tree())
        bad = {"params": {"w": jnp.zeros((2, 2)), "b": jnp.zeros((4,))},
               "step_count": jnp.zeros((), jnp.int32)}
        with pytest.raises(ValueError):
            load_checkpoint(tmpdir, jax.eval_shape(lambda: bad))

    def test_restore_resharded(self, tmpdir, mesh22):
        """Elastic path: restore onto a mesh with different sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = {"w": jnp.arange(16.0).reshape(4, 4)}
        save_checkpoint(tmpdir, 1, t)
        sh = {"w": NamedSharding(mesh22, P("data", "model"))}
        got, _ = load_checkpoint(tmpdir, jax.eval_shape(lambda: t),
                                 shardings=sh)
        assert got["w"].sharding == sh["w"]
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(t["w"]))


class TestDataPipeline:
    def test_deterministic_per_step(self):
        d1 = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                    global_batch=8, seed=3))
        d2 = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                    global_batch=8, seed=3))
        b1 = d1.batch(42)
        b2 = d2.batch(42)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_steps_differ(self):
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=8))
        assert not np.array_equal(np.asarray(d.batch(1)["tokens"]),
                                  np.asarray(d.batch(2)["tokens"]))

    def test_labels_shifted(self):
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=4))
        b = d.batch(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_resume_no_state(self):
        """Restarting mid-run regenerates the identical remaining stream."""
        d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                   global_batch=4, seed=9))
        run1 = [np.asarray(d.batch(s)["tokens"]) for s in range(5)]
        d_restarted = SyntheticLM(DataConfig(vocab_size=100, seq_len=16,
                                             global_batch=4, seed=9))
        run2 = [np.asarray(d_restarted.batch(s)["tokens"])
                for s in range(3, 5)]
        np.testing.assert_array_equal(run1[3], run2[0])
        np.testing.assert_array_equal(run1[4], run2[1])

    def test_compressible_structure(self):
        """n-gram structure: consecutive-token entropy below uniform."""
        d = SyntheticLM(DataConfig(vocab_size=1000, seq_len=257,
                                   global_batch=16, noise_prob=0.05))
        toks = np.asarray(d.batch(0)["tokens"])
        # bigram repeat rate across batch rows must exceed uniform chance
        pairs = set()
        repeats = 0
        total = 0
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                total += 1
                if (a, b) in pairs:
                    repeats += 1
                pairs.add((a, b))
        assert repeats / total > 0.2   # uniform would be ~0
