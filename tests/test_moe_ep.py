"""Expert-parallel MoE dispatch (``models/moe_ep.py``).

The conduit layer's last unbound traffic class: EP dispatch must equal the
dense-GSPMD capacity path token-for-token (same routing, same capacity
drops) for every registered ``all_to_all`` transport and for odd/even
expert-axis sizes; the train step must select it from
``TransportPolicy.moe`` and produce the same update as the dense path;
and the bucketed exchange must verifiably run through the conduit
``all_to_all`` registry (asserted with a counting probe transport).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_ep_preset
from repro.core import conduit
from repro.data import DataConfig, SyntheticLM, batch_specs
from repro.dist.sharding import dp_axes, param_pspecs
from repro.dist.steps import (
    StepConfig, TransportPolicy, build_init, build_train_step)
from repro.models import layers as L
from repro.models import moe_ep
from repro.models.model import init_params

ALL_TRANSPORTS = ("xla", "ring", "bidir", "auto")


def _expert_mesh(n_expert, data=1):
    devs = np.array(jax.devices()[: data * n_expert])
    if data == 1:
        return jax.sharding.Mesh(devs, ("expert",))
    return jax.sharding.Mesh(devs.reshape(data, n_expert),
                             ("data", "expert"))


def _moe_layer(cfg, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    return jax.tree.map(lambda a: a[0], params["layers"]["moe"])


# ---------------------------------------------------------------------------
# layer-level equivalence: every transport × odd/even expert-axis sizes
# ---------------------------------------------------------------------------


class TestLayerEquivalence:
    @pytest.mark.parametrize("transport", ALL_TRANSPORTS)
    @pytest.mark.parametrize("n_exp", [2, 4])
    def test_matches_dense_even(self, transport, n_exp):
        cfg = get_config("grok-1-314b").reduced()     # 4 experts, top-2
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        dense = L.moe(cfg, moe_p, x)
        mesh = _expert_mesh(n_exp)
        runner = moe_ep.build_moe_ep_runner(cfg, mesh, transport=transport)
        assert runner is not None
        got = jax.jit(lambda p, v: runner(cfg, p, v))(moe_p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("transport", ("ring", "bidir"))
    def test_matches_dense_odd_axis(self, transport):
        """3 expert shards (odd — the ring schedules' hard case)."""
        cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                  n_experts=6)
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 8, cfg.d_model))
        dense = L.moe(cfg, moe_p, x)
        mesh = _expert_mesh(3)
        runner = moe_ep.build_moe_ep_runner(cfg, mesh, transport=transport)
        assert runner is not None
        got = jax.jit(lambda p, v: runner(cfg, p, v))(moe_p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-6, atol=1e-6)

    def test_shared_expert_arch(self):
        """llama4-scout: shared expert rides outside the manual region."""
        cfg = get_config("llama4-scout-17b-a16e").reduced()
        assert cfg.n_shared_experts
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg.d_model))
        dense = L.moe(cfg, moe_p, x)
        runner = moe_ep.build_moe_ep_runner(cfg, _expert_mesh(2),
                                            transport="ring")
        got = jax.jit(lambda p, v: runner(cfg, p, v))(moe_p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                                   rtol=1e-6, atol=1e-6)

    def test_grads_match_dense(self):
        """The psum the shard_map transpose inserts for the replicated
        router / expert-replicated weights must be a true sum of distinct
        token partials — grads equal the dense path's."""
        cfg = get_config("grok-1-314b").reduced()
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 8, cfg.d_model))
        runner = moe_ep.build_moe_ep_runner(cfg, _expert_mesh(2, data=2),
                                            transport="ring")
        g_dense = jax.grad(lambda p: (L.moe(cfg, p, x) ** 2).sum())(moe_p)
        g_ep = jax.jit(jax.grad(
            lambda p: (runner(cfg, p, x) ** 2).sum()))(moe_p)
        for a, b in zip(jax.tree.leaves(g_dense), jax.tree.leaves(g_ep)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fallbacks (drop to dense, never fail to trace)
# ---------------------------------------------------------------------------


class TestFallbacks:
    def test_no_expert_axis_returns_none(self, mesh22):
        cfg = get_config("grok-1-314b").reduced()
        assert moe_ep.build_moe_ep_runner(cfg, mesh22,
                                          transport="ring") is None

    def test_indivisible_experts_returns_none(self):
        cfg = get_config("grok-1-314b").reduced()      # 4 experts
        assert moe_ep.build_moe_ep_runner(cfg, _expert_mesh(3),
                                          transport="ring") is None

    def test_indivisible_batch_falls_back_to_dense(self):
        cfg = get_config("grok-1-314b").reduced()
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(5), (3, 8, cfg.d_model))
        runner = moe_ep.build_moe_ep_runner(cfg, _expert_mesh(2),
                                            transport="ring")
        got = runner(cfg, moe_p, x)                   # B=3 % mesh 2 != 0
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(L.moe(cfg, moe_p, x)))


# ---------------------------------------------------------------------------
# the exchange really rides the conduit registry
# ---------------------------------------------------------------------------


class TestConduitBinding:
    def test_dispatch_goes_through_registry(self):
        """Register a counting probe transport for all_to_all and name it
        in TransportPolicy.moe: tracing the EP layer must invoke it (twice
        per layer — dispatch + return)."""
        calls = []

        @conduit.register("all_to_all", "probe")
        def _probe(x, *, axis, chunk_bytes=None):
            calls.append(x.shape)
            return conduit.resolve("all_to_all", "ring")(
                x, axis=axis, chunk_bytes=chunk_bytes)

        try:
            TransportPolicy(moe="probe")              # registry-validated
            cfg = get_config("grok-1-314b").reduced()
            moe_p = _moe_layer(cfg)
            x = jax.random.normal(jax.random.PRNGKey(6), (4, 8, cfg.d_model))
            runner = moe_ep.build_moe_ep_runner(cfg, _expert_mesh(2),
                                                transport="probe")
            got = jax.jit(lambda p, v: runner(cfg, p, v))(moe_p, x)
            assert len(calls) == 2, calls              # there and back
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(L.moe(cfg, moe_p, x)),
                                       rtol=1e-6, atol=1e-6)
        finally:
            del conduit._REGISTRY[("all_to_all", "probe")]
        with pytest.raises(ValueError):
            TransportPolicy(moe="probe")               # gone again


# ---------------------------------------------------------------------------
# sharding rules: the expert axis
# ---------------------------------------------------------------------------


class TestExpertSharding:
    def test_expert_axis_on_moe_params(self):
        cfg = get_config("llama4-scout-17b-a16e").reduced()
        mesh = _expert_mesh(2, data=2)
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        specs = param_pspecs(cfg, mesh, shape)
        flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
                for path, s in
                jax.tree_util.tree_flatten_with_path(specs)[0]}
        # routed experts: (layer, E, in, out) -> E on "expert"
        assert flat["layers/moe/w_up"] == P(None, "expert", "data", None)
        assert flat["layers/moe/w_down"] == P(None, "expert", None, "data")
        # router replicated over experts; shared expert is a dense MLP
        assert flat["layers/moe/router"][-1] is None
        assert "expert" not in tuple(flat["layers/moe/shared/w_up"])
        assert flat["layers/moe/shared/w_up"] == P(None, "data", None)

    def test_no_expert_axis_specs_unchanged(self, mesh22):
        cfg = get_config("grok-1-314b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        specs = param_pspecs(cfg, mesh22, shape)
        flat = {"/".join(str(getattr(k, "key", k)) for k in path): s
                for path, s in
                jax.tree_util.tree_flatten_with_path(specs)[0]}
        assert flat["layers/moe/w_up"] == P(None, None, "data", "model")

    def test_dp_axes_include_expert(self):
        mesh = _expert_mesh(2, data=2)
        assert dp_axes(mesh) == ("data", "expert")


# ---------------------------------------------------------------------------
# conduit all_to_all: tiled leading dims (the xla-transport semantics)
# ---------------------------------------------------------------------------


class TestTiledAllToAll:
    @pytest.mark.parametrize("transport", ("ring", "bidir"))
    def test_tiled_matches_xla(self, transport):
        n = 4
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("x",))
        x = jax.random.normal(jax.random.PRNGKey(7), (n, 2 * n, 3))
        outs = {}
        for t in (transport, "xla"):
            cd = conduit.Conduit("x", t)
            outs[t] = np.asarray(jax.jit(jax.shard_map(
                lambda v, cd=cd: cd.all_to_all(v[0])[None], mesh=mesh,
                in_specs=P("x"), out_specs=P("x")))(x))
        np.testing.assert_array_equal(outs[transport], outs["xla"])


# ---------------------------------------------------------------------------
# streamed dispatch: the chunked pipeline ≡ the bulk exchange, bit-for-bit
# ---------------------------------------------------------------------------


class TestStreamedDispatch:
    def _outs(self, cfg, mesh, transport, stream_chunks, *, batch=8, seed=8):
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(seed),
                              (batch, 8, cfg.d_model))
        runner = moe_ep.build_moe_ep_runner(
            cfg, mesh, transport=transport, stream_chunks=stream_chunks)
        assert runner is not None
        return np.asarray(jax.jit(lambda p, v: runner(cfg, p, v))(moe_p, x))

    @pytest.mark.parametrize("transport", ("xla", "ring", "bidir"))
    @pytest.mark.parametrize("chunks", (2, 3))
    def test_streamed_equals_bulk(self, transport, chunks):
        """Per transport, including a chunk count that does not divide the
        local row extent (b=4 rows over 2 shards → chunks of 1/2/1)."""
        cfg = get_config("grok-1-314b").reduced()
        mesh = _expert_mesh(2)
        bulk = self._outs(cfg, mesh, transport, None)
        got = self._outs(cfg, mesh, transport, chunks)
        np.testing.assert_array_equal(got, bulk)

    def test_odd_expert_axis(self):
        """3 expert shards through the streamed path (the ring schedules'
        hard case), chunk count not dividing the row extent either."""
        cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                  n_experts=6)
        mesh = _expert_mesh(3)
        bulk = self._outs(cfg, mesh, "ring", None, batch=9)
        got = self._outs(cfg, mesh, "ring", 2, batch=9)
        np.testing.assert_array_equal(got, bulk)

    def test_oversized_chunk_count_clamps_to_rows(self):
        """stream_chunks beyond the local row extent degenerates cleanly
        (clamped — at most one row per bucket), still ≡ bulk."""
        cfg = get_config("grok-1-314b").reduced()
        mesh = _expert_mesh(2)
        bulk = self._outs(cfg, mesh, "ring", None)
        got = self._outs(cfg, mesh, "ring", 1000)
        np.testing.assert_array_equal(got, bulk)

    def test_streamed_grads_equal_bulk(self):
        cfg = get_config("grok-1-314b").reduced()
        moe_p = _moe_layer(cfg)
        x = jax.random.normal(jax.random.PRNGKey(9), (4, 8, cfg.d_model))
        grads = {}
        for chunks in (None, 2):
            runner = moe_ep.build_moe_ep_runner(
                cfg, _expert_mesh(2), transport="ring",
                stream_chunks=chunks)
            grads[chunks] = jax.jit(jax.grad(
                lambda p: (runner(cfg, p, x) ** 2).sum()))(moe_p)
        for a, b in zip(jax.tree.leaves(grads[None]),
                        jax.tree.leaves(grads[2])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_streamed_issues_same_total_traffic_as_bulk(self):
        """Counting probe on the registry: the streamed dispatch makes
        ``2 × stream_chunks`` smaller conduit calls whose element total is
        exactly the bulk exchange's (nothing sent twice, nothing skipped).
        """
        calls = []

        @conduit.register("all_to_all", "probe")
        def _probe(v, *, axis, chunk_bytes=None):
            calls.append(int(v.size))
            return conduit.resolve("all_to_all", "ring")(
                v, axis=axis, chunk_bytes=chunk_bytes)

        try:
            cfg = get_config("grok-1-314b").reduced()
            moe_p = _moe_layer(cfg)
            x = jax.random.normal(jax.random.PRNGKey(10),
                                  (4, 8, cfg.d_model))
            totals = {}
            for chunks in (None, 2):
                calls.clear()
                runner = moe_ep.build_moe_ep_runner(
                    cfg, _expert_mesh(2), transport="probe",
                    stream_chunks=chunks)
                jax.jit(lambda p, v, r=runner: r(cfg, p, v))(moe_p, x)
                totals[chunks] = (len(calls), sum(calls))
            assert totals[None][0] == 2            # there and back
            assert totals[2][0] == 4               # 2 chunks × (there+back)
            assert totals[2][1] == totals[None][1]
        finally:
            del conduit._REGISTRY[("all_to_all", "probe")]


# ---------------------------------------------------------------------------
# the train step: TransportPolicy.moe selects EP, update matches dense
# ---------------------------------------------------------------------------


class TestEPTrainStep:
    def test_ep_step_matches_dense_gspmd(self):
        """Acceptance: moe="ring" and moe="auto" produce the same MoE layer
        output / loss / grads as the dense-GSPMD step (identical capacity
        drops by construction)."""
        cfg = get_config("grok-1-314b").reduced()
        mesh = _expert_mesh(2, data=2)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        batch = data.global_batch(0)
        bshape = batch_specs(16, 8, cfg.vocab_size)
        outs = {}
        for moe_t in ("xla", "ring", "auto"):
            scfg = StepConfig(microbatches=2, seq_chunk=8, warmup_steps=2,
                              total_steps=10,
                              transport=TransportPolicy(moe=moe_t))
            bundle = build_train_step(cfg, mesh, scfg, bshape)
            init_fn, _ = build_init(cfg, mesh, scfg)
            params, opt = init_fn(jax.random.PRNGKey(0))
            _, _, m = bundle.fn(params, opt, batch, jnp.int32(0))
            outs[moe_t] = (float(m["loss"]), float(m["grad_norm"]),
                           float(m["moe_aux"]))
        for moe_t in ("ring", "auto"):
            np.testing.assert_allclose(outs["xla"][0], outs[moe_t][0],
                                       rtol=1e-5)
            np.testing.assert_allclose(outs["xla"][1], outs[moe_t][1],
                                       rtol=1e-4)
            np.testing.assert_allclose(outs["xla"][2], outs[moe_t][2],
                                       rtol=1e-5)

    def test_streamed_bucketed_step_matches_baseline(self):
        """The full overlapped step — streamed EP dispatch + bucketed
        microbatch accumulation — produces bit-identical metrics and
        params to the same step with both pipelines off."""
        cfg = get_config("grok-1-314b").reduced()
        mesh = _expert_mesh(2, data=2)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=17,
                                      global_batch=8))
        batch = data.global_batch(0)
        bshape = batch_specs(16, 8, cfg.vocab_size)
        outs = {}
        for overlapped in (False, True):
            scfg = StepConfig(
                microbatches=2, seq_chunk=8, warmup_steps=2, total_steps=10,
                grad_bucket_bytes=(1 << 12) if overlapped else None,
                transport=TransportPolicy(
                    moe="ring",
                    moe_stream_chunks=2 if overlapped else None))
            bundle = build_train_step(cfg, mesh, scfg, bshape)
            init_fn, _ = build_init(cfg, mesh, scfg)
            params, opt = init_fn(jax.random.PRNGKey(0))
            p2, _, m = bundle.fn(params, opt, batch, jnp.int32(0))
            outs[overlapped] = (m, p2)
        m0, m1 = outs[False][0], outs[True][0]
        for k in m0:
            assert float(m0[k]) == float(m1[k]), (k, m0[k], m1[k])
        for a, b in zip(jax.tree.leaves(outs[False][1]),
                        jax.tree.leaves(outs[True][1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ep_presets_build(self):
        """Every shipped EP preset wires a valid policy end to end
        (get_ep_preset validates arch family / expert-axis divisibility,
        and the preset policy ships the streamed dispatch)."""
        from repro.configs import EP_PRESET_NAMES

        for name in EP_PRESET_NAMES:
            preset = get_ep_preset(name)
            policy = preset.step.resolved_transport()
            assert policy.moe == "auto"
            assert policy.moe_stream_chunks and policy.moe_stream_chunks > 1
            assert preset.config.n_experts % preset.expert_axis == 0
