"""Transport equivalence the dist layer's switch relies on: the PGAS ring
collectives must be numerically interchangeable with the XLA built-ins
(``dist/steps.py`` swaps one for the other per StepConfig)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as col
from repro.dist.grad_sync import cross_pod_all_reduce


class TestRingAllReduce:
    @pytest.mark.parametrize("shape", [(8,), (3, 5), (2, 4, 3)])
    def test_matches_psum_exact_on_ints(self, mesh4, shape):
        """Integer-valued payloads: any summation order is exact, so the
        ring must equal psum bit-for-bit."""
        vals = jax.random.randint(
            jax.random.PRNGKey(0), (4,) + shape, -100, 100).astype(jnp.float32)

        def ours(v):
            return col.ring_all_reduce(v[0], axis="x")[None]

        def ref(v):
            return jax.lax.psum(v[0], "x")[None]

        got, want = [
            np.asarray(jax.jit(jax.shard_map(
                f, mesh=mesh4, in_specs=P("x"), out_specs=P("x")))(vals))
            for f in (ours, ref)
        ]
        np.testing.assert_array_equal(got, want)

    def test_matches_psum_float(self, mesh4):
        vals = jax.random.normal(jax.random.PRNGKey(1), (4, 64))

        def ours(v):
            return col.ring_all_reduce(v[0], axis="x")[None]

        def ref(v):
            return jax.lax.psum(v[0], "x")[None]

        got, want = [
            np.asarray(jax.jit(jax.shard_map(
                f, mesh=mesh4, in_specs=P("x"), out_specs=P("x")))(vals))
            for f in (ours, ref)
        ]
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


class TestCrossPodTransportSwitch:
    @pytest.fixture(scope="class")
    def podmesh(self):
        return jax.make_mesh((2, 2), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def test_uncompressed_bit_exact_with_xla(self, podmesh):
        """With 2 pods the per-element sum is a single commutative add, so
        the PGAS ring and the XLA pmean must agree bit-for-bit — the
        equivalence that makes the PGAS ring a pure transport swap."""
        g = jax.random.normal(jax.random.PRNGKey(2), (2, 128))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))

        ours, _ = cross_pod_all_reduce({"w": gs}, podmesh)

        ref = jax.jit(jax.shard_map(
            lambda t: jax.lax.pmean(t, "pod"),
            mesh=podmesh, in_specs=P("pod", None),
            out_specs=P("pod", None)))(gs)
        np.testing.assert_array_equal(np.asarray(ours["w"]), np.asarray(ref))

    def test_ef_is_zero_when_uncompressed(self, podmesh):
        g = jax.random.normal(jax.random.PRNGKey(3), (2, 32))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))
        _, ef = cross_pod_all_reduce({"w": gs}, podmesh)
        assert float(jnp.abs(ef["w"]).max()) == 0.0
