"""Transport equivalence the conduit layer's switch relies on: every
registered transport of every collective op must be numerically
interchangeable with the XLA built-ins (``dist/steps.py``'s
TransportPolicy swaps one for the other), and the ``auto`` policy must
actually *use* the Fig. 5 tradeoff — different transports for small vs
large messages under the QSFP+ netmodel."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as col
from repro.core import conduit
from repro.core import netmodel as nm
from repro.dist.grad_sync import cross_pod_all_reduce

RING_TRANSPORTS = ("ring", "bidir")
ALL_TRANSPORTS = ("xla", "ring", "bidir")


def _mesh(n):
    """1-D mesh over the first ``n`` host devices (odd sizes included)."""
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("x",))


def _run(mesh, fn, *args, in_specs=P("x"), out_specs=P("x")):
    return np.asarray(jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs))(*args))


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------


class TestRegistry:
    @pytest.mark.parametrize("op", conduit.OPS)
    def test_every_op_has_three_transports(self, op):
        names = conduit.transports(op)
        assert set(ALL_TRANSPORTS) <= set(names), (op, names)


# ---------------------------------------------------------------------------
# per-op equivalence, every transport × odd/even axis sizes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [3, 4])
@pytest.mark.parametrize("transport", ALL_TRANSPORTS)
class TestTransportEquivalence:
    """Each conduit transport against the XLA builtin oracle."""

    def test_all_gather(self, transport, n):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        x = jax.random.normal(jax.random.PRNGKey(0), (n * 4, 6))
        got = _run(mesh, lambda v: cd.all_gather(v), x)
        want = _run(mesh, lambda v: jax.lax.all_gather(
            v, "x", axis=0, tiled=True), x)
        np.testing.assert_array_equal(got, want)

    def test_reduce_scatter(self, transport, n):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        x = jax.random.randint(
            jax.random.PRNGKey(1), (n, n * 3, 5), -50, 50
        ).astype(jnp.float32).reshape(n * n * 3, 5)
        got = _run(mesh, lambda v: cd.reduce_scatter(v), x)
        want = _run(mesh, lambda v: jax.lax.psum_scatter(
            v, "x", scatter_dimension=0, tiled=True), x)
        np.testing.assert_array_equal(got, want)   # ints: exact in any order

    def test_all_reduce(self, transport, n):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        x = jax.random.normal(jax.random.PRNGKey(2), (n, 7, 5))

        def ours(v):
            return cd.all_reduce(v[0])[None]

        def ref(v):
            return jax.lax.psum(v[0], "x")[None]

        got = _run(mesh, ours, x)
        want = _run(mesh, ref, x)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("root", [0, 1])
    def test_broadcast(self, transport, n, root):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        x = jax.random.normal(jax.random.PRNGKey(3), (n, 9))

        def ours(v):
            return cd.broadcast(v[0], root)[None]

        got = _run(mesh, ours, x)
        want = np.broadcast_to(np.asarray(x)[root], (n, 9))
        np.testing.assert_array_equal(got, want)

    def test_all_to_all(self, transport, n):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        x = jax.random.normal(jax.random.PRNGKey(4), (n, n, 2, 3))

        def ours(v):
            return cd.all_to_all(v[0])[None]

        got = _run(mesh, ours, x)
        # oracle: slot q on rank r must hold what rank q addressed to r
        want = np.asarray(x).transpose(1, 0, 2, 3)
        np.testing.assert_array_equal(got, want)

    def test_barrier(self, transport, n):
        mesh = _mesh(n)
        cd = conduit.Conduit("x", transport)
        got = np.asarray(jax.jit(jax.shard_map(
            lambda: cd.barrier()[None], mesh=mesh,
            in_specs=(), out_specs=P("x")))())
        assert got.tolist() == [n] * n


# ---------------------------------------------------------------------------
# ART chunking is numerics-neutral
# ---------------------------------------------------------------------------


class TestChunking:
    @pytest.mark.parametrize("transport", RING_TRANSPORTS)
    def test_chunked_equals_unchunked(self, transport):
        mesh = _mesh(4)
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 8, 10))
        outs = []
        for chunk in (None, 64):
            cd = conduit.Conduit("x", transport, chunk_bytes=chunk)
            outs.append(_run(mesh, lambda v, cd=cd: cd.all_reduce(v[0])[None],
                             x))
        np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# legacy collectives wrappers (the old public surface, now conduit-backed)
# ---------------------------------------------------------------------------


class TestRingAllReduce:
    @pytest.mark.parametrize("shape", [(8,), (3, 5), (2, 4, 3)])
    def test_matches_psum_exact_on_ints(self, mesh4, shape):
        """Integer-valued payloads: any summation order is exact, so the
        ring must equal psum bit-for-bit."""
        vals = jax.random.randint(
            jax.random.PRNGKey(0), (4,) + shape, -100, 100).astype(jnp.float32)

        def ours(v):
            return col.ring_all_reduce(v[0], axis="x")[None]

        def ref(v):
            return jax.lax.psum(v[0], "x")[None]

        got, want = [
            np.asarray(jax.jit(jax.shard_map(
                f, mesh=mesh4, in_specs=P("x"), out_specs=P("x")))(vals))
            for f in (ours, ref)
        ]
        np.testing.assert_array_equal(got, want)

    def test_matches_psum_float(self, mesh4):
        vals = jax.random.normal(jax.random.PRNGKey(1), (4, 64))

        def ours(v):
            return col.ring_all_reduce(v[0], axis="x")[None]

        def ref(v):
            return jax.lax.psum(v[0], "x")[None]

        got, want = [
            np.asarray(jax.jit(jax.shard_map(
                f, mesh=mesh4, in_specs=P("x"), out_specs=P("x")))(vals))
            for f in (ours, ref)
        ]
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# the auto policy (paper Fig. 5 as a runtime decision)
# ---------------------------------------------------------------------------


class TestAutoSelection:
    @pytest.mark.parametrize("op", ["all_reduce", "all_gather",
                                    "reduce_scatter"])
    def test_small_vs_large_pick_different_transports(self, op):
        """Under the QSFP+ netmodel, tiny messages must resolve to the
        latency-lean xla transport and multi-MB messages to a ring family
        (full-duplex bidir) — the Fig. 5 tradeoff, decided at runtime."""
        small, _ = conduit.auto_select(
            op, size_bytes=256, axis_size=8, link=nm.FSHMEM_QSFP)
        large, chunk = conduit.auto_select(
            op, size_bytes=8 << 20, axis_size=8, link=nm.FSHMEM_QSFP)
        assert small == "xla"
        assert large in RING_TRANSPORTS
        assert small != large
        assert chunk in conduit.CHUNK_CANDIDATES

    def test_large_prefers_full_duplex(self):
        t, _ = conduit.auto_select(
            "all_reduce", size_bytes=8 << 20, axis_size=8,
            link=nm.FSHMEM_QSFP)
        assert t == "bidir"   # both directions carry half the bytes

    def test_auto_conduit_is_correct(self):
        """End to end: an auto conduit must still be numerically right for
        both a tiny and a large payload (different transports inside)."""
        mesh = _mesh(4)
        cd = conduit.Conduit("x", "auto")
        for shape in ((4, 3), (4, 1 << 15)):
            x = jax.random.normal(jax.random.PRNGKey(6), shape)
            got = _run(mesh, lambda v: cd.all_reduce(v[0])[None], x)
            want = np.broadcast_to(np.asarray(x).sum(0), x.shape)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_estimate_time_covers_every_pair(self):
        for op in conduit.OPS:
            for t in conduit.transports(op):
                dt = conduit.estimate_time(
                    op, t, size_bytes=1 << 16, axis_size=8,
                    link=nm.FSHMEM_QSFP)
                assert dt > 0.0, (op, t)


# ---------------------------------------------------------------------------
# cross-pod grad sync through the conduit (transport switch incl. compression)
# ---------------------------------------------------------------------------


class TestCrossPodTransportSwitch:
    @pytest.fixture(scope="class")
    def podmesh(self):
        return jax.make_mesh((2, 2), ("pod", "data"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def test_uncompressed_bit_exact_with_xla(self, podmesh):
        """With 2 pods the per-element sum is a single commutative add, so
        the PGAS ring and the XLA pmean must agree bit-for-bit — the
        equivalence that makes the PGAS ring a pure transport swap."""
        g = jax.random.normal(jax.random.PRNGKey(2), (2, 128))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))

        ours, _ = cross_pod_all_reduce({"w": gs}, podmesh)

        ref = jax.jit(jax.shard_map(
            lambda t: jax.lax.pmean(t, "pod"),
            mesh=podmesh, in_specs=P("pod", None),
            out_specs=P("pod", None)))(gs)
        np.testing.assert_array_equal(np.asarray(ours["w"]), np.asarray(ref))

    @pytest.mark.parametrize("transport", ALL_TRANSPORTS)
    def test_every_transport_agrees(self, podmesh, transport):
        g = jax.random.normal(jax.random.PRNGKey(7), (2, 96))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))
        ours, _ = cross_pod_all_reduce({"w": gs}, podmesh,
                                       transport=transport)
        want = np.broadcast_to(np.asarray(g).mean(0), g.shape)
        np.testing.assert_allclose(np.asarray(ours["w"]), want,
                                   rtol=1e-6, atol=1e-6)

    def test_ef_is_zero_when_uncompressed(self, podmesh):
        g = jax.random.normal(jax.random.PRNGKey(3), (2, 32))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))
        _, ef = cross_pod_all_reduce({"w": gs}, podmesh)
        assert float(jnp.abs(ef["w"]).max()) == 0.0

    def test_compression_is_a_conduit_wrapper(self, podmesh):
        """compressed=True must behave the same over any base transport —
        compression wraps the conduit, it is not a transport property."""
        g = jax.random.normal(jax.random.PRNGKey(8), (2, 64))
        gs = jax.device_put(g, NamedSharding(podmesh, P("pod", None)))
        outs = []
        for transport in ("ring", "xla"):
            synced, ef = cross_pod_all_reduce(
                {"w": gs}, podmesh, compressed=True, transport=transport)
            outs.append((np.asarray(synced["w"]), np.asarray(ef["w"])))
        np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-6)
        np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-6)


# ---------------------------------------------------------------------------
# overlap schedules driven by a conduit handle
# ---------------------------------------------------------------------------


class TestOverlapConduit:
    @pytest.mark.parametrize("transport", RING_TRANSPORTS)
    def test_allgather_matmul_conduit(self, mesh4, transport):
        from repro.core import overlap
        cd = conduit.Conduit("x", transport)
        x = jax.random.normal(jax.random.PRNGKey(9), (8, 16))
        w = jax.random.normal(jax.random.PRNGKey(10), (16, 32))
        xs = jax.device_put(x, NamedSharding(mesh4, P("x", None)))
        ws = jax.device_put(w, NamedSharding(mesh4, P(None, "x")))
        f = jax.jit(jax.shard_map(
            functools.partial(overlap.allgather_matmul, conduit=cd),
            mesh=mesh4, in_specs=(P("x", None), P(None, "x")),
            out_specs=P(None, "x")))
        np.testing.assert_allclose(
            np.asarray(f(xs, ws)), np.asarray(x @ w), rtol=1e-5, atol=1e-5)
