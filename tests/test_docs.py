"""Docs claims stay true: the op × transport matrix in
``docs/transports.md`` must mirror the conduit registry exactly, every
````python`` block in ``docs/`` and ``DESIGN.md`` must at least compile,
and the link/docstring gate the CI docs job runs must pass from the test
suite too (so a broken doc fails tier-1, not just CI)."""

import os
import re

import pytest

from repro.core import conduit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")


def _read(path):
    with open(path, encoding="utf-8") as f:
        return f.read()


def _doc_files():
    files = [os.path.join(REPO, "DESIGN.md")]
    files += [os.path.join(DOCS, f) for f in sorted(os.listdir(DOCS))
              if f.endswith(".md")]
    return files


# ---------------------------------------------------------------------------
# the support matrix mirrors the registry
# ---------------------------------------------------------------------------


def _parse_matrix():
    """The op × transport table from docs/transports.md.

    Returns (transports, {op: {transport: supported}}).  The table is the
    one whose header row is ``| op | ... |``.
    """
    text = _read(os.path.join(DOCS, "transports.md"))
    lines = [ln.strip() for ln in text.splitlines()]
    header = None
    rows = {}
    for i, ln in enumerate(lines):
        cells = [c.strip() for c in ln.strip("|").split("|")]
        if header is None:
            if ln.startswith("|") and cells[0] == "op":
                header = cells[1:]
            continue
        if not ln.startswith("|"):
            break
        if set(ln) <= {"|", "-", " "}:          # the separator row
            continue
        rows[cells[0]] = {t: c == "✓" for t, c in zip(header, cells[1:])}
    assert header, "no `| op | ...` table found in docs/transports.md"
    return header, rows


class TestSupportMatrix:
    def test_every_documented_pair_is_registered(self):
        transports, rows = _parse_matrix()
        for op, cols in rows.items():
            for t, supported in cols.items():
                if supported:
                    assert conduit.resolve(op, t) is not None, (op, t)

    def test_every_registered_pair_is_documented(self):
        transports, rows = _parse_matrix()
        assert set(rows) == set(conduit.OPS)
        for op in conduit.OPS:
            registered = set(conduit.transports(op)) & set(transports)
            documented = {t for t, ok in rows[op].items() if ok}
            assert documented == registered, (op, documented, registered)

    def test_matrix_lists_core_transports(self):
        transports, _ = _parse_matrix()
        assert set(transports) >= {"xla", "ring", "bidir"}


# ---------------------------------------------------------------------------
# every python block in docs compiles
# ---------------------------------------------------------------------------

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks():
    out = []
    for path in _doc_files():
        for i, m in enumerate(_BLOCK_RE.finditer(_read(path))):
            out.append(pytest.param(
                m.group(1), id=f"{os.path.basename(path)}-{i}"))
    return out


class TestDocSnippets:
    def test_docs_have_snippets(self):
        assert len(_python_blocks()) >= 2

    @pytest.mark.parametrize("src", _python_blocks())
    def test_block_compiles(self, src):
        compile(src, "<doc-snippet>", "exec")


# ---------------------------------------------------------------------------
# the CI docs gate, from the suite
# ---------------------------------------------------------------------------


class TestDocsGate:
    @staticmethod
    def _docs_check():
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "docs_check", os.path.join(REPO, "tools", "docs_check.py"))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_links_and_docstrings(self):
        mod = self._docs_check()
        assert mod.check_links() == []
        assert mod.check_docstrings() == []

    def test_serving_matrix_mirrors_capability_table(self):
        """The arch × serving-feature matrix in docs/serving.md is
        machine-checked against repro.configs.base in both directions —
        here with jax importable, so the check cannot be skipped (the
        no-jax CI docs job skips it by design)."""
        mod = self._docs_check()
        assert mod.check_serving_matrix() == []
        rows = mod._parse_serving_matrix(
            _read(os.path.join(DOCS, "serving.md")))
        from repro.configs import ARCH_NAMES
        assert set(rows) == set(ARCH_NAMES)
