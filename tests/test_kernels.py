"""Per-kernel shape/dtype sweeps, asserted allclose against ref.py oracles
(interpret mode executes the Pallas body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd import ssd
from repro.kernels.ssd.ref import ssd_ref


class TestMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (100, 200, 150), (256, 64, 512), (1, 7, 3),
        (384, 128, 128),
    ])
    def test_shapes(self, m, k, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("act", ["none", "relu", "relu2", "silu", "gelu"])
    def test_fused_activations(self, act):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 96), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (96, 80), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(2), (80,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(matmul(x, w, b, activation=act)),
            np.asarray(matmul_ref(x, w, b, activation=act)),
            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64)).astype(dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 64)).astype(dtype)
        got = matmul(x, w, out_dtype=jnp.float32)
        want = matmul_ref(x, w, out_dtype=jnp.float32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_batched(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 40, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    @given(m=st.integers(1, 300), k=st.integers(1, 260), n=st.integers(1, 200))
    @settings(max_examples=8, deadline=None)
    def test_padding_is_exact(self, m, k, n):
        """Zero-padding to block multiples must not perturb results."""
        x = jax.random.normal(jax.random.PRNGKey(m), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(n), (k, n), jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=3e-5, atol=3e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv", [(128, 128), (100, 100), (64, 256),
                                        (256, 256)])
    def test_causal_shapes(self, sq, skv):
        q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, sq, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, skv, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, skv, 32))
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64, 129])
    def test_sliding_window(self, window):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 200, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 200, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 200, 32))
        got = flash_attention(q, k, v, causal=True, window=window)
        want = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32))
        got = flash_attention(q, k, v, causal=False)
        want = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("group", [1, 2, 4])
    def test_gqa_groups(self, group):
        hkv = 2
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (1, hkv * group, 128, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, hkv, 128, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, hkv, 128, 16))
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)


class TestSSD:
    @pytest.mark.parametrize("s,chunk", [(64, 16), (50, 16), (128, 32),
                                         (17, 8)])
    def test_shapes_vs_ref(self, s, chunk):
        B, H, P, G, N = 2, 4, 16, 2, 8
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, s, H, P))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (B, s, H)))
        a = -jnp.exp(jnp.linspace(0.0, 1.0, H))
        bm = jax.random.normal(jax.random.PRNGKey(2), (B, s, G, N))
        cm = jax.random.normal(jax.random.PRNGKey(3), (B, s, G, N))
        d = jnp.ones((H,))
        y, st_ = ssd(xs, dt, a, bm, cm, d, chunk=chunk)
        yr, sr = ssd_ref(xs, dt, a, bm, cm, d)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(sr),
                                   rtol=2e-3, atol=2e-3)

    def test_decode_step_consistency(self):
        """Sequential decode steps == full-sequence SSD."""
        from repro.kernels.ssd.ref import ssd_decode_step

        B, s, H, P, G, N = 1, 12, 2, 8, 1, 4
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, s, H, P))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (B, s, H)))
        a = -jnp.exp(jnp.linspace(0.0, 0.5, H))
        bm = jax.random.normal(jax.random.PRNGKey(2), (B, s, G, N))
        cm = jax.random.normal(jax.random.PRNGKey(3), (B, s, G, N))
        d = jnp.zeros((H,))
        y_full, state_full = ssd(xs, dt, a, bm, cm, d, chunk=4)

        state = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(s):
            state, y = ssd_decode_step(state, xs[:, t], dt[:, t], a,
                                       bm[:, t], cm[:, t], d)
            ys.append(y)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(state),
                                   np.asarray(state_full),
                                   rtol=2e-3, atol=2e-3)
