"""Per-kernel shape/dtype sweeps, asserted allclose against ref.py oracles
(interpret mode executes the Pallas body on CPU)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.kernels import common
from repro.kernels.cc_matmul import (
    allgather_matmul_pallas,
    allgather_matmul_ref,
    matmul_reducescatter_pallas,
    matmul_reducescatter_ref,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd import ssd, ssd_chunk_fed
from repro.kernels.ssd.ref import ssd_ref


class TestMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (100, 200, 150), (256, 64, 512), (1, 7, 3),
        (384, 128, 128),
    ])
    def test_shapes(self, m, k, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("act", ["none", "relu", "relu2", "silu", "gelu"])
    def test_fused_activations(self, act):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 96), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (96, 80), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(2), (80,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(matmul(x, w, b, activation=act)),
            np.asarray(matmul_ref(x, w, b, activation=act)),
            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64)).astype(dtype)
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 64)).astype(dtype)
        got = matmul(x, w, out_dtype=jnp.float32)
        want = matmul_ref(x, w, out_dtype=jnp.float32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_batched(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 40, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)

    @given(m=st.integers(1, 300), k=st.integers(1, 260), n=st.integers(1, 200))
    @settings(max_examples=8, deadline=None)
    def test_padding_is_exact(self, m, k, n):
        """Zero-padding to block multiples must not perturb results."""
        x = jax.random.normal(jax.random.PRNGKey(m), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(n), (k, n), jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, w)),
                                   np.asarray(matmul_ref(x, w)),
                                   rtol=3e-5, atol=3e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv", [(128, 128), (100, 100), (64, 256),
                                        (256, 256)])
    def test_causal_shapes(self, sq, skv):
        q = jax.random.normal(jax.random.PRNGKey(0), (2, 4, sq, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, skv, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, skv, 32))
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64, 129])
    def test_sliding_window(self, window):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 200, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 200, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 200, 32))
        got = flash_attention(q, k, v, causal=True, window=window)
        want = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 32))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32))
        got = flash_attention(q, k, v, causal=False)
        want = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("group", [1, 2, 4])
    def test_gqa_groups(self, group):
        hkv = 2
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (1, hkv * group, 128, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, hkv, 128, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, hkv, 128, 16))
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = jax.random.normal(jax.random.PRNGKey(0),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2),
                              (1, 2, 128, 32)).astype(jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)


class TestSSD:
    @pytest.mark.parametrize("s,chunk", [(64, 16), (50, 16), (128, 32),
                                         (17, 8)])
    def test_shapes_vs_ref(self, s, chunk):
        B, H, P, G, N = 2, 4, 16, 2, 8
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, s, H, P))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (B, s, H)))
        a = -jnp.exp(jnp.linspace(0.0, 1.0, H))
        bm = jax.random.normal(jax.random.PRNGKey(2), (B, s, G, N))
        cm = jax.random.normal(jax.random.PRNGKey(3), (B, s, G, N))
        d = jnp.ones((H,))
        y, st_ = ssd(xs, dt, a, bm, cm, d, chunk=chunk)
        yr, sr = ssd_ref(xs, dt, a, bm, cm, d)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(sr),
                                   rtol=2e-3, atol=2e-3)

    def test_decode_step_consistency(self):
        """Sequential decode steps == full-sequence SSD."""
        from repro.kernels.ssd.ref import ssd_decode_step

        B, s, H, P, G, N = 1, 12, 2, 8, 1, 4
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, s, H, P))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (B, s, H)))
        a = -jnp.exp(jnp.linspace(0.0, 0.5, H))
        bm = jax.random.normal(jax.random.PRNGKey(2), (B, s, G, N))
        cm = jax.random.normal(jax.random.PRNGKey(3), (B, s, G, N))
        d = jnp.zeros((H,))
        y_full, state_full = ssd(xs, dt, a, bm, cm, d, chunk=4)

        state = jnp.zeros((B, H, N, P))
        ys = []
        for t in range(s):
            state, y = ssd_decode_step(state, xs[:, t], dt[:, t], a,
                                       bm[:, t], cm[:, t], d)
            ys.append(y)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(state),
                                   np.asarray(state_full),
                                   rtol=2e-3, atol=2e-3)


class TestCommonInterpret:
    """kernels/common.py: the one shared interpret-mode policy."""

    def test_env_override_wins(self, monkeypatch):
        monkeypatch.setenv(common.INTERPRET_ENV, "1")
        assert common.should_interpret() is True
        monkeypatch.setenv(common.INTERPRET_ENV, "0")
        assert common.should_interpret() is False
        assert common.supports_remote_dma() is False  # forced interpret

    def test_default_follows_backend(self, monkeypatch):
        monkeypatch.delenv(common.INTERPRET_ENV, raising=False)
        expect = jax.default_backend() == "cpu"
        assert common.should_interpret() is expect

    def test_legacy_alias_survives(self):
        """matmul/ops kept its historical private name as an alias."""
        from repro.kernels.matmul import ops as matmul_ops

        assert matmul_ops._should_interpret is common.should_interpret


def _ring_mesh(n):
    import numpy as _np

    return jax.sharding.Mesh(_np.array(jax.devices()[:n]), ("x",))


def _run_sharded(mesh, fn, args, in_specs, out_spec):
    f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_spec, check_vma=False))
    return np.asarray(f(*args))


class TestCCMatmulAllGather:
    """Fused AG·matmul: allclose vs the lax oracle, bitwise vs overlap.py."""

    @pytest.mark.parametrize("bidir", [False, True])
    @pytest.mark.parametrize("n_ranks", [2, 3, 4])
    @pytest.mark.parametrize("b_loc,k,m", [(8, 16, 32), (6, 24, 40)])
    def test_vs_ref_and_overlap(self, n_ranks, bidir, b_loc, k, m):
        from repro.core import overlap

        mesh = _ring_mesh(n_ranks)
        x = jax.random.normal(jax.random.PRNGKey(0), (n_ranks * b_loc, k))
        w = jax.random.normal(jax.random.PRNGKey(1), (k, m))
        args = (x, w)
        specs = (P("x", None), P(None, None))
        fused = _run_sharded(
            mesh,
            functools.partial(allgather_matmul_pallas, axis="x",
                              bidirectional=bidir),
            args, specs, P(None, None))
        ref = _run_sharded(
            mesh,
            functools.partial(allgather_matmul_ref, axis="x"),
            args, specs, P(None, None))
        streamed = _run_sharded(
            mesh,
            functools.partial(overlap.allgather_matmul, axis="x",
                              bidirectional=bidir),
            args, specs, P(None, None))
        np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(
            fused, streamed,
            err_msg="fused AG schedule must be bit-identical to overlap.py")

    def test_batched_3d(self):
        mesh = _ring_mesh(4)
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 4 * 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
        fused = _run_sharded(
            mesh, functools.partial(allgather_matmul_pallas, axis="x"),
            (x, w), (P(None, "x", None), P(None, None)), P(None, None, None))
        want = np.einsum("bik,kn->bin", np.asarray(x), np.asarray(w))
        np.testing.assert_allclose(fused, want, rtol=1e-4, atol=1e-4)

    def test_grads_match_ref(self):
        mesh = _ring_mesh(4)
        x = jax.random.normal(jax.random.PRNGKey(0), (4 * 8, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 32))

        def loss(fn):
            def inner(x_, w_):
                return jnp.sum(fn(x_, w_) ** 2)

            g = jax.jit(jax.shard_map(
                jax.grad(inner, argnums=(0, 1)), mesh=mesh,
                in_specs=(P("x", None), P(None, None)),
                out_specs=(P("x", None), P(None, None)), check_vma=False))
            return g(x, w)

        gx_f, gw_f = loss(functools.partial(
            allgather_matmul_pallas, axis="x"))
        gx_r, gw_r = loss(functools.partial(
            allgather_matmul_ref, axis="x"))
        np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_r),
                                   rtol=1e-4, atol=1e-4)


class TestCCMatmulReduceScatter:
    """Fused matmul·RS: allclose vs the lax oracle, bitwise vs overlap.py."""

    @pytest.mark.parametrize("bidir", [False, True])
    @pytest.mark.parametrize("n_ranks", [2, 3, 4])
    @pytest.mark.parametrize("b_loc,k,m", [(8, 16, 32), (6, 24, 40)])
    def test_vs_ref_and_overlap(self, n_ranks, bidir, b_loc, k, m):
        from repro.core import overlap

        mesh = _ring_mesh(n_ranks)
        rows = n_ranks * n_ranks * b_loc       # local rows divisible by n
        x = jax.random.normal(jax.random.PRNGKey(2), (rows, k))
        w = jax.random.normal(jax.random.PRNGKey(3), (k, m))
        args = (x, w)
        specs = (P("x", None), P(None, None))
        fused = _run_sharded(
            mesh,
            functools.partial(matmul_reducescatter_pallas, axis="x",
                              bidirectional=bidir),
            args, specs, P("x", None))
        ref = _run_sharded(
            mesh,
            functools.partial(matmul_reducescatter_ref, axis="x"),
            args, specs, P("x", None))
        streamed = _run_sharded(
            mesh,
            functools.partial(overlap.matmul_reducescatter, axis="x",
                              bidirectional=bidir),
            args, specs, P("x", None))
        np.testing.assert_allclose(fused, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(
            fused, streamed,
            err_msg="fused RS schedule must be bit-identical to overlap.py")

    def test_grads_match_ref(self):
        mesh = _ring_mesh(4)
        x = jax.random.normal(jax.random.PRNGKey(2), (4 * 16, 8))
        w = jax.random.normal(jax.random.PRNGKey(3), (8, 12))

        def loss(fn):
            def inner(x_, w_):
                return jnp.sum(fn(x_, w_) ** 2)

            g = jax.jit(jax.shard_map(
                jax.grad(inner, argnums=(0, 1)), mesh=mesh,
                in_specs=(P("x", None), P(None, None)),
                out_specs=(P("x", None), P(None, None)), check_vma=False))
            return g(x, w)

        gx_f, gw_f = loss(functools.partial(
            matmul_reducescatter_pallas, axis="x"))
        gx_r, gw_r = loss(functools.partial(
            matmul_reducescatter_ref, axis="x"))
        np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw_f), np.asarray(gw_r),
                                   rtol=1e-4, atol=1e-4)


class TestSSDChunkFed:
    """Chunk-fed SSD scan: segments streamed in, state carried across."""

    def _inputs(self, s):
        B, H, P_, G, N = 2, 4, 16, 2, 8
        xs = jax.random.normal(jax.random.PRNGKey(0), (B, s, H, P_))
        dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                               (B, s, H)))
        a = -jnp.exp(jnp.linspace(0.0, 1.0, H))
        bm = jax.random.normal(jax.random.PRNGKey(2), (B, s, G, N))
        cm = jax.random.normal(jax.random.PRNGKey(3), (B, s, G, N))
        d = jnp.ones((H,))
        return xs, dt, a, bm, cm, d

    def test_aligned_segments_bitwise(self):
        """Chunk-aligned segment cuts reproduce the bulk scan exactly."""
        xs, dt, a, bm, cm, d = self._inputs(64)
        y0, st0 = ssd(xs, dt, a, bm, cm, d, chunk=16)
        cuts = [(0, 16), (16, 48), (48, 64)]

        def fetch(k):
            lo, hi = cuts[k]
            return xs[:, lo:hi], dt[:, lo:hi], bm[:, lo:hi], cm[:, lo:hi]

        y1, st1 = ssd_chunk_fed(fetch, len(cuts), a, d, chunk=16)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(st0), np.asarray(st1))

    def test_unaligned_segments_allclose(self):
        """Unaligned cuts move chunk boundaries: allclose, state exact-ish."""
        xs, dt, a, bm, cm, d = self._inputs(50)
        y0, st0 = ssd(xs, dt, a, bm, cm, d, chunk=16)
        cuts = [(0, 20), (20, 50)]

        def fetch(k):
            lo, hi = cuts[k]
            return xs[:, lo:hi], dt[:, lo:hi], bm[:, lo:hi], cm[:, lo:hi]

        y1, st1 = ssd_chunk_fed(fetch, len(cuts), a, d, chunk=16)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st0), np.asarray(st1),
                                   rtol=2e-3, atol=2e-3)

    def test_init_state_resumes_scan(self):
        """Seeding init_state continues a previous scan exactly."""
        xs, dt, a, bm, cm, d = self._inputs(32)
        y0, st0 = ssd(xs, dt, a, bm, cm, d, chunk=8)
        _, st_head = ssd(xs[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16],
                         d, chunk=8)
        y_tail, st_tail = ssd(xs[:, 16:], dt[:, 16:], a, bm[:, 16:],
                              cm[:, 16:], d, chunk=8, init_state=st_head)
        np.testing.assert_array_equal(np.asarray(y0[:, 16:]),
                                      np.asarray(y_tail))
        np.testing.assert_array_equal(np.asarray(st0), np.asarray(st_tail))

    def test_layers_binding_bitwise(self):
        """cfg.ssm_stream_segments routes the mamba block through the
        chunk-fed scan, bit-identical to the bulk path."""
        import dataclasses

        from repro.configs import get_config
        from repro.models import layers as L
        from repro.models.model import init_params

        cfg = get_config("mamba2-2.7b").reduced()
        cfg = dataclasses.replace(cfg, attn_impl="pallas")
        params = init_params(cfg, jax.random.PRNGKey(0))
        lp = jax.tree.map(lambda p: p[0], params["layers"])
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (2, 4 * cfg.ssm_chunk + 3, cfg.d_model))
        bulk = L.mamba2_block(cfg, lp["mamba"], x)
        fed = L.mamba2_block(
            dataclasses.replace(cfg, ssm_stream_segments=3),
            lp["mamba"], x)
        np.testing.assert_array_equal(np.asarray(bulk), np.asarray(fed))
