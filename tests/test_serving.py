"""Streamed serving: chunked prefill ≡ bulk (bitwise), EP decode ≡
dense-combine per transport, donation-clean step builders, and the
ring-buffer wraparound properties the scheduler relies on.

The bit-identity discipline (PR 2): a streamed schedule partitions the
bulk payload and runs the identical per-row recipe, so results must be
*bit*-equal, not allclose — asserted here per entry point, odd chunk
sizes and ring wraparound included.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.dist.steps import (
    StepConfig,
    TransportPolicy,
    build_prefill_chunk_step,
    build_prefill_step,
    build_serve_step,
    build_slot_write_step,
)
from repro.models.decode import decode_step, init_cache, kv_buf_len
from repro.models.model import init_params
from repro.models.prefill import (
    init_prefill_scratch,
    prefill,
    prefill_chunk,
    prefill_chunk_cuts,
    prefill_chunked,
    scratch_to_cache,
    supports_chunked_prefill,
)


def _setup(name, **overrides):
    cfg = get_config(name).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _tokens(cfg, b, s, key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)


def _assert_tree_equal(a, b, msg=""):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{msg} leaf {k!r}")


class TestChunkedPrefill:
    """prefill_chunked ≡ prefill, bit for bit — cache and logits."""

    @pytest.mark.parametrize("n_chunks", [2, 3, 5, 13])
    def test_bit_identical_odd_chunks(self, n_chunks):
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 13)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=32)
        cache, logits = prefill_chunked(cfg, params, toks, cache_len=32,
                                        n_chunks=n_chunks)
        _assert_tree_equal(bulk_cache, cache, f"n_chunks={n_chunks}")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_windowed_ring_wraparound(self):
        """Chunk boundaries crossing the SWA ring (sb < S) stay exact."""
        cfg, params = _setup("h2o-danube-1.8b")
        assert cfg.window and cfg.window < 17
        toks = _tokens(cfg, 1, 17)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=17)
        cache, logits = prefill_chunked(cfg, params, toks, cache_len=17,
                                        n_chunks=5)
        assert cache["k"].shape[3] == cfg.window     # ring, not 17
        _assert_tree_equal(bulk_cache, cache, "windowed")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_incremental_scratch_path(self):
        """The server's chunk-step flavor reassembles the bulk cache."""
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 11)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=24)
        scratch = init_prefill_scratch(cfg, 2, 11)
        logits = None
        for lo, hi in prefill_chunk_cuts(11, chunk_len=4):
            scratch, logits = prefill_chunk(cfg, params, scratch,
                                            toks[:, lo:hi], lo)
        cache = scratch_to_cache(cfg, scratch, cache_len=24)
        _assert_tree_equal(bulk_cache, cache, "incremental")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_decode_continues_identically(self):
        """Decoding from a chunked-prefill cache == from the bulk cache."""
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 9)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=4)
        nxt = jnp.argmax(la, -1).astype(jnp.int32)
        ca, la2 = decode_step(cfg, params, ca, nxt)
        cb, lb2 = decode_step(cfg, params, cb, nxt)
        np.testing.assert_array_equal(np.asarray(la2), np.asarray(lb2))

    def test_unsupported_family_falls_back_to_bulk(self):
        cfg, params = _setup("mamba2-2.7b")
        assert not supports_chunked_prefill(cfg)
        toks = _tokens(cfg, 1, 8)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=4)
        _assert_tree_equal(ca, cb, "fallback")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_cuts_partition_exactly(self):
        assert prefill_chunk_cuts(10, chunk_len=4) == [(0, 4), (4, 8),
                                                       (8, 10)]
        for s in (1, 7, 16):
            for c in (1, 3, 5, 20):
                cuts = prefill_chunk_cuts(s, chunk_len=c)
                assert cuts[0][0] == 0 and cuts[-1][1] == s
                assert all(a[1] == b[0] for a, b in zip(cuts, cuts[1:]))


class TestChunkedPrefillStep:
    """The jitted, sharded flavors (dist/steps.py) keep bit-identity."""

    @pytest.mark.parametrize("chunks", [3, 4])
    def test_prefill_step_chunks_bit_identical(self, mesh22, chunks):
        """With a fixed residual sharding (SP off) the chunked and bulk
        jitted programs are bit-identical; SP resharding (seq % tp differs
        per chunk) perturbs GSPMD reduction placement at the float-ulp
        level, so that flavor asserts tightly instead."""
        cfg = get_config("smollm-360m").reduced()
        from repro.dist.steps import build_init
        for sp, exact in ((False, True), (True, False)):
            scfg = StepConfig(sequence_parallel=sp)
            init_fn, _ = build_init(cfg, mesh22, scfg)
            params, _ = init_fn(jax.random.PRNGKey(0))
            toks = _tokens(cfg, 4, 16, key=2)
            bulk = build_prefill_step(cfg, mesh22, scfg, batch=4,
                                      seq_len=16)
            chunked = build_prefill_step(cfg, mesh22, scfg, batch=4,
                                         seq_len=16, chunks=chunks)
            ca, la = bulk.fn(params, toks)
            cb, lb = chunked.fn(params, toks)
            if exact:
                _assert_tree_equal(jax.device_get(ca), jax.device_get(cb),
                                   f"chunks={chunks}")
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb))
            else:
                for k in ca:
                    np.testing.assert_allclose(
                        np.asarray(ca[k]), np.asarray(cb[k]),
                        rtol=1e-5, atol=1e-5, err_msg=k)
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=1e-5, atol=1e-5)

    def test_chunk_step_and_slot_write(self, mesh22):
        """build_prefill_chunk_step + build_slot_write_step reproduce a
        row of the batched cache exactly — against the *sharded* bulk
        prefill step (sharded-vs-unsharded differs by TP partial-sum
        order; SP off fixes the residual sharding across chunk shapes)."""
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig(sequence_parallel=False)
        from repro.dist.sharding import to_shardings
        from repro.dist.steps import build_init
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        prompt = _tokens(cfg, 1, 10, key=3)

        writer = build_slot_write_step(cfg, mesh22, batch=4, max_seq=32)
        cache = jax.jit(lambda: init_cache(cfg, 4, 32),
                        out_shardings=to_shardings(
                            mesh22, writer.in_specs[0]))()

        scratch = None
        logits = None
        for lo, hi in prefill_chunk_cuts(10, chunk_len=4):
            bundle = build_prefill_chunk_step(cfg, mesh22, scfg, batch=1,
                                              prompt_len=10, lo=lo,
                                              chunk_len=hi - lo)
            if scratch is None:
                scratch = jax.jit(
                    lambda: init_prefill_scratch(cfg, 1, 10),
                    out_shardings=to_shardings(mesh22,
                                               bundle.in_specs[1]))()
            scratch, logits = bundle.fn(params, scratch,
                                        prompt[:, lo:hi])
        slot_cache = jax.jit(
            lambda s: scratch_to_cache(cfg, s, cache_len=32),
            out_shardings=to_shardings(mesh22, writer.in_specs[1]))(scratch)
        cache = writer.fn(cache, slot_cache, jnp.int32(2))

        # reference: the sharded bulk prefill step.  The chunk path runs as
        # *separate* jitted programs (per chunk + convert + write), and
        # GSPMD partitions each program's einsum reductions independently,
        # so cross-program equality is ulp-tight, not bitwise (the bitwise
        # claims live in TestChunkedPrefill, same-program).
        ref_bundle = build_prefill_step(cfg, mesh22, scfg, batch=1,
                                        seq_len=10, cache_len=32)
        ref_cache, ref_logits = ref_bundle.fn(params, prompt)
        got = jax.device_get(cache)
        ref = jax.device_get(ref_cache)
        np.testing.assert_allclose(np.asarray(got["k"][:, 2]),
                                   np.asarray(ref["k"][:, 0]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got["slot_pos"][2]),
                                      np.asarray(ref["slot_pos"][0]))
        assert int(got["pos"][2]) == 10
        # untouched rows stay empty
        assert int(got["pos"][0]) == 0
        assert np.all(np.asarray(got["slot_pos"][0]) == -1)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits),
                                   rtol=1e-5, atol=1e-6)


class TestPerSlotDecode:
    """Per-slot positions: cache rows advance independently."""

    def test_rows_decode_at_different_positions(self):
        """A batch whose rows were prefilled to different lengths decodes
        each row exactly as its own single-request run."""
        cfg, params = _setup("smollm-360m")
        pa = _tokens(cfg, 1, 5, key=4)
        pb = _tokens(cfg, 1, 9, key=5)
        ca, la = prefill(cfg, params, pa, cache_len=16)
        cb, lb = prefill(cfg, params, pb, cache_len=16)
        # merge the two single-request caches into one 2-row cache
        merged = {}
        for k in ca:
            ax = 0 if k in ("pos", "slot_pos") else 1
            merged[k] = jnp.concatenate([ca[k], cb[k]], axis=ax)
        toks = jnp.concatenate([jnp.argmax(la, -1),
                                jnp.argmax(lb, -1)]).astype(jnp.int32)
        for _ in range(3):
            merged, lm = decode_step(cfg, params, merged, toks)
            ca, la1 = decode_step(cfg, params, ca, toks[:1])
            cb, lb1 = decode_step(cfg, params, cb, toks[1:])
            assert np.asarray(merged["pos"]).tolist() == \
                [int(ca["pos"][0]), int(cb["pos"][0])]
            toks = jnp.argmax(lm, -1).astype(jnp.int32)
            # batched rows match the single-request argmax choices
            assert int(toks[0]) == int(jnp.argmax(la1, -1)[0])
            assert int(toks[1]) == int(jnp.argmax(lb1, -1)[0])


EP_TRANSPORTS = ("ring", "bidir", "auto")


class TestEPDecode:
    """Latency-mode EP decode over the conduit all_to_all."""

    def _mesh_ep(self):
        return jax.make_mesh((4,), ("expert",),
                             axis_types=(jax.sharding.AxisType.Auto,))

    def _setup_grok(self, mesh):
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = get_config("grok-1-314b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        return cfg, params

    def _decode_logits(self, cfg, params, mesh, transport, steps=2):
        scfg = StepConfig(transport=TransportPolicy(moe=transport))
        bundle = build_serve_step(cfg, mesh, scfg, batch=4, max_seq=32)
        from repro.dist.sharding import to_shardings
        cache = jax.jit(lambda: init_cache(cfg, 4, 32),
                        out_shardings=to_shardings(
                            mesh, bundle.in_specs[1]))()
        toks = jnp.asarray([1, 7, 3, 5], jnp.int32)
        for _ in range(steps):
            cache, logits = bundle.fn(params, cache, toks)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.asarray(logits)

    def test_ep_decode_matches_dense_combine(self):
        mesh = self._mesh_ep()
        cfg, params = self._setup_grok(mesh)
        dense = self._decode_logits(cfg, params, mesh, "xla")
        ep = self._decode_logits(cfg, params, mesh, "ring")
        np.testing.assert_allclose(ep, dense, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("transport", ["bidir", "auto"])
    def test_ep_decode_bitwise_across_transports(self, transport):
        """Per PR-2 discipline: every conduit transport carries the same
        payload — EP decode results are bit-identical across them."""
        mesh = self._mesh_ep()
        cfg, params = self._setup_grok(mesh)
        ref = self._decode_logits(cfg, params, mesh, "ring")
        got = self._decode_logits(cfg, params, mesh, transport)
        np.testing.assert_array_equal(got, ref)

    def test_indivisible_batch_keeps_dense_combine(self, mesh22):
        """Without a usable expert axis (or batch), the serve step keeps
        the dense-combine fallback and still runs."""
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = get_config("grok-1-314b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh22, param_pspecs(cfg, mesh22, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        scfg = StepConfig(transport=TransportPolicy(moe="ring"))
        bundle = build_serve_step(cfg, mesh22, scfg, batch=3, max_seq=16)
        cache = jax.jit(lambda: init_cache(cfg, 3, 16),
                        out_shardings=to_shardings(
                            mesh22, bundle.in_specs[1]))()
        cache, logits = bundle.fn(params, cache,
                                  jnp.asarray([1, 2, 3], jnp.int32))
        assert logits.shape == (3, cfg.vocab_size)


class TestFrontendServing:
    def test_vlm_requests_carry_embeds(self, mesh22):
        """Frontend (vlm) archs serve through real per-slot prefill with
        per-request embeddings (bulk admission; the chunk path is
        text-only)."""
        from repro.dist.sharding import param_pspecs, to_shardings
        from repro.runtime.server import Server, ServerConfig
        cfg = get_config("internvl2-2b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh22, param_pspecs(cfg, mesh22, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        srv = Server(cfg, params, mesh22, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=2))
        rng = np.random.default_rng(0)
        for _ in range(2):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6),
                       frontend_embeds=rng.normal(
                           size=(cfg.frontend_tokens, cfg.frontend_dim)))
        srv.run()
        assert len(srv.done) == 2
        assert all(len(r.out_tokens) == 2 for r in srv.done)
        with pytest.raises(AssertionError):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6))


class TestSampledServeStep:
    def test_sample_ids_equal_argmax_logits(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig()
        from repro.dist.sharding import to_shardings
        from repro.dist.steps import build_init
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        logit_b = build_serve_step(cfg, mesh22, scfg, batch=4, max_seq=16)
        sample_b = build_serve_step(cfg, mesh22, scfg, batch=4,
                                    max_seq=16, sample=True)
        toks = jnp.asarray([3, 1, 4, 1], jnp.int32)
        c1 = jax.jit(lambda: init_cache(cfg, 4, 16),
                     out_shardings=to_shardings(
                         mesh22, logit_b.in_specs[1]))()
        c2 = jax.jit(lambda: init_cache(cfg, 4, 16),
                     out_shardings=to_shardings(
                         mesh22, sample_b.in_specs[1]))()
        c1, logits = logit_b.fn(params, c1, toks)
        c2, ids = sample_b.fn(params, c2, toks)
        assert ids.dtype == jnp.int32 and ids.shape == (4,)
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(jnp.argmax(logits, -1)))
        _assert_tree_equal(jax.device_get(c1), jax.device_get(c2),
                           "sampled step cache")


class TestRingBufferProperties:
    """Hypothesis: slot_pos masking exactly at and across the window
    boundary, and chunked ≡ bulk across drawn odd chunk sizes."""

    @settings(max_examples=12, deadline=None)
    @given(s=st.integers(1, 20), d=st.integers(0, 6))
    def test_slot_pos_tracks_last_sb_positions(self, s, d):
        """After prefilling ``s`` tokens and decoding ``d`` more, the ring
        holds exactly the last ``min(pos, sb)`` positions — wraparound at
        and across the ``window`` boundary included."""
        cfg, params = _setup("h2o-danube-1.8b")
        sb = kv_buf_len(cfg, 24)
        toks = _tokens(cfg, 1, s + d + 1, key=6)
        cache, _ = prefill(cfg, params, toks[:, :s], cache_len=24)
        for t in range(d):
            cache, _ = decode_step(cfg, params, cache, toks[:, s + t])
        pos = s + d
        slot_pos = np.asarray(cache["slot_pos"][0])
        expect = np.full((sb,), -1, np.int64)
        for p in range(max(0, pos - sb), pos):
            expect[p % sb] = p
        np.testing.assert_array_equal(slot_pos, expect)

    @settings(max_examples=8, deadline=None)
    @given(s=st.integers(2, 14), n=st.integers(2, 7))
    def test_chunked_equals_bulk_drawn_sizes(self, s, n):
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 1, s, key=100 + s)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=n)
        _assert_tree_equal(ca, cb, f"s={s} n={n}")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_window_masks_exactly_at_boundary(self):
        """A key exactly ``window`` back is masked; ``window−1`` back is
        visible (the ``slot_pos > pos − window`` edge)."""
        from repro.models.decode import _valid_slots
        w = 4
        pos = jnp.asarray([10])
        slot_pos = jnp.asarray([[6, 7, 8, 9, 10, -1]])
        valid = np.asarray(_valid_slots(slot_pos, pos, w)[0])
        # pos-w = 6 masked (> is strict), 7..10 visible, empty masked
        assert valid.tolist() == [False, True, True, True, True, False]
