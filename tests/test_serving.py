"""Streamed serving: chunked prefill ≡ bulk (bitwise), EP decode ≡
dense-combine per transport, donation-clean step builders, and the
ring-buffer wraparound properties the scheduler relies on.

The bit-identity discipline (PR 2): a streamed schedule partitions the
bulk payload and runs the identical per-row recipe, so results must be
*bit*-equal, not allclose — asserted here per entry point, odd chunk
sizes and ring wraparound included.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.dist.steps import (
    StepConfig,
    TransportPolicy,
    build_prefill_chunk_step,
    build_prefill_step,
    build_serve_step,
    build_slot_write_step,
)
from repro.models.decode import (
    decode_step,
    init_cache,
    init_paged_cache,
    kv_buf_len,
    paged_slot_blocks,
    supports_paged,
)
from repro.models.model import init_params
from repro.models.prefill import (
    cache_to_blocks,
    chunk_support,
    init_prefill_scratch,
    prefill,
    prefill_chunk,
    prefill_chunk_cuts,
    prefill_chunked,
    scratch_to_cache,
    supports_chunked_prefill,
)
from repro.runtime.server import BlockPool, Server, ServerConfig


def _setup(name, **overrides):
    cfg = get_config(name).reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _tokens(cfg, b, s, key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0,
                              cfg.vocab_size)


def _assert_tree_equal(a, b, msg=""):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{msg} leaf {k!r}")


class TestChunkedPrefill:
    """prefill_chunked ≡ prefill, bit for bit — cache and logits."""

    @pytest.mark.parametrize("n_chunks", [2, 3, 5, 13])
    def test_bit_identical_odd_chunks(self, n_chunks):
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 13)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=32)
        cache, logits = prefill_chunked(cfg, params, toks, cache_len=32,
                                        n_chunks=n_chunks)
        _assert_tree_equal(bulk_cache, cache, f"n_chunks={n_chunks}")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_windowed_ring_wraparound(self):
        """Chunk boundaries crossing the SWA ring (sb < S) stay exact."""
        cfg, params = _setup("h2o-danube-1.8b")
        assert cfg.window and cfg.window < 17
        toks = _tokens(cfg, 1, 17)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=17)
        cache, logits = prefill_chunked(cfg, params, toks, cache_len=17,
                                        n_chunks=5)
        assert cache["k"].shape[3] == cfg.window     # ring, not 17
        _assert_tree_equal(bulk_cache, cache, "windowed")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_incremental_scratch_path(self):
        """The server's chunk-step flavor reassembles the bulk cache."""
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 11)
        bulk_cache, bulk_logits = prefill(cfg, params, toks, cache_len=24)
        scratch = init_prefill_scratch(cfg, 2, 11)
        logits = None
        for lo, hi in prefill_chunk_cuts(11, chunk_len=4):
            scratch, logits = prefill_chunk(cfg, params, scratch,
                                            toks[:, lo:hi], lo)
        cache = scratch_to_cache(cfg, scratch, cache_len=24)
        _assert_tree_equal(bulk_cache, cache, "incremental")
        np.testing.assert_array_equal(np.asarray(bulk_logits),
                                      np.asarray(logits))

    def test_decode_continues_identically(self):
        """Decoding from a chunked-prefill cache == from the bulk cache."""
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 2, 9)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=4)
        nxt = jnp.argmax(la, -1).astype(jnp.int32)
        ca, la2 = decode_step(cfg, params, ca, nxt)
        cb, lb2 = decode_step(cfg, params, cb, nxt)
        np.testing.assert_array_equal(np.asarray(la2), np.asarray(lb2))

    def test_pallas_attn_gated_falls_back_to_bulk(self):
        """A forced fused-attention (pallas) impl can't take the chunk
        path's mid-sequence ``q_offset``, so the gate names that reason
        and ``prefill_chunked`` falls back to bulk — while pure-SSM archs
        chunk under *any* impl (their carry is SSD state, not
        attention)."""
        cfg, params = _setup("smollm-360m", attn_impl="pallas")
        ok, why = chunk_support(cfg)
        assert not ok and "pallas" in why
        assert not supports_chunked_prefill(cfg)
        assert supports_chunked_prefill(get_config("mamba2-2.7b").reduced())
        toks = _tokens(cfg, 1, 8)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=4)
        _assert_tree_equal(ca, cb, "fallback")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_cuts_partition_exactly(self):
        assert prefill_chunk_cuts(10, chunk_len=4) == [(0, 4), (4, 8),
                                                       (8, 10)]
        for s in (1, 7, 16):
            for c in (1, 3, 5, 20):
                cuts = prefill_chunk_cuts(s, chunk_len=c)
                assert cuts[0][0] == 0 and cuts[-1][1] == s
                assert all(a[1] == b[0] for a, b in zip(cuts, cuts[1:]))


class TestChunkedPrefillStep:
    """The jitted, sharded flavors (dist/steps.py) keep bit-identity."""

    @pytest.mark.parametrize("chunks", [3, 4])
    def test_prefill_step_chunks_bit_identical(self, mesh22, chunks):
        """With a fixed residual sharding (SP off) the chunked and bulk
        jitted programs are bit-identical; SP resharding (seq % tp differs
        per chunk) perturbs GSPMD reduction placement at the float-ulp
        level, so that flavor asserts tightly instead."""
        cfg = get_config("smollm-360m").reduced()
        from repro.dist.steps import build_init
        for sp, exact in ((False, True), (True, False)):
            scfg = StepConfig(sequence_parallel=sp)
            init_fn, _ = build_init(cfg, mesh22, scfg)
            params, _ = init_fn(jax.random.PRNGKey(0))
            toks = _tokens(cfg, 4, 16, key=2)
            bulk = build_prefill_step(cfg, mesh22, scfg, batch=4,
                                      seq_len=16)
            chunked = build_prefill_step(cfg, mesh22, scfg, batch=4,
                                         seq_len=16, chunks=chunks)
            ca, la = bulk.fn(params, toks)
            cb, lb = chunked.fn(params, toks)
            if exact:
                _assert_tree_equal(jax.device_get(ca), jax.device_get(cb),
                                   f"chunks={chunks}")
                np.testing.assert_array_equal(np.asarray(la),
                                              np.asarray(lb))
            else:
                for k in ca:
                    np.testing.assert_allclose(
                        np.asarray(ca[k]), np.asarray(cb[k]),
                        rtol=1e-5, atol=1e-5, err_msg=k)
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=1e-5, atol=1e-5)

    def test_chunk_step_and_slot_write(self, mesh22):
        """build_prefill_chunk_step + build_slot_write_step reproduce a
        row of the batched cache exactly — against the *sharded* bulk
        prefill step (sharded-vs-unsharded differs by TP partial-sum
        order; SP off fixes the residual sharding across chunk shapes)."""
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig(sequence_parallel=False)
        from repro.dist.sharding import to_shardings
        from repro.dist.steps import build_init
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        prompt = _tokens(cfg, 1, 10, key=3)

        writer = build_slot_write_step(cfg, mesh22, batch=4, max_seq=32)
        cache = jax.jit(lambda: init_cache(cfg, 4, 32),
                        out_shardings=to_shardings(
                            mesh22, writer.in_specs[0]))()

        scratch = None
        logits = None
        for lo, hi in prefill_chunk_cuts(10, chunk_len=4):
            bundle = build_prefill_chunk_step(cfg, mesh22, scfg, batch=1,
                                              prompt_len=10, lo=lo,
                                              chunk_len=hi - lo)
            if scratch is None:
                scratch = jax.jit(
                    lambda: init_prefill_scratch(cfg, 1, 10),
                    out_shardings=to_shardings(mesh22,
                                               bundle.in_specs[1]))()
            scratch, logits = bundle.fn(params, scratch,
                                        prompt[:, lo:hi])
        slot_cache = jax.jit(
            lambda s: scratch_to_cache(cfg, s, cache_len=32),
            out_shardings=to_shardings(mesh22, writer.in_specs[1]))(scratch)
        cache = writer.fn(cache, slot_cache, jnp.int32(2))

        # reference: the sharded bulk prefill step.  The chunk path runs as
        # *separate* jitted programs (per chunk + convert + write), and
        # GSPMD partitions each program's einsum reductions independently,
        # so cross-program equality is ulp-tight, not bitwise (the bitwise
        # claims live in TestChunkedPrefill, same-program).
        ref_bundle = build_prefill_step(cfg, mesh22, scfg, batch=1,
                                        seq_len=10, cache_len=32)
        ref_cache, ref_logits = ref_bundle.fn(params, prompt)
        got = jax.device_get(cache)
        ref = jax.device_get(ref_cache)
        np.testing.assert_allclose(np.asarray(got["k"][:, 2]),
                                   np.asarray(ref["k"][:, 0]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got["slot_pos"][2]),
                                      np.asarray(ref["slot_pos"][0]))
        assert int(got["pos"][2]) == 10
        # untouched rows stay empty
        assert int(got["pos"][0]) == 0
        assert np.all(np.asarray(got["slot_pos"][0]) == -1)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits),
                                   rtol=1e-5, atol=1e-6)


class TestPerSlotDecode:
    """Per-slot positions: cache rows advance independently."""

    def test_rows_decode_at_different_positions(self):
        """A batch whose rows were prefilled to different lengths decodes
        each row exactly as its own single-request run."""
        cfg, params = _setup("smollm-360m")
        pa = _tokens(cfg, 1, 5, key=4)
        pb = _tokens(cfg, 1, 9, key=5)
        ca, la = prefill(cfg, params, pa, cache_len=16)
        cb, lb = prefill(cfg, params, pb, cache_len=16)
        # merge the two single-request caches into one 2-row cache
        merged = {}
        for k in ca:
            ax = 0 if k in ("pos", "slot_pos") else 1
            merged[k] = jnp.concatenate([ca[k], cb[k]], axis=ax)
        toks = jnp.concatenate([jnp.argmax(la, -1),
                                jnp.argmax(lb, -1)]).astype(jnp.int32)
        for _ in range(3):
            merged, lm = decode_step(cfg, params, merged, toks)
            ca, la1 = decode_step(cfg, params, ca, toks[:1])
            cb, lb1 = decode_step(cfg, params, cb, toks[1:])
            assert np.asarray(merged["pos"]).tolist() == \
                [int(ca["pos"][0]), int(cb["pos"][0])]
            toks = jnp.argmax(lm, -1).astype(jnp.int32)
            # batched rows match the single-request argmax choices
            assert int(toks[0]) == int(jnp.argmax(la1, -1)[0])
            assert int(toks[1]) == int(jnp.argmax(lb1, -1)[0])


EP_TRANSPORTS = ("ring", "bidir", "auto")


class TestEPDecode:
    """Latency-mode EP decode over the conduit all_to_all."""

    def _mesh_ep(self):
        return jax.make_mesh((4,), ("expert",),
                             axis_types=(jax.sharding.AxisType.Auto,))

    def _setup_grok(self, mesh):
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = get_config("grok-1-314b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        return cfg, params

    def _decode_logits(self, cfg, params, mesh, transport, steps=2):
        scfg = StepConfig(transport=TransportPolicy(moe=transport))
        bundle = build_serve_step(cfg, mesh, scfg, batch=4, max_seq=32)
        from repro.dist.sharding import to_shardings
        cache = jax.jit(lambda: init_cache(cfg, 4, 32),
                        out_shardings=to_shardings(
                            mesh, bundle.in_specs[1]))()
        toks = jnp.asarray([1, 7, 3, 5], jnp.int32)
        for _ in range(steps):
            cache, logits = bundle.fn(params, cache, toks)
            toks = jnp.argmax(logits, -1).astype(jnp.int32)
        return np.asarray(logits)

    def test_ep_decode_matches_dense_combine(self):
        mesh = self._mesh_ep()
        cfg, params = self._setup_grok(mesh)
        dense = self._decode_logits(cfg, params, mesh, "xla")
        ep = self._decode_logits(cfg, params, mesh, "ring")
        np.testing.assert_allclose(ep, dense, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("transport", ["bidir", "auto"])
    def test_ep_decode_bitwise_across_transports(self, transport):
        """Per PR-2 discipline: every conduit transport carries the same
        payload — EP decode results are bit-identical across them."""
        mesh = self._mesh_ep()
        cfg, params = self._setup_grok(mesh)
        ref = self._decode_logits(cfg, params, mesh, "ring")
        got = self._decode_logits(cfg, params, mesh, transport)
        np.testing.assert_array_equal(got, ref)

    def test_indivisible_batch_keeps_dense_combine(self, mesh22):
        """Without a usable expert axis (or batch), the serve step keeps
        the dense-combine fallback and still runs."""
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = get_config("grok-1-314b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh22, param_pspecs(cfg, mesh22, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        scfg = StepConfig(transport=TransportPolicy(moe="ring"))
        bundle = build_serve_step(cfg, mesh22, scfg, batch=3, max_seq=16)
        cache = jax.jit(lambda: init_cache(cfg, 3, 16),
                        out_shardings=to_shardings(
                            mesh22, bundle.in_specs[1]))()
        cache, logits = bundle.fn(params, cache,
                                  jnp.asarray([1, 2, 3], jnp.int32))
        assert logits.shape == (3, cfg.vocab_size)


class TestFrontendServing:
    def test_vlm_requests_carry_embeds(self, mesh22):
        """Frontend (vlm) archs serve through real per-slot prefill with
        per-request embeddings (bulk admission here; the chunked flavor
        is covered zoo-wide by tests/test_zoo.py)."""
        from repro.dist.sharding import param_pspecs, to_shardings
        from repro.runtime.server import Server, ServerConfig
        cfg = get_config("internvl2-2b").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh22, param_pspecs(cfg, mesh22, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        srv = Server(cfg, params, mesh22, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=2))
        rng = np.random.default_rng(0)
        for _ in range(2):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6),
                       frontend_embeds=rng.normal(
                           size=(cfg.frontend_tokens, cfg.frontend_dim)))
        srv.run()
        assert len(srv.done) == 2
        assert all(len(r.out_tokens) == 2 for r in srv.done)
        with pytest.raises(AssertionError):
            srv.submit(rng.integers(0, cfg.vocab_size, size=6))


class TestSampledServeStep:
    def test_sample_ids_equal_argmax_logits(self, mesh22):
        cfg = get_config("smollm-360m").reduced()
        scfg = StepConfig()
        from repro.dist.sharding import to_shardings
        from repro.dist.steps import build_init
        init_fn, _ = build_init(cfg, mesh22, scfg)
        params, _ = init_fn(jax.random.PRNGKey(0))
        logit_b = build_serve_step(cfg, mesh22, scfg, batch=4, max_seq=16)
        sample_b = build_serve_step(cfg, mesh22, scfg, batch=4,
                                    max_seq=16, sample=True)
        toks = jnp.asarray([3, 1, 4, 1], jnp.int32)
        c1 = jax.jit(lambda: init_cache(cfg, 4, 16),
                     out_shardings=to_shardings(
                         mesh22, logit_b.in_specs[1]))()
        c2 = jax.jit(lambda: init_cache(cfg, 4, 16),
                     out_shardings=to_shardings(
                         mesh22, sample_b.in_specs[1]))()
        c1, logits = logit_b.fn(params, c1, toks)
        c2, ids = sample_b.fn(params, c2, toks)
        assert ids.dtype == jnp.int32 and ids.shape == (4,)
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(jnp.argmax(logits, -1)))
        _assert_tree_equal(jax.device_get(c1), jax.device_get(c2),
                           "sampled step cache")


class TestRingBufferProperties:
    """Hypothesis: slot_pos masking exactly at and across the window
    boundary, and chunked ≡ bulk across drawn odd chunk sizes."""

    @settings(max_examples=12, deadline=None)
    @given(s=st.integers(1, 20), d=st.integers(0, 6))
    def test_slot_pos_tracks_last_sb_positions(self, s, d):
        """After prefilling ``s`` tokens and decoding ``d`` more, the ring
        holds exactly the last ``min(pos, sb)`` positions — wraparound at
        and across the ``window`` boundary included."""
        cfg, params = _setup("h2o-danube-1.8b")
        sb = kv_buf_len(cfg, 24)
        toks = _tokens(cfg, 1, s + d + 1, key=6)
        cache, _ = prefill(cfg, params, toks[:, :s], cache_len=24)
        for t in range(d):
            cache, _ = decode_step(cfg, params, cache, toks[:, s + t])
        pos = s + d
        slot_pos = np.asarray(cache["slot_pos"][0])
        expect = np.full((sb,), -1, np.int64)
        for p in range(max(0, pos - sb), pos):
            expect[p % sb] = p
        np.testing.assert_array_equal(slot_pos, expect)

    @settings(max_examples=8, deadline=None)
    @given(s=st.integers(2, 14), n=st.integers(2, 7))
    def test_chunked_equals_bulk_drawn_sizes(self, s, n):
        cfg, params = _setup("smollm-360m")
        toks = _tokens(cfg, 1, s, key=100 + s)
        ca, la = prefill(cfg, params, toks, cache_len=16)
        cb, lb = prefill_chunked(cfg, params, toks, cache_len=16,
                                 n_chunks=n)
        _assert_tree_equal(ca, cb, f"s={s} n={n}")
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_window_masks_exactly_at_boundary(self):
        """A key exactly ``window`` back is masked; ``window−1`` back is
        visible (the ``slot_pos > pos − window`` edge)."""
        from repro.models.decode import _valid_slots
        w = 4
        pos = jnp.asarray([10])
        slot_pos = jnp.asarray([[6, 7, 8, 9, 10, -1]])
        valid = np.asarray(_valid_slots(slot_pos, pos, w)[0])
        # pos-w = 6 masked (> is strict), 7..10 visible, empty masked
        assert valid.tolist() == [False, True, True, True, True, False]


def _install_contiguous(cache, slot_cache, i):
    """Write a batch-1 ring cache into row ``i`` of a batched cache."""
    out = dict(cache)
    for k in ("k", "v"):
        out[k] = cache[k].at[:, i].set(slot_cache[k][:, 0])
    out["slot_pos"] = cache["slot_pos"].at[i].set(slot_cache["slot_pos"][0])
    out["pos"] = cache["pos"].at[i].set(slot_cache["pos"][0])
    return out


def _install_paged(cache, blocks, i, dst):
    """Deposit a slot's blocks at pool ids ``dst`` and map row ``i``."""
    bk, bv, slot_pos_row, pos_row = blocks
    out = dict(cache)
    dst = jnp.asarray(dst, jnp.int32)
    out["kp"] = cache["kp"].at[:, dst].set(bk)
    out["vp"] = cache["vp"].at[:, dst].set(bv)
    out["block_ids"] = cache["block_ids"].at[i].set(dst)
    out["slot_pos"] = cache["slot_pos"].at[i].set(slot_pos_row)
    out["pos"] = cache["pos"].at[i].set(pos_row)
    return out


class TestPagedDecode:
    """Paged decode ≡ contiguous decode, bitwise on every active row.

    The gather of the block table reconstructs *exactly* the contiguous
    ring layout (``block_size`` divides ``kv_buf_len``), so active-row
    logits must be bit-equal — across block sizes, SWA ring wraparound,
    and shared-prefix block aliasing.  Idle rows park on a private
    reserved block and are excluded: their garbage internals diverge by
    design and their outputs are never read.
    """

    def _decode_pair(self, cfg, params, cont, paged, rows, steps=6):
        """Decode both caches in lockstep; assert bitwise equality on
        ``rows`` each step and feed the (identical) argmax back in."""
        batch = cont["pos"].shape[0]
        toks = jnp.zeros((batch,), jnp.int32)
        for t in range(steps):
            cont, la = decode_step(cfg, params, cont, toks)
            paged, lb = decode_step(cfg, params, paged, toks)
            for r in rows:
                np.testing.assert_array_equal(
                    np.asarray(la[r]), np.asarray(lb[r]),
                    err_msg=f"row {r} step {t}")
            np.testing.assert_array_equal(
                np.asarray(cont["slot_pos"])[list(rows)],
                np.asarray(paged["slot_pos"])[list(rows)])
            nxt = np.zeros((batch,), np.int32)
            for r in rows:
                nxt[r] = int(jnp.argmax(la[r]))
            toks = jnp.asarray(nxt)
        return cont, paged

    @pytest.mark.parametrize("blk", [2, 8])
    def test_bit_identical_across_block_sizes(self, blk):
        cfg, params = _setup("smollm-360m")
        assert supports_paged(cfg)
        max_seq = 16
        npb = paged_slot_blocks(cfg, max_seq, blk)
        slot_cache, _ = prefill(cfg, params, _tokens(cfg, 1, 7, key=7),
                                cache_len=max_seq)
        cont = _install_contiguous(init_cache(cfg, 2, max_seq),
                                   slot_cache, 1)
        paged = _install_paged(
            init_paged_cache(cfg, 2, max_seq, blk, 2 + npb),
            cache_to_blocks(cfg, slot_cache, blk), 1,
            list(range(2, 2 + npb)))
        self._decode_pair(cfg, params, cont, paged, rows=(1,))

    @pytest.mark.parametrize("blk", [2, 4])
    def test_windowed_ring_wraparound(self, blk):
        """Decode past the SWA ring extent: the write slot wraps back to
        block 0 of the slot's table and stays bit-identical."""
        cfg, params = _setup("h2o-danube-1.8b")
        sb = kv_buf_len(cfg, 24)
        npb = paged_slot_blocks(cfg, 24, blk)
        slot_cache, _ = prefill(cfg, params, _tokens(cfg, 1, 6, key=8),
                                cache_len=24)
        cont = _install_contiguous(init_cache(cfg, 2, 24), slot_cache, 1)
        paged = _install_paged(
            init_paged_cache(cfg, 2, 24, blk, 2 + npb),
            cache_to_blocks(cfg, slot_cache, blk), 1,
            list(range(2, 2 + npb)))
        cont, paged = self._decode_pair(cfg, params, cont, paged,
                                        rows=(1,), steps=sb)
        assert int(cont["pos"][1]) > sb      # the ring actually wrapped

    def test_shared_prefix_aliasing(self):
        """Two rows whose tables alias the same (read-only) prefix block
        but own private tails decode bit-identically to two full
        contiguous copies — the COW invariant of the prefix cache."""
        cfg, params = _setup("smollm-360m")
        blk, max_seq = 4, 16
        npb = paged_slot_blocks(cfg, max_seq, blk)
        slot_cache, _ = prefill(cfg, params, _tokens(cfg, 1, 6, key=9),
                                cache_len=max_seq)
        blocks = cache_to_blocks(cfg, slot_cache, blk)
        cont = init_cache(cfg, 3, max_seq)
        cont = _install_contiguous(cont, slot_cache, 1)
        cont = _install_contiguous(cont, slot_cache, 2)
        # block 3 holds positions [0, 4): shared; tails 4.. are private
        paged = init_paged_cache(cfg, 3, max_seq, blk, 3 + 2 * npb - 1)
        paged = _install_paged(paged, blocks, 1,
                               [3] + list(range(4, 3 + npb)))
        paged = _install_paged(paged, blocks, 2,
                               [3] + list(range(3 + npb, 2 + 2 * npb)))
        # feed *different* tokens per row so the rows diverge while the
        # shared block keeps being read by both
        toks = jnp.zeros((3,), jnp.int32)
        for t in range(5):
            cont, la = decode_step(cfg, params, cont, toks)
            paged, lb = decode_step(cfg, params, paged, toks)
            np.testing.assert_array_equal(np.asarray(la[1:]),
                                          np.asarray(lb[1:]),
                                          err_msg=f"step {t}")
            nxt = np.zeros((3,), np.int32)
            nxt[1] = int(jnp.argmax(la[1]))
            nxt[2] = int(jnp.argmin(la[2])) % cfg.vocab_size
            toks = jnp.asarray(nxt)
        # the shared prefix block was never written by either row
        np.testing.assert_array_equal(np.asarray(paged["kp"][:, 3]),
                                      np.asarray(blocks[0][:, 0]))


class TestBlockPool:
    """Host-side pool allocator: no double-free, no aliasing, and
    free + live == n_blocks − reserved under arbitrary op sequences."""

    def test_double_free_raises(self):
        pool = BlockPool(8, reserved=2)
        bids = pool.alloc(3)
        pool.release(bids)
        with pytest.raises(ValueError):
            pool.release(bids)
        pool.check_conservation()

    def test_alloc_never_returns_reserved_or_live(self):
        pool = BlockPool(10, reserved=3)
        a = pool.alloc(4)
        b = pool.alloc(3)
        assert not set(a) & set(b)
        assert all(bid >= 3 for bid in a + b)
        with pytest.raises(MemoryError):
            pool.alloc(1)           # 7 usable, 7 live
        pool.check_conservation()

    def test_eviction_under_pressure(self):
        """Allocation pressure evicts LRU cache entries (entry refs
        only — request-held blocks always survive) before failing."""
        pool = BlockPool(10, reserved=2)
        a = pool.alloc(4)
        pool.cache_insert(b"p1", a[:2])
        pool.release(a)             # entry still pins a[:2]
        assert pool.free_blocks == 6 and pool.cached_entries == 1
        held = pool.alloc(2)        # no pressure: entry survives
        assert pool.cached_entries == 1
        big = pool.alloc(6)         # needs the pinned pair -> evict
        assert pool.evictions == 1 and pool.cached_entries == 0
        assert len(big) == 6 and not set(big) & set(held)
        pool.check_conservation()
        with pytest.raises(MemoryError):
            pool.alloc(1)           # held blocks were NOT reclaimed
        pool.release(held + big)
        pool.check_conservation()

    def test_lookup_retains_and_refreshes_lru(self):
        pool = BlockPool(12, reserved=0)
        a, b = pool.alloc(2), pool.alloc(2)
        pool.cache_insert(b"a", a)
        pool.cache_insert(b"b", b)
        pool.release(a)
        pool.release(b)
        got = pool.cache_lookup(b"a")       # refreshes "a"; caller ref
        assert got == a
        pool.alloc(10)                      # pressure evicts "b" first
        assert pool.cache_lookup(b"b") is None
        assert pool.cache_lookup(b"a") == a     # still resident (held)
        pool.check_conservation()

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "release", "insert", "lookup"]),
                  st.integers(0, 7)), max_size=40))
    def test_random_op_sequences_conserve(self, ops):
        pool = BlockPool(16, reserved=3)
        held = []                   # groups we hold a ref on
        keys = []
        for step, (op, k) in enumerate(ops):
            if op == "alloc":
                try:
                    bids = pool.alloc(k % 5 + 1)
                except MemoryError:
                    pool.check_conservation()
                    continue
                # freshly allocated blocks alias nothing we hold
                flat = {b for grp in held for b in grp}
                assert not set(bids) & flat
                assert all(b >= 3 for b in bids)
                held.append(bids)
            elif op == "release" and held:
                pool.release(held.pop(k % len(held)))
            elif op == "insert" and held:
                key = f"k{step}".encode()
                pool.cache_insert(key, held[k % len(held)])
                keys.append(key)
            elif op == "lookup" and keys:
                got = pool.cache_lookup(keys[k % len(keys)])
                if got is not None:
                    held.append(got)    # lookup retains for the caller
            pool.check_conservation()
        for grp in held:
            pool.release(grp)
        pool.check_conservation()


class TestPagedServer:
    """End-to-end: the paged scheduler is token-identical to the
    contiguous one, prefix hits fire on shared prompts, and retire
    reclaims blocks at every phase (the mid-prefill cancel bugfix)."""

    def _params(self, mesh):
        from repro.dist.sharding import param_pspecs, to_shardings
        cfg = get_config("smollm-360m").reduced()
        shape = jax.eval_shape(lambda k: init_params(cfg, k),
                               jax.random.PRNGKey(0))
        psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
        params = jax.jit(lambda k: init_params(cfg, k),
                         out_shardings=psh)(jax.random.PRNGKey(0))
        return cfg, params

    def _server(self, cfg, params, mesh, paged, **kw):
        srv = dict(max_batch=2, max_seq=32, max_new_tokens=4,
                   prefill_chunk=4)
        srv.update(kw)
        return Server(cfg, params, mesh, srv=ServerConfig(
            paged=paged, block_size=4, **srv))

    def _prompts(self, cfg, n=5, shared=8, tail=4):
        rng = np.random.default_rng(2)
        prefix = rng.integers(0, cfg.vocab_size, size=shared)
        return [np.concatenate([prefix,
                                rng.integers(0, cfg.vocab_size, size=tail)])
                for _ in range(n)]

    def test_paged_tokens_equal_contiguous_with_prefix_hits(self, mesh22):
        cfg, params = self._params(mesh22)
        outs = {}
        servers = {}
        for paged in (False, True):
            s = self._server(cfg, params, mesh22, paged)
            for pr in self._prompts(cfg):
                s.submit(pr)
            s.run()
            outs[paged] = {r.rid: r.out_tokens for r in s.done}
            servers[paged] = s
        assert outs[True] == outs[False]
        assert servers[True].prefix_hits > 0
        servers[True].pool.check_conservation()
        st_ = servers[True].stats()
        assert st_["prefix_hits"] == servers[True].prefix_hits
        assert st_["pool_free_blocks"] == servers[True].pool.free_blocks

    def test_prefix_cache_off_still_identical(self, mesh22):
        cfg, params = self._params(mesh22)
        ref = self._server(cfg, params, mesh22, False)
        s = self._server(cfg, params, mesh22, True, prefix_cache=False)
        for pr in self._prompts(cfg, n=3):
            ref.submit(pr)
            s.submit(pr)
        ref.run()
        s.run()
        assert ({r.rid: r.out_tokens for r in s.done}
                == {r.rid: r.out_tokens for r in ref.done})
        assert s.prefix_hits == 0
        s.pool.check_conservation()

    def test_cancel_mid_prefill_reclaims_blocks(self, mesh22):
        """Regression: a cancel while phase == 'prefill' must release the
        admission scratch *and* the slot's pool blocks, and the slot must
        be reusable afterwards."""
        cfg, params = self._params(mesh22)
        s = self._server(cfg, params, mesh22, True, max_batch=1,
                         max_new_tokens=2)
        rng = np.random.default_rng(3)
        rid = s.submit(rng.integers(0, cfg.vocab_size, size=12))
        s.step()                      # admit + first prefill chunk only
        req = s.slots[0]
        assert req is not None and req.phase == "prefill"
        assert req._scratch is not None and req._blocks
        free_before_cancel = s.pool.free_blocks
        assert s.cancel(rid)
        assert req._scratch is None and req._blocks == []
        assert s.slots[0] is None
        assert s.pool.free_blocks > free_before_cancel
        s.pool.check_conservation()
        full = s.pool.free_blocks
        # the parked slot admits and completes a fresh request
        rid2 = s.submit(rng.integers(0, cfg.vocab_size, size=6))
        s.run()
        done = {r.rid: r for r in s.done}
        assert done[rid].cancelled and done[rid].out_tokens == []
        assert len(done[rid2].out_tokens) == 2
        # entries published by rid2's prompt may pin blocks; evict them
        while s.pool.cached_entries:
            s.pool._evict_lru()
        assert s.pool.free_blocks == full
        s.pool.check_conservation()

    def test_cancel_queued_and_unknown(self, mesh22):
        cfg, params = self._params(mesh22)
        s = self._server(cfg, params, mesh22, True)
        rid = s.submit(np.asarray([1, 2, 3], np.int32))
        assert s.cancel(rid)          # still queued: dropped without slot
        assert not s.cancel(rid)      # already gone
        assert s.done[0].cancelled and s.done[0].out_tokens == []
