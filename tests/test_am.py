"""Active Messages: opcode dispatch, message classes, PUT/GET flows."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import am, pgas


def _gas(mesh, size=64):
    heap = pgas.SymmetricHeap(size)
    return heap, pgas.GlobalAddressSpace(mesh, "x", heap)


class TestRegistry:
    def test_builtin_opcodes(self):
        reg = am.HandlerRegistry()
        assert reg.reply_opcode("NOP_REPLY") == 0
        assert reg.reply_opcode("PUT_REPLY") == 1
        assert reg.request_opcode("PUT") == 0
        assert reg.request_opcode("GET") == 1

    def test_registration_order_defines_opcode(self):
        reg = am.HandlerRegistry()
        op1 = reg.register_request("H1", lambda h, a, p: (h, jnp.int32(0),
                                                          am.make_args(), p))
        op2 = reg.register_request("H2", lambda h, a, p: (h, jnp.int32(0),
                                                          am.make_args(), p))
        assert op2 == op1 + 1


class TestGasnetPutGet:
    def test_put(self, mesh4):
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()
        g = gas.zeros_global()

        def f(h):
            payload = jnp.arange(8.0) + 3
            return am.gasnet_put(reg, h, payload, 10, axis="x", perm=[(1, 3)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        np.testing.assert_allclose(out[3, 10:18], np.arange(8) + 3)
        assert np.all(out[[0, 1, 2]] == 0)

    def test_get_lands_at_dst_offset(self, mesh4):
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()
        g = gas.zeros_global()

        def f(h):
            my = jax.lax.axis_index("x").astype(jnp.float32)
            h = h.at[:8].set(my * 100 + jnp.arange(8.0))
            # rank 0 reads rank 2's [0:8) into its own [32:40)
            return am.gasnet_get(reg, h, 0, 32, 8, axis="x", perm=[(0, 2)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        np.testing.assert_allclose(out[0, 32:40], 200 + np.arange(8))
        # GET must not disturb the source
        np.testing.assert_allclose(out[2, :8], 200 + np.arange(8))


class TestMessageClasses:
    def test_short_runs_handler_without_payload(self, mesh4):
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()

        def bump(h, args, payload):
            h = h.at[args[0]].add(1.0)
            return h, jnp.int32(0), am.make_args(), jnp.zeros_like(payload)

        opc = reg.register_request("BUMP", bump)
        g = gas.zeros_global()

        def f(h):
            return am.am_request_short(reg, h, opc, am.make_args(7),
                                       axis="x", perm=[(0, 1), (2, 3)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        assert out[1, 7] == 1.0 and out[3, 7] == 1.0
        assert out[0, 7] == 0.0 and out[2, 7] == 0.0

    def test_medium_delivers_scratch(self, mesh4):
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()
        g = gas.zeros_global()

        def f(h):
            payload = jnp.full((8,), 5.0)
            h, scratch = am.am_request_medium(
                reg, h, jnp.int32(0), am.make_args(0), payload,
                axis="x", perm=[(0, 2)])
            return h, scratch

        _, scratch = gas.run(f, extra_out_specs=P("x"))(g)
        s = np.asarray(scratch).reshape(4, 8)
        np.testing.assert_allclose(s[2], 5.0)   # receiver got scratch
        assert np.all(s[[0, 1, 3]] == 0)

    def test_long_deposits_before_handler(self, mesh4):
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()

        def check(h, args, payload):
            # handler sees the payload already in the heap at args[0]
            val = jax.lax.dynamic_slice(h, (args[0],), (1,))
            h = jax.lax.dynamic_update_slice(h, val * 2, (args[0] + 16,))
            return h, jnp.int32(0), am.make_args(), jnp.zeros((1,), h.dtype)

        opc = reg.register_request("CHECK", check)
        g = gas.zeros_global()

        def f(h):
            payload = jnp.full((4,), 21.0)
            return am.am_request_long(reg, h, opc, am.make_args(), payload,
                                      dst_offset=8, axis="x", perm=[(0, 1)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        np.testing.assert_allclose(out[1, 8:12], 21.0)   # deposit
        assert out[1, 24] == 42.0                        # handler ran after


class TestComputeHandler:
    def test_dla_pattern(self, mesh4):
        """AM carrying a compute opcode: the Sec. III-A orange flow."""
        heap, gas = _gas(mesh4)
        reg = am.HandlerRegistry()

        def compute(h, args, payload):
            # "DLA": scale inbox by args[1], store at args[2]
            x = jax.lax.dynamic_slice(h, (args[0],), (8,))
            h = jax.lax.dynamic_update_slice(
                h, x * args[1].astype(h.dtype), (args[2],))
            return h, jnp.int32(0), am.make_args(), jnp.zeros((1,), h.dtype)

        opc = reg.register_request("COMPUTE", compute)
        g = gas.zeros_global()

        def f(h):
            my = jax.lax.axis_index("x").astype(jnp.float32)
            h = h.at[:8].set(my + 1.0)
            return am.am_request_short(
                reg, h, opc, am.make_args(0, 3, 16), axis="x", perm=[(0, 2)])

        out = np.asarray(gas.run(f)(g)).reshape(4, 64)
        np.testing.assert_allclose(out[2, 16:24], 9.0)   # (2+1) * 3
