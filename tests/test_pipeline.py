"""The generalized ART scheduler (``core/pipeline.py``) and its two new
bindings: streamed conduit collectives and the bucketed gradient sync.

Contract under test everywhere: chunking/bucketing is a *schedule* change,
never a numerics change — streamed results must equal their bulk
counterparts bit-for-bit, per transport, including the edge cases (chunk
size not dividing the payload, single-chunk degenerate pipelines, leaves
bigger than a bucket), and streamed paths must put exactly the same total
traffic on the conduit as bulk (counting-probe proof, the
``tests/test_moe_ep.py`` discipline).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import conduit
from repro.core import pipeline as pl
from repro.dist import bucketing, grad_sync


# ---------------------------------------------------------------------------
# chunk partitioning
# ---------------------------------------------------------------------------


class TestChunkSlices:
    def test_exact_partition_when_not_dividing(self):
        cuts = pl.chunk_slices(10, 3)
        assert cuts[0][0] == 0 and cuts[-1][1] == 10
        assert all(a[1] == b[0] for a, b in zip(cuts, cuts[1:]))
        assert sum(hi - lo for lo, hi in cuts) == 10

    def test_more_chunks_than_elements(self):
        cuts = pl.chunk_slices(2, 5)
        assert len(cuts) == 2                      # empties dropped
        assert sum(hi - lo for lo, hi in cuts) == 2

    def test_single_chunk(self):
        assert pl.chunk_slices(7, 1) == [(0, 7)]

    def test_n_chunks_clamps(self):
        assert pl.n_chunks(100, None, 8) == 1      # no target: bulk
        assert pl.n_chunks(100, 1000, 8) == 1      # oversized target: bulk
        assert pl.n_chunks(100, 10, 8) == 8        # clamped to extent
        assert pl.n_chunks(100, 30, 8) == 4

    def test_split_concat_roundtrip(self):
        x = jnp.arange(3 * 7 * 2.0).reshape(3, 7, 2)
        for axis in (0, 1, -1):
            for n in (1, 2, 3, 5, 100):
                parts = pl.split(x, n, axis=axis)
                back = jnp.concatenate(parts, axis=axis)
                np.testing.assert_array_equal(np.asarray(back),
                                              np.asarray(x))


# ---------------------------------------------------------------------------
# the scheduler loops: streamed/bulk and unroll/loop parity
# ---------------------------------------------------------------------------


class TestChunkPipeline:
    def _run(self, n, loop):
        data = jnp.arange(12.0).reshape(n, 12 // n) if 12 % n == 0 else None
        assert data is not None

        def compute(k):
            return jax.lax.dynamic_index_in_dim(data, k, 0,
                                                keepdims=False) * 2.0

        def transfer(k, payload):
            return payload + 1.0

        def consume(acc, k, arrived):
            return jax.lax.dynamic_update_index_in_dim(acc, arrived, k, 0)

        return pl.chunk_pipeline(
            n, compute, transfer, consume,
            init=lambda c0: jnp.zeros((n,) + c0.shape, c0.dtype), loop=loop)

    @pytest.mark.parametrize("n", [1, 2, 3, 4, 6])
    def test_unroll_equals_loop_equals_reference(self, n):
        ref = np.arange(12.0).reshape(n, 12 // n) * 2.0 + 1.0
        for loop in (False, True):
            np.testing.assert_array_equal(np.asarray(self._run(n, loop)),
                                          ref)

    def test_streamed_order_and_single_chunk(self):
        issued, consumed = [], []

        def issue(k):
            issued.append(k)
            return k * 10

        def consume(k, arrived):
            consumed.append(k)
            return arrived + k

        assert pl.streamed(1, issue, consume) == [0]
        issued.clear(), consumed.clear()
        out = pl.streamed(4, issue, consume)
        assert out == [0, 11, 22, 33]
        assert issued == [0, 1, 2, 3] and consumed == [0, 1, 2, 3]
        # chunk k+1 is issued before chunk k is consumed (the ART window)
        assert pl.streamed(3, lambda k: k, None) == [0, 1, 2]

    def test_zero_chunks_degenerate(self):
        """n=0 issues nothing — parity with the sequential schedule (an
        empty gradient pytree reaches the streamed sync as 0 buckets)."""
        assert pl.streamed(0, lambda k: 1 / 0, None) == []

    @pytest.mark.parametrize("n", [1, 2, 5])
    def test_carried_equals_sequential_reference(self, n):
        """chunk_pipeline_carried == the naive sequential fold: carry
        chains through computes, payload path unchanged (chunked prefill's
        loop shape)."""
        order = []

        def compute(k, carry):
            order.append(("c", k))
            return carry + k, carry + k        # payload_k, carry'

        def transfer(k, payload):
            order.append(("t", k))
            return payload * 10

        def consume(state, k, arrived):
            order.append(("f", k))
            return state + [arrived]

        state, carry = pl.chunk_pipeline_carried(
            n, compute, transfer, consume, carry=0, init=[])
        prefix = [sum(range(k + 1)) for k in range(n)]   # running carries
        assert state == [p * 10 for p in prefix]
        assert carry == prefix[-1]
        if n > 1:
            # the ART window: transfer of k−1 precedes compute of k,
            # which precedes consume of k−1
            i_t0 = order.index(("t", 0))
            assert order.index(("c", 1)) > i_t0
            assert order.index(("f", 0)) > order.index(("c", 1))


class TestConduitStreamed:
    """Conduit.streamed == bulk call, and same total wire traffic."""

    @pytest.mark.parametrize("transport", ["xla", "ring", "bidir"])
    @pytest.mark.parametrize("n_chunks", [1, 2, 3, 5])
    def test_streamed_equals_bulk(self, mesh4, transport, n_chunks):
        n = 4
        x = jax.random.normal(jax.random.PRNGKey(0), (n, n, 5, 7))
        cd = conduit.Conduit("x", transport)

        def bulk(v):
            return cd.all_to_all(v[0])[None]

        def streamed(v):
            parts = pl.split(v[0], n_chunks, axis=1)
            outs = cd.streamed("all_to_all", parts)
            return jnp.concatenate(outs, axis=1)[None]

        outs = {}
        for name, fn in (("bulk", bulk), ("streamed", streamed)):
            outs[name] = np.asarray(jax.jit(jax.shard_map(
                fn, mesh=mesh4, in_specs=P("x"), out_specs=P("x")))(x))
        np.testing.assert_array_equal(outs["streamed"], outs["bulk"])

    def test_streamed_issues_same_total_traffic(self, mesh4):
        """Counting probe: the streamed schedule puts exactly the bulk
        payload on the conduit, in more, smaller pieces."""
        calls = []

        @conduit.register("all_to_all", "probe")
        def _probe(v, *, axis, chunk_bytes=None):
            calls.append(int(v.size))
            return conduit.resolve("all_to_all", "ring")(
                v, axis=axis, chunk_bytes=chunk_bytes)

        try:
            n = 4
            x = jax.random.normal(jax.random.PRNGKey(1), (n, n, 6, 3))
            cd = conduit.Conduit("x", "probe")

            def run(chunks):
                def fn(v):
                    parts = pl.split(v[0], chunks, axis=1)
                    outs = cd.streamed("all_to_all", parts)
                    return jnp.concatenate(outs, axis=1)[None]
                jax.jit(jax.shard_map(fn, mesh=mesh4, in_specs=P("x"),
                                      out_specs=P("x")))(x).block_until_ready()

            run(1)
            bulk_calls, bulk_total = len(calls), sum(calls)
            calls.clear()
            run(4)
            assert len(calls) == 4 * bulk_calls, calls
            assert sum(calls) == bulk_total, (sum(calls), bulk_total)
        finally:
            del conduit._REGISTRY[("all_to_all", "probe")]


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def _tree(key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    return {
        "a": jax.random.normal(ks[0], (13,)),
        "b": {"w": jax.random.normal(ks[1], (8, 9)),
              "s": jax.random.normal(ks[2], ())},
        "c": jax.random.normal(ks[3], (257,)).astype(jnp.bfloat16),
    }


class TestBucketing:
    def test_plan_partitions_whole_leaves(self):
        tree = _tree()
        plan = bucketing.bucket_plan(tree, target_bytes=128)
        all_idx = [i for b in plan.buckets for i in b]
        assert all_idx == list(range(len(jax.tree.leaves(tree))))
        # the 8×9 leaf (288 B) exceeds the target: its own bucket
        assert any(len(b) == 1 for b in plan.buckets)
        assert sum(plan.bucket_elements()) == sum(
            leaf.size for leaf in jax.tree.leaves(tree))

    def test_single_bucket_when_target_large(self):
        plan = bucketing.bucket_plan(_tree(), target_bytes=1 << 30)
        assert plan.n_buckets == 1

    def test_pack_unpack_roundtrip_exact(self):
        tree = _tree()
        for target in (64, 300, 1 << 20):
            plan = bucketing.bucket_plan(tree, target_bytes=target)
            back = bucketing.unpack(bucketing.pack(tree, plan), plan)
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(
                    np.asarray(a, dtype=np.float32),
                    np.asarray(b, dtype=np.float32))

    def test_wire_bytes_wrapper_and_per_bucket_accounting(self):
        # old signature still answers; per-bucket is canonical: compressed
        # buckets each pad to their own block boundary + ship their own
        # scales, so summed per-bucket bytes > one whole-pytree count
        assert grad_sync.wire_bytes(1000) == 4000
        sizes = (100, 300, 77)
        per = grad_sync.bucket_wire_bytes(sizes, compressed=True)
        assert len(per) == 3
        assert sum(per) > grad_sync.wire_bytes(sum(sizes), compressed=True)
        assert grad_sync.wire_bytes(1000, compressed=True) == \
            grad_sync.bucket_wire_bytes((1000,), compressed=True)[0]


# ---------------------------------------------------------------------------
# bucketed cross-pod sync: streamed ≡ bulk, per transport
# ---------------------------------------------------------------------------


def _pod_grads(n):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    return {
        "a": jax.random.normal(ks[0], (n, 300)),
        "b": jax.random.normal(ks[1], (n, 7, 100)),
        "c": jax.random.normal(ks[2], (n, 130)),
    }


class TestBucketedSync:
    @pytest.mark.parametrize("compressed", [False, True])
    @pytest.mark.parametrize("transport", ["xla", "ring"])
    def test_streamed_is_bit_identical_to_bulk(self, mesh4, transport,
                                               compressed):
        grads = _pod_grads(4)
        outs = {}
        for streamed in (True, False):
            fn = jax.jit(functools.partial(
                grad_sync.bucketed_cross_pod_all_reduce, mesh=mesh4,
                axis="x", transport=transport, compressed=compressed,
                bucket_bytes=2048, streamed=streamed))
            s, ef = fn(grads)
            outs[streamed] = (jax.tree.map(np.asarray, s),
                              jax.tree.map(np.asarray, ef))
        for k in grads:
            np.testing.assert_array_equal(outs[True][0][k],
                                          outs[False][0][k])
            np.testing.assert_array_equal(outs[True][1][k],
                                          outs[False][1][k])

    def test_uncompressed_matches_mean(self, mesh4):
        grads = _pod_grads(4)
        synced, ef = grad_sync.bucketed_cross_pod_all_reduce(
            grads, mesh4, axis="x", transport="ring", bucket_bytes=1024)
        for k, g in grads.items():
            want = np.asarray(g).mean(0, keepdims=True).repeat(4, 0)
            np.testing.assert_allclose(np.asarray(synced[k]), want,
                                       rtol=1e-5, atol=1e-6)
            assert not np.asarray(ef[k]).any()     # lossless: no residual

    def test_compressed_ef_matches_bulk_contract(self, mesh4):
        """EF residual comes back per leaf in fp32 and re-injecting it is
        accepted (the cross_pod_all_reduce caller contract)."""
        grads = _pod_grads(4)
        s1, ef = grad_sync.bucketed_cross_pod_all_reduce(
            grads, mesh4, axis="x", compressed=True, bucket_bytes=2048)
        assert all(e.dtype == jnp.float32 for e in jax.tree.leaves(ef))
        s2, _ = grad_sync.bucketed_cross_pod_all_reduce(
            grads, mesh4, axis="x", compressed=True, bucket_bytes=2048,
            ef=ef)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(s2))

    def test_single_bucket_degenerate(self, mesh4):
        """bucket_bytes bigger than the pytree: one bucket, one message —
        and still the exact mean."""
        grads = _pod_grads(4)
        synced, _ = grad_sync.bucketed_cross_pod_all_reduce(
            grads, mesh4, axis="x", transport="ring",
            bucket_bytes=1 << 30)
        for k, g in grads.items():
            want = np.asarray(g).mean(0, keepdims=True).repeat(4, 0)
            np.testing.assert_allclose(np.asarray(synced[k]), want,
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# pipeline-aware cost model
# ---------------------------------------------------------------------------


class TestPipelineModel:
    def test_pipeline_time_reduces_to_bulk(self):
        from repro.core import netmodel as nm

        assert nm.pipeline_time([3.0], [2.0]) == 5.0
        # perfect balance: n chunks hide all but one chunk's wire
        t = nm.pipeline_time([1.0] * 8, [1.0] * 8)
        assert t == pytest.approx(9.0)

    def test_art_time_is_uniform_pipeline_time(self):
        from repro.core import netmodel as nm

        for n in (1, 2, 8, 32):
            assert nm.art_time(1e-3, 1e-3, 1e-6, n) == pytest.approx(
                nm.pipeline_time([1e-3 / n] * n, [1e-3 / n + 1e-6] * n)
                if n > 1 else nm.bulk_time(1e-3, 1e-3, 1e-6))

    def test_estimate_never_beats_its_parts(self):
        t = conduit.pipeline_estimate(
            "all_to_all", "ring", size_bytes=1 << 22, axis_size=4,
            n_chunks=8, compute_time=1e-3)
        assert t >= 1e-3                           # compute is a lower bound

    def test_auto_select_pipeline_prefers_overlap_with_compute(self):
        """With comparable compute, the pipeline policy must pick a
        multi-chunk schedule and model faster than the bulk baseline."""
        from repro.core import netmodel as nm

        size = 1 << 24
        tc = conduit.estimate_time("all_to_all", "bidir",
                                   size_bytes=size, axis_size=8)
        name, chunk, c = conduit.auto_select_pipeline(
            "all_to_all", size_bytes=size, axis_size=8, compute_time=tc)
        assert c > 1
        streamed = conduit.pipeline_estimate(
            "all_to_all", name, size_bytes=size, axis_size=8, n_chunks=c,
            compute_time=tc, chunk_bytes=chunk)
        bulk = min(
            conduit.pipeline_estimate(
                "all_to_all", t, size_bytes=size, axis_size=8, n_chunks=1,
                compute_time=tc)
            for t in ("xla", "ring", "bidir"))
        assert streamed < bulk
        assert bulk / streamed > 1.2               # the acceptance regime

    def test_auto_select_pipeline_no_compute_falls_back_to_bulkish(self):
        """With zero compute to hide, chunking only adds per-message
        latency — the policy must never model worse than auto_select."""
        size = 1 << 20
        name, chunk, c = conduit.auto_select_pipeline(
            "all_reduce", size_bytes=size, axis_size=8, compute_time=0.0)
        t_pipe = conduit.pipeline_estimate(
            "all_reduce", name, size_bytes=size, axis_size=8, n_chunks=c,
            chunk_bytes=chunk)
        bname, bchunk = conduit.auto_select(
            "all_reduce", size_bytes=size, axis_size=8)
        t_bulk = conduit.estimate_time(
            "all_reduce", bname, size_bytes=size, axis_size=8,
            chunk_bytes=bchunk)
        assert t_pipe <= t_bulk * (1 + 1e-9)
