"""Test fixtures.

We request 4 host devices (NOT 512 — the 512-device config belongs
exclusively to launch/dryrun.py, which sets it before its own jax init):
the PGAS/collective/dist tests need a real multi-device mesh to mean
anything, and 4 keeps every smoke test fast.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")


def _install_hypothesis_fallback():
    """Register a minimal deterministic `hypothesis` stand-in when the real
    library is absent (the pinned container has no network; CI installs the
    real one via `pip install -e .[test]`).  Supports exactly the subset the
    suite uses: @given(**kwargs) + @settings(max_examples, deadline) with
    st.integers / st.sampled_from / st.tuples / st.lists.  Draws are
    deterministic: the bounds first, then seeded pseudo-random interior
    points (lists draw the empty boundary first, then seeded contents).
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import functools
    import random
    import types

    class _Integers:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, i, rng):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _SampledFrom:
        def __init__(self, elems):
            self.elems = list(elems)

        def draw(self, i, rng):
            if i < len(self.elems):
                return self.elems[i]
            return rng.choice(self.elems)

    class _Tuples:
        def __init__(self, *elems):
            self.elems = elems

        def draw(self, i, rng):
            return tuple(s.draw(i, rng) for s in self.elems)

    class _Lists:
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def draw(self, i, rng):
            if i == 0:
                n = self.min_size
            else:
                n = rng.randint(self.min_size, self.max_size)
            # force every element onto the seeded-random interior path
            # (a boundary index would repeat one element n times)
            return [self.elements.draw(1 << 20, rng) for _ in range(n)]

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            n = getattr(fn, "_stub_max_examples", 10)

            @functools.wraps(fn)
            def wrapper(*args):
                rng = random.Random(fn.__qualname__)
                for i in range(n):
                    kwargs = {k: s.draw(i, rng)
                              for k, s in strategies.items()}
                    fn(*args, **kwargs)

            # hide the strategy kwargs from pytest's fixture resolution
            import inspect
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _Integers
    st_mod.sampled_from = _SampledFrom
    st_mod.tuples = _Tuples
    st_mod.lists = _Lists
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_fallback()

import jax  # noqa: E402
import pytest  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
import repro  # noqa: E402,F401  (installs jax compat shims for fixtures)


@pytest.fixture(scope="session")
def mesh4():
    """1-D 4-rank PGAS mesh."""
    return jax.make_mesh((4,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh22():
    """2-D (data=2, model=2) mesh for dist tests."""
    return jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
