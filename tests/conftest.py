"""Test fixtures.

We request 4 host devices (NOT 512 — the 512-device config belongs
exclusively to launch/dryrun.py, which sets it before its own jax init):
the PGAS/collective/dist tests need a real multi-device mesh to mean
anything, and 4 keeps every smoke test fast.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh4():
    """1-D 4-rank PGAS mesh."""
    return jax.make_mesh((4,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))


@pytest.fixture(scope="session")
def mesh22():
    """2-D (data=2, model=2) mesh for dist tests."""
    return jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
