"""The analytic netmodel must reproduce every quantitative claim of the
paper's Fig. 5 / Table III, and satisfy basic physical invariants."""


from hypothesis import given, settings, strategies as st

from repro.core import netmodel as nm


class TestPaperClaims:
    def test_peak_bandwidth_3813(self):
        for p in (512, 1024):
            bw = nm.put_bandwidth(nm.FSHMEM_QSFP, 2 << 20, p) / 1e6
            assert abs(bw - 3813) < 40
            assert bw > 0.95 * 4000

    def test_small_packet_peaks(self):
        assert abs(nm.put_bandwidth(nm.FSHMEM_QSFP, 2 << 20, 128) / 1e6
                   - 2621) < 60
        assert abs(nm.put_bandwidth(nm.FSHMEM_QSFP, 2 << 20, 256) / 1e6
                   - 3419) < 60

    def test_half_saturation_around_2kb(self):
        assert 1024 <= nm.half_saturation_size(nm.FSHMEM_QSFP, 1024) <= 4096

    def test_saturation_around_32kb(self):
        assert 16384 <= nm.saturation_size(nm.FSHMEM_QSFP, 1024) <= 65536

    def test_latencies_table_iii(self):
        lat = nm.FSHMEM_QSFP.latency
        assert abs(lat.put_short * 1e6 - 0.21) < 0.005
        assert abs(lat.get_short * 1e6 - 0.45) < 0.005
        assert abs(lat.put_long * 1e6 - 0.35) < 0.005
        assert abs(lat.get_long * 1e6 - 0.59) < 0.005

    def test_get_below_put_asymmetry(self):
        """GET −20 % at 2 KB, −8 % at 8 KB (Sec. IV-C)."""
        gap2k = 1 - (nm.get_bandwidth(nm.FSHMEM_QSFP, 2048, 1024)
                     / nm.put_bandwidth(nm.FSHMEM_QSFP, 2048, 1024))
        gap8k = 1 - (nm.get_bandwidth(nm.FSHMEM_QSFP, 8192, 1024)
                     / nm.put_bandwidth(nm.FSHMEM_QSFP, 8192, 1024))
        assert 0.15 <= gap2k <= 0.25
        assert 0.05 <= gap8k <= 0.11
        assert gap2k > gap8k     # overhead amortizes with size

    def test_9_5x_over_prior(self):
        bw = nm.put_bandwidth(nm.FSHMEM_QSFP, 2 << 20, 1024) / 1e6
        assert 9.0 <= bw / 400 <= 10.0


class TestInvariants:
    @given(size=st.integers(4, 1 << 22), packet=st.sampled_from(
        (128, 256, 512, 1024)))
    @settings(max_examples=50, deadline=None)
    def test_bandwidth_below_line_rate(self, size, packet):
        bw = nm.put_bandwidth(nm.FSHMEM_QSFP, size, packet)
        assert bw <= nm.FSHMEM_QSFP.peak_bandwidth * (1 + 1e-9)

    @given(packet=st.sampled_from((128, 256, 512, 1024)))
    @settings(max_examples=10, deadline=None)
    def test_put_time_monotonic(self, packet):
        sizes = [4 << i for i in range(16)]
        times = [nm.put_time(nm.FSHMEM_QSFP, s, packet) for s in sizes]
        assert times == sorted(times)

    @given(size=st.integers(4, 1 << 20))
    @settings(max_examples=30, deadline=None)
    def test_get_slower_than_put(self, size):
        assert (nm.get_time(nm.FSHMEM_QSFP, size, 1024)
                > nm.put_time(nm.FSHMEM_QSFP, size, 1024))


class TestARTModel:
    def test_art_never_slower_when_free(self):
        t_bulk = nm.bulk_time(1e-3, 5e-4, 1e-6)
        best = nm.best_chunk_count(1e-3, 5e-4, 1e-6)
        assert nm.art_time(1e-3, 5e-4, 1e-6, best) <= t_bulk

    def test_art_speedup_grows_with_problem_size(self):
        """Fig. 7: in a matmul family compute ∝ s³ while the exchanged
        partial sums ∝ s² — larger problems leave more compute to hide the
        transfer under, so the ART-vs-bulk advantage grows with s."""
        sps = []
        for s in (256, 512, 1024):
            tc = (s ** 3) * 1e-12          # compute time ∝ s³
            tx = (s ** 2) * 1e-9           # exchange time ∝ s²
            sps.append(nm.art_speedup(tc, tx, 1e-6, 8))
        assert sps == sorted(sps)
        assert sps[0] > 1.0

    @given(n=st.integers(1, 256))
    @settings(max_examples=30, deadline=None)
    def test_art_time_at_least_compute(self, n):
        t = nm.art_time(1e-3, 2e-4, 1e-6, n)
        assert t >= 1e-3  # cannot beat the compute lower bound

    def test_chunk_u_curve(self):
        """Too many chunks pay per-message latency — same U as Fig. 5."""
        t_huge = nm.art_time(1e-4, 5e-5, 1e-6, 4096)
        best = nm.best_chunk_count(1e-4, 5e-5, 1e-6)
        assert nm.art_time(1e-4, 5e-5, 1e-6, best) < t_huge
