"""Fig. 5 reproduction: PUT/GET bandwidth vs transfer size × packet size.

Sources, kept carefully separate (DESIGN §2):
  model  — the analytic QSFP+ netmodel calibrated on the paper's constants;
           the assertions below are the paper's own quantitative claims.
  ici    — the same mechanism with TPU-v5e ICI constants (projection).
  mesh   — measured wall-clock of the real ``fshmem_put`` collective on a
           2-device CPU mesh (functional path only; CPU numbers are never
           reported as TPU performance).
"""

from __future__ import annotations

import time

from repro.core import netmodel as nm

PACKETS = (128, 256, 512, 1024)
SIZES = tuple(4 * 2 ** i for i in range(20))        # 4 B .. 2 MB


def rows():
    out = []
    link = nm.FSHMEM_QSFP
    for p in PACKETS:
        for s in SIZES:
            out.append({
                "source": "model-qsfp", "packet": p, "size": s,
                "put_MBps": nm.put_bandwidth(link, s, p) / 1e6,
                "get_MBps": nm.get_bandwidth(link, s, p) / 1e6,
            })
    ici = nm.TPU_ICI
    for s in SIZES:
        out.append({
            "source": "model-ici", "packet": 4096, "size": s,
            "put_MBps": nm.put_bandwidth(ici, s, 4096) / 1e6,
            "get_MBps": nm.get_bandwidth(ici, s, 4096) / 1e6,
        })
    return out


def verify_paper_claims() -> dict:
    """The quantitative claims of Fig. 5 / Sec. IV-C, asserted."""
    link = nm.FSHMEM_QSFP
    peak = {p: nm.put_bandwidth(link, 2 << 20, p) / 1e6 for p in PACKETS}
    claims = {
        "peak_512_1024_MBps": round(min(peak[512], peak[1024])),
        "peak_over_95pct_of_max": min(peak[512], peak[1024]) > 0.95 * 4000,
        "peak_128_MBps": round(peak[128]),
        "peak_256_MBps": round(peak[256]),
        "half_saturation_B": nm.half_saturation_size(link, 1024),
        "saturation_95_B": nm.saturation_size(link, 1024),
        "get_vs_put_2KB_pct": round(
            100 * (1 - nm.get_bandwidth(link, 2048, 1024)
                   / nm.put_bandwidth(link, 2048, 1024))),
        "get_vs_put_8KB_pct": round(
            100 * (1 - nm.get_bandwidth(link, 8192, 1024)
                   / nm.put_bandwidth(link, 8192, 1024))),
        "speedup_vs_prior_400MBps": round(peak[1024] / 400, 1),
    }
    # paper: 3813 MB/s peak (>95 %), 2621 @128B, 3419 @256B, half-sat ~2 KB,
    # sat ~32 KB, GET −20 % @2 KB / −8 % @8 KB, 9.5× over 400 MB/s
    assert abs(claims["peak_512_1024_MBps"] - 3813) <= 40, claims
    assert claims["peak_over_95pct_of_max"]
    assert abs(claims["peak_128_MBps"] - 2621) <= 60, claims
    assert abs(claims["peak_256_MBps"] - 3419) <= 60, claims
    assert 1024 <= claims["half_saturation_B"] <= 4096, claims
    assert 16384 <= claims["saturation_95_B"] <= 65536, claims
    assert 15 <= claims["get_vs_put_2KB_pct"] <= 25, claims
    assert 5 <= claims["get_vs_put_8KB_pct"] <= 11, claims
    assert 9.0 <= claims["speedup_vs_prior_400MBps"] <= 10.0, claims
    return claims


def measured_mesh_put(n_iters: int = 50) -> dict:
    """Functional-path wall clock of fshmem_put on a host mesh (2 ranks)."""
    import jax
    from repro.core import pgas

    if len(jax.devices()) < 2:
        return {"source": "mesh-cpu", "note": "single device; skipped"}
    mesh = jax.make_mesh((2,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    size = 1 << 16
    heap = pgas.SymmetricHeap(size)
    gas = pgas.GlobalAddressSpace(mesh, "x", heap)
    g = gas.zeros_global()

    def f(h):
        payload = h[: size // 2]
        return pgas.put(h, payload, size // 2, axis="x",
                        perm=[(0, 1), (1, 0)])

    fn = gas.run(f)
    fn(g).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n_iters):
        g = fn(g)
    g.block_until_ready()
    dt = (time.perf_counter() - t0) / n_iters
    return {"source": "mesh-cpu", "bytes": size // 2 * 4,
            "us_per_put": dt * 1e6,
            "MBps_functional": size // 2 * 4 / dt / 1e6}


def main(write_csv: bool = True):
    claims = verify_paper_claims()
    print("bandwidth: paper-claim verification PASS")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    m = measured_mesh_put()
    print(f"  {m}")
    if write_csv:
        import csv, os
        os.makedirs("results", exist_ok=True)
        with open("results/bandwidth.csv", "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows()[0]))
            w.writeheader()
            w.writerows(rows())
        print("  curves -> results/bandwidth.csv")
    return claims


if __name__ == "__main__":
    main()
