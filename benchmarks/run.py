"""Benchmark harness entry: one suite per paper table/figure.

  bandwidth   — Fig. 5   (PUT/GET bandwidth vs transfer × packet size)
  latency     — Table III (short/long PUT/GET latency + prior works)
  resource    — Table II  (comm-layer share of the compiled module)
  casestudy   — Fig. 6/7  (2-node ART matmul + kernel-split conv)
  roofline    — §Roofline (aggregated dry-run terms; needs results/dryrun)

``PYTHONPATH=src python -m benchmarks.run`` runs them all; each suite
asserts the paper's quantitative claims internally (a failed claim is a
failed run, not a printed warning).
"""

from __future__ import annotations

import os
import sys
import time


def _ensure_devices(n: int = 4):
    # benches that build host meshes need >1 CPU device; set before jax init
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")


def main() -> None:
    _ensure_devices()
    from benchmarks import artlayer, bandwidth, casestudy, latency, resource
    from benchmarks import (
        moe_dispatch,
        overlap_pipeline,
        roofline_bench,
        serve_bench,
        transport_sweep,
    )

    suites = [
        ("bandwidth(Fig5)", bandwidth.main),
        ("latency(TableIII)", latency.main),
        ("resource(TableII)", resource.main),
        ("casestudy(Fig6/7)", casestudy.main),
        ("artlayer(§Perf ART-TP)", artlayer.main),
        ("transport(conduit sweep)", transport_sweep.main),
        ("moe(EP dispatch sweep)", moe_dispatch.main),
        # after transport/moe: the overlap suite fits the netmodel against
        # their freshly written measured rows
        ("overlap(pipeline sweep)", overlap_pipeline.main),
        ("serve(streamed serving)", serve_bench.main),
        ("roofline(§Roofline)", roofline_bench.main),
    ]
    failed = []
    for name, fn in suites:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"=== {name} PASS ({time.time()-t0:.1f}s) ===")
        except AssertionError as e:
            failed.append(name)
            print(f"=== {name} FAIL: {e} ===")
        except Exception as e:
            failed.append(name)
            print(f"=== {name} ERROR: {type(e).__name__}: {e} ===")
    print()
    if failed:
        print(f"benchmarks: {len(failed)} suite(s) failed: {failed}")
        sys.exit(1)
    print("benchmarks: all suites passed")


if __name__ == "__main__":
    main()
