"""Table II analogue: the communication layer's footprint vs the compute.

The paper's point: the GASNet core costs 0.21 % of FPGA logic, leaving the
device to the DLA (10.96 % + 24.46 % of DSPs).  The XLA analogue of "logic
share" is the share of the compiled module occupied by communication ops:
we lower the ART-overlapped distributed matmul (the paper's case-study
kernel) and census the partitioned HLO — collective ops vs compute ops, by
count, bytes and FLOPs.  The PGAS layer should be a rounding error next to
the MXU work, mirroring Table II.
"""

from __future__ import annotations

import functools


def census(n_devices: int = 4, m: int = 512, k: int = 512, n: int = 512,
           chunks: int = 4):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis.hlo_cost import summarize
    from repro.core import art

    if len(jax.devices()) < n_devices:
        n_devices = len(jax.devices())
    mesh = jax.make_mesh(
        (n_devices,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))

    fn = jax.jit(jax.shard_map(
        functools.partial(art.art_matmul_reducescatter, axis="x",
                          n_chunks=chunks),
        mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
        out_specs=P(None, "x")))
    lowered = fn.lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    compiled = lowered.compile()
    s = summarize(compiled.as_text())
    comm_bytes = s.total_coll_bytes
    comm_ops = sum(s.coll_count.values())
    total_bytes = s.bytes
    return {
        "pgas_collective_ops": comm_ops,
        "pgas_collective_bytes": comm_bytes,
        "compute_flops": s.flops,
        "hbm_bytes": total_bytes,
        "comm_share_of_traffic": comm_bytes / max(total_bytes, 1),
        # flops a single v5e chip retires in the time the comm layer's bytes
        # cross one ICI link — the "logic share" analogue
        "comm_equiv_flop_fraction":
            (comm_bytes / 50e9) / max(s.flops / 197e12, 1e-12),
    }


def main():
    c = census()
    print("resource: PGAS-layer share of the compiled module "
          "(Table II analogue)")
    for k, v in c.items():
        print(f"  {k}: {v:.4g}" if isinstance(v, float) else f"  {k}: {v}")
    return c


if __name__ == "__main__":
    main()
