"""Overlap pipeline sweep: streamed EP dispatch + bucketed gradient sync.

The perf artifact of the generalized-ART scheduler (``core/pipeline.py``).
Two modeled sections price the two hot paths the scheduler now covers:

* **streamed EP dispatch** — for every EP preset operating point
  (tokens/rank × arch), the bulk schedule (expert FFN fully serialized
  behind the ``all_to_all``) against the best streamed schedule
  ``conduit.auto_select_pipeline`` finds (chunk count chosen to maximize
  hiding).  Per-link compute models: the ICI rows pair the exchange with
  TPU-v5e peak bf16 compute (honest: large MoE FFNs are compute-dominated
  there, so streaming buys little); the QSFP+ rows pair it with the
  paper's streaming DLA, which produces results at link rate (Sec. III-B
  — the regime ART exists for, and where the paper's own Fig. 7 sits at
  1.94–1.98×).
* **bucketed gradient sync** — a per-pod gradient pytree reduced in
  size-targeted buckets (``dist/bucketing.py`` → ``dist/grad_sync.py``):
  bucket *k*'s conduit reduction in flight while bucket *k±1* packs /
  quantizes, swept over bucket size × transport × link, with the smallest
  bucket count where streaming starts winning recorded as the crossover.

A measured section times the real streamed schedules against their bulk
counterparts on a host-device CPU mesh (functional wall-clock only) and
asserts bit-identity.  When ``BENCH_transport.json`` carries measured
rows, the netmodel fit (``tools/fit_netmodel.py``) records the fitted
small-message constants and crossovers alongside the modeled ones.

Writes ``BENCH_overlap.json`` at the repo root; ``tools/bench_gate.py``
gates CI on its preset rows.  ``--model-only`` skips the measured section.

A third modeled section prices the **fused collective matmuls** (PR 7,
``kernels/cc_matmul``): per TP preset operating point (tokens/rank ×
edge op × link), the best XLA-level streamed schedule
(``core/overlap.py`` — n sub-matmuls each paying the per-hop
launch/repack boundary) against the in-kernel fused schedule (the same
pipeline with the boundary paid once and the hop wire issued by the
kernel's own DMA, ``conduit.matmul_edge_estimate``).  The measured
section times both schedules on the CPU mesh and asserts bit-identity;
``tools/fit_netmodel.py`` fits the per-hop overhead the fusion removes
from those walls.

Internal assertions (a failed claim is a failed run):
  * every EP preset operating point shows streamed-vs-bulk speedup > 1.2×
    on at least one link model (the acceptance bar);
  * every TP preset operating point shows fused-vs-streamed speedup
    > 1.0× on its best link (strictly — the fusion only removes cost);
  * every measured streamed/fused schedule is bit-identical to its bulk
    counterpart.
"""

from __future__ import annotations

import importlib.util
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_overlap.json")
TRANSPORT_PATH = os.path.join(REPO_ROOT, "BENCH_transport.json")
MOE_PATH = os.path.join(REPO_ROOT, "BENCH_moe.json")

EP_TOKENS = (512, 4096, 32768)
TP_TOKENS = (256, 1024, 4096)            # sequence tokens per TP rank
TRANSPORTS = ("xla", "ring", "bidir")

#: TPU v5e peak bf16 (the ICI link's compute side).
TPU_V5E_FLOPS = 197e12
#: HBM bandwidth for the pack/quantize passes of the bucketed sync model.
HBM_BYTES_PER_S = 100e9
#: modeled per-pod gradient sizes for the sync sweep (bytes, fp32)
SYNC_GRAD_BYTES = (16 << 20, 64 << 20, 256 << 20)
SYNC_BUCKET_BYTES = tuple(1 << p for p in range(18, 25))   # 256 KB .. 16 MB
SYNC_PODS = 4


# bytes per dispatch direction: the one shared convention, so the EP rows
# here and in BENCH_moe.json always weigh a preset operating point alike
from benchmarks.moe_dispatch import _dispatch_bytes  # noqa: E402


def _ffn_flops(cfg, tokens_per_rank: int) -> float:
    """Expert-FFN flops one rank computes per dispatch: every routed slot
    through the (gate/)up/down matmuls of its expert."""
    slots = max(1, int(tokens_per_rank * cfg.experts_per_token
                       * cfg.capacity_factor))
    matmuls = 3 if cfg.gated_mlp else 2
    return slots * matmuls * 2 * cfg.d_model * cfg.d_ff


def _ep_compute_time(cfg, tokens: int, link_name: str, link) -> float:
    """The per-dispatch compute the exchange can hide under, per link model.

    ``ici``: FFN flops at TPU-v5e peak — honest, usually compute-dominated.
    ``qsfp``: the paper's DLA streams results at link rate (Sec. III-B), so
    compute time equals the payload's line time — the balanced regime the
    paper's ART speedups (Fig. 7) come from.
    """
    if link_name == "ici":
        return _ffn_flops(cfg, tokens) / TPU_V5E_FLOPS
    return _dispatch_bytes(cfg, tokens) / link.peak_bandwidth


def model_ep_rows():
    from repro.configs import EP_PRESETS
    from repro.core import conduit
    from repro.core import netmodel as nm

    rows = []
    for name, preset in EP_PRESETS.items():
        cfg = preset.config
        n = preset.expert_axis
        for tokens in EP_TOKENS:
            size = _dispatch_bytes(cfg, tokens)
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                tc = _ep_compute_time(cfg, tokens, link_name, link)
                bulk = min(
                    conduit.pipeline_estimate(
                        "all_to_all", t, size_bytes=size, axis_size=n,
                        n_chunks=1, compute_time=tc, link=link)
                    for t in TRANSPORTS)
                tname, chunk, c = conduit.auto_select_pipeline(
                    "all_to_all", size_bytes=size, axis_size=n,
                    compute_time=tc, link=link)
                streamed = conduit.pipeline_estimate(
                    "all_to_all", tname, size_bytes=size, axis_size=n,
                    n_chunks=c, compute_time=tc, link=link,
                    chunk_bytes=chunk)
                rows.append({
                    "source": "preset-model", "suite": "streamed_ep",
                    "preset": name, "arch": cfg.name, "link": link_name,
                    "tokens_per_rank": tokens, "bytes": size,
                    "axis_size": n, "compute_us": 1e6 * tc,
                    "bulk_us": 1e6 * bulk, "streamed_us": 1e6 * streamed,
                    "transport": tname, "chunk_bytes": chunk,
                    "stream_chunks": c,
                    "speedup": bulk / streamed,
                })
    return rows


def _tp_edges(cfg, n: int, tokens: int):
    """The two dense-block TP edges a preset runs per layer, as
    (op, global payload bytes, matmul flops) — the inputs
    ``conduit.matmul_edge_estimate`` prices a schedule family on.

    Up/QKV edge: local (t, D) activations all_gathered under the
    column-parallel matmul; down/O edge: the row-parallel matmul's
    (t·n, D) partials reduce_scattered.  Both move the same bytes and
    compute the same flops — they differ only in which side of the
    matmul the ring feeds."""
    d, f = cfg.d_model, cfg.d_ff
    bytes_ = tokens * n * d * 2                       # bf16 activations
    flops = 2.0 * tokens * d * f                      # per-rank sub-matmuls
    return (("all_gather", bytes_, flops), ("reduce_scatter", bytes_, flops))


def model_fused_rows():
    from repro.configs import TP_PRESETS
    from repro.core import conduit
    from repro.core import netmodel as nm

    rows = []
    for name, preset in TP_PRESETS.items():
        cfg = preset.config
        n = preset.tp_axis
        for tokens in TP_TOKENS:
            for op, size, flops in _tp_edges(cfg, n, tokens):
                for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                        ("ici", nm.TPU_ICI)):
                    if link_name == "ici":
                        tc = flops / TPU_V5E_FLOPS
                    else:
                        tc = size / link.peak_bandwidth   # paper's DLA:
                        #                                   link-rate compute
                    est = {t: conduit.matmul_edge_estimate(
                        op, t, size_bytes=size, axis_size=n,
                        compute_time=tc, link=link)
                        for t in ("xla", "ring", "bidir", "fused")}
                    stream_t = min(("ring", "bidir"), key=est.get)
                    rows.append({
                        "source": "tp-preset-model", "suite": "fused_tp",
                        "preset": name, "arch": cfg.name, "op": op,
                        "link": link_name, "tokens_per_rank": tokens,
                        "bytes": size, "axis_size": n,
                        "compute_us": 1e6 * tc,
                        "bulk_us": 1e6 * est["xla"],
                        "streamed_us": 1e6 * est[stream_t],
                        "fused_us": 1e6 * est["fused"],
                        "streamed_transport": stream_t,
                        "speedup": est[stream_t] / est["fused"],
                    })
    return rows


def model_sync_rows():
    from repro.core import conduit
    from repro.core import netmodel as nm
    from repro.dist.grad_sync import bucket_wire_bytes

    rows = []
    for link_name, link in (("qsfp", nm.FSHMEM_QSFP), ("ici", nm.TPU_ICI)):
        for grad_bytes in SYNC_GRAD_BYTES:
            for compressed in (False, True):
                for bucket_bytes in SYNC_BUCKET_BYTES:
                    n_buckets = max(1, grad_bytes // bucket_bytes)
                    per_elems = bucket_bytes // 4
                    wire = bucket_wire_bytes(
                        [per_elems] * n_buckets, compressed=compressed)
                    # pack + (de)quantize passes over each bucket in HBM
                    passes = 3 if compressed else 2
                    tcs = [passes * bucket_bytes / HBM_BYTES_PER_S
                           ] * n_buckets
                    txs = [conduit.estimate_time(
                        "all_reduce", "ring", size_bytes=w,
                        axis_size=SYNC_PODS, link=link) for w in wire]
                    streamed = nm.pipeline_time(tcs, txs)
                    bulk = sum(tcs) + sum(txs)
                    rows.append({
                        "source": "sync-model", "suite": "bucketed_sync",
                        "link": link_name, "grad_bytes": grad_bytes,
                        "compressed": compressed,
                        "bucket_bytes": bucket_bytes,
                        "n_buckets": n_buckets,
                        "wire_bytes_total": sum(wire),
                        "bulk_us": 1e6 * bulk, "streamed_us": 1e6 * streamed,
                        "speedup": bulk / streamed,
                    })
    return rows


def claims_from(rows) -> dict:
    """The acceptance claims, computed from (and stored beside) the rows."""
    ep = [r for r in rows if r["source"] == "preset-model"]
    claims = {}
    worst = None
    for name in {r["preset"] for r in ep}:
        for tokens in EP_TOKENS:
            best = max(r["speedup"] for r in ep
                       if r["preset"] == name
                       and r["tokens_per_rank"] == tokens)
            worst = best if worst is None else min(worst, best)
    claims["ep_min_speedup_best_link"] = worst
    assert worst is not None and worst > 1.2, (
        f"streamed EP must model > 1.2x on some link at every preset "
        f"operating point (worst best-link speedup: {worst})")

    fused = [r for r in rows if r["source"] == "tp-preset-model"]
    worst_f, worst_q = None, None
    for key in {(r["preset"], r["tokens_per_rank"], r["op"]) for r in fused}:
        pts = [r for r in fused
               if (r["preset"], r["tokens_per_rank"], r["op"]) == key]
        best = max(r["speedup"] for r in pts)
        qsfp = max(r["speedup"] for r in pts if r["link"] == "qsfp")
        worst_f = best if worst_f is None else min(worst_f, best)
        worst_q = qsfp if worst_q is None else min(worst_q, qsfp)
    claims["fused_min_speedup_best_link"] = worst_f
    claims["fused_min_speedup_qsfp"] = worst_q
    assert worst_f is not None and worst_f > 1.0, (
        f"fused must model strictly faster than the streamed schedule at "
        f"every TP preset operating point (worst best-link: {worst_f})")

    sync = [r for r in rows if r["source"] == "sync-model"]
    for link in ("qsfp", "ici"):
        wins = sorted(
            (r["bucket_bytes"] for r in sync
             if r["link"] == link and not r["compressed"]
             and r["grad_bytes"] == max(SYNC_GRAD_BYTES)
             and r["n_buckets"] > 1 and r["speedup"] >= 1.05),
        )
        claims[f"sync_{link}_crossover_bucket_bytes"] = (
            wins[-1] if wins else None)   # largest bucket still pipelining
    return claims


def measured_ep_rows(n_iters: int = 5):
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import moe_ep
    from repro.models.model import init_params

    cfg = get_config("grok-1-314b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    n = min(4, len(jax.devices()))
    while n > 1 and cfg.n_experts % n:
        n -= 1
    if n < 2:
        return []
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("expert",))
    x = jax.random.normal(jax.random.PRNGKey(1), (n * 4, 64, cfg.d_model))

    rows = []
    ref = None
    for chunks in (1, 2, 4):
        runner = moe_ep.build_moe_ep_runner(
            cfg, mesh, transport="ring", stream_chunks=chunks)
        fn = jax.jit(lambda p, v, r=runner: r(cfg, p, v))
        out = np.asarray(fn(moe_p, x))          # compile + correctness
        if ref is None:
            ref = out
        else:
            np.testing.assert_array_equal(
                out, ref,
                err_msg=f"streamed EP (chunks={chunks}) != bulk")
        t0 = time.perf_counter()
        for _ in range(n_iters):
            jax.block_until_ready(fn(moe_p, x))
        dt = (time.perf_counter() - t0) / n_iters
        rows.append({
            "source": "measured-cpu-mesh", "suite": "streamed_ep",
            "op": "moe_layer", "transport": "ring", "axis_size": n,
            "stream_chunks": chunks, "wall_us": 1e6 * dt,
        })
    return rows


def measured_fused_rows(n_iters: int = 5):
    """Wall-clocks of the real fused-vs-streamed TP edges on the CPU mesh
    (functional only — the fitted per-hop overhead, not link perf), with
    bit-identity between the two schedules asserted."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.conduit import Conduit
    from repro.core.overlap import allgather_matmul, matmul_reducescatter
    from repro.kernels.cc_matmul import (
        allgather_matmul_pallas, matmul_reducescatter_pallas)

    n = min(4, len(jax.devices()))
    if n < 2:
        return []
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("model",))
    conduit = Conduit(axis="model", transport="bidir")
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    b_loc, k, m = 64, 128, 128
    x_ag = jax.random.normal(k1, (n * b_loc, k), jnp.float32)
    w_ag = jax.random.normal(k2, (k, m), jnp.float32) * 0.05
    x_rs = jax.random.normal(k3, (n * (n * b_loc), m), jnp.float32)
    w_rs = jnp.asarray(np.asarray(w_ag).T)

    cases = [
        ("all_gather", x_ag, w_ag,
         functools.partial(allgather_matmul, conduit=conduit),
         functools.partial(allgather_matmul_pallas, axis="model",
                           bidirectional=True),
         P("model", None), P(None, None)),
        ("reduce_scatter", x_rs, w_rs,
         functools.partial(matmul_reducescatter, conduit=conduit),
         functools.partial(matmul_reducescatter_pallas, axis="model",
                           bidirectional=True),
         P("model", None), P("model", None)),
    ]
    rows = []
    for op, x, w, streamed_fn, fused_fn, in_spec, out_spec in cases:
        ref = None
        for schedule, fn in (("streamed", streamed_fn), ("fused", fused_fn)):
            run = jax.jit(jax.shard_map(
                fn, mesh=mesh, in_specs=(in_spec, P(None, None)),
                out_specs=out_spec, check_vma=False))
            out = np.asarray(run(x, w))
            if ref is None:
                ref = out
            else:
                np.testing.assert_array_equal(
                    out, ref, err_msg=f"fused {op} != streamed")
            t0 = time.perf_counter()
            for _ in range(n_iters):
                jax.block_until_ready(run(x, w))
            dt = (time.perf_counter() - t0) / n_iters
            rows.append({
                "source": "measured-cpu-mesh", "suite": "fused_tp",
                "op": op, "schedule": schedule, "axis_size": n,
                "bytes": int(x.size * 4), "wall_us": 1e6 * dt,
            })
    return rows


def measured_sync_rows(n_iters: int = 5):
    import functools

    import jax
    import numpy as np
    from repro.dist import grad_sync

    n = min(4, len(jax.devices()))
    if n < 2:
        return []
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("pod",))
    keys = jax.random.split(jax.random.PRNGKey(2), 6)
    grads = {f"w{i}": jax.random.normal(k, (n, 1 << (8 + i)))
             for i, k in enumerate(keys)}

    rows = []
    for compressed in (False, True):
        ref = None
        for streamed in (True, False):
            fn = jax.jit(functools.partial(
                grad_sync.bucketed_cross_pod_all_reduce, mesh=mesh,
                transport="ring", compressed=compressed,
                bucket_bytes=16 << 10, streamed=streamed))
            synced, _ = fn(grads)
            flat = np.concatenate(
                [np.asarray(v).ravel() for v in jax.tree.leaves(synced)])
            if ref is None:
                ref = flat
            else:
                np.testing.assert_array_equal(
                    flat, ref, err_msg="streamed bucketed sync != bulk")
            t0 = time.perf_counter()
            for _ in range(n_iters):
                jax.block_until_ready(fn(grads))
            dt = (time.perf_counter() - t0) / n_iters
            rows.append({
                "source": "measured-cpu-mesh", "suite": "bucketed_sync",
                "transport": "ring", "axis_size": n,
                "compressed": compressed, "streamed": streamed,
                "wall_us": 1e6 * dt,
            })
    return rows


def _fit_netmodel_module():
    spec = importlib.util.spec_from_file_location(
        "fit_netmodel", os.path.join(REPO_ROOT, "tools", "fit_netmodel.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def netmodel_fit_section() -> dict:
    """Fitted small-message constants + crossovers (tools/fit_netmodel.py),
    when the transport sweep artifact carries measured rows."""
    return _fit_netmodel_module().fit_report(TRANSPORT_PATH, MOE_PATH)


def main(model_only: bool = False) -> dict:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows = model_ep_rows() + model_fused_rows() + model_sync_rows()
    claims = claims_from(rows)
    if not model_only:
        rows += measured_ep_rows()
        rows += measured_fused_rows()
        rows += measured_sync_rows()
    fit = netmodel_fit_section()
    # per-hop launch overhead, fitted from this run's own measured
    # fused-vs-streamed walls (the quantity the fusion removes)
    fit["hop_overhead"] = _fit_netmodel_module().fit_hop_overhead(rows)
    payload = {
        "suite": "overlap_pipeline",
        "claims": claims,
        "netmodel_fit": fit,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"overlap_pipeline: {len(rows)} rows -> {OUT_PATH}")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
