"""§Roofline table: aggregate the dry-run JSONs into the per-(arch × shape ×
mesh) three-term roofline report (beyond-paper deliverable)."""

from __future__ import annotations

import glob
import json
import os

RESULTS = "results/dryrun"


def load(results_dir: str = RESULTS):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r) -> str:
    if r.get("status") == "skip":
        return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:14s} "
                f"SKIP  {r['reason'][:60]}")
    if r.get("status") == "error":
        return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:14s} "
                f"ERROR {r['error'][:60]}")
    dom = r["dominant"]
    terms = (f"C {r['compute_s']*1e3:9.2f}  M {r['memory_s']*1e3:9.2f}  "
             f"X {r['collective_s']*1e3:9.2f} ms")
    return (f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:14s} {terms}  "
            f"dom={dom:10s} useful={r['useful_ratio']:.2f}")


def summarize(results_dir: str = RESULTS):
    recs = load(results_dir)
    base = [r for r in recs if r.get("variant", "baseline") == "baseline"]
    ok = [r for r in base if r.get("status") == "ok"]
    skip = [r for r in base if r.get("status") == "skip"]
    err = [r for r in base if r.get("status") == "error"]
    print(f"roofline: {len(ok)} ok / {len(skip)} skip / {len(err)} error "
          f"({len(base)} baseline cells)")
    for r in sorted(base, key=lambda x: (x["arch"], x["shape"],
                                         str(x.get("mesh")))):
        print("  " + fmt_row(r))
    if ok:
        worst = min(ok, key=lambda r: r["useful_ratio"])
        collbound = [r for r in ok if r["dominant"] == "collective"]
        print(f"  worst useful_ratio: {worst['arch']}/{worst['shape']}"
              f"/{worst['mesh']} = {worst['useful_ratio']:.3f}")
        print(f"  collective-bound cells: {len(collbound)}")
    return recs


def main():
    if not os.path.isdir(RESULTS) or not glob.glob(RESULTS + "/*.json"):
        print("roofline: no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --multi-pod both` first")
        return None
    return summarize()


if __name__ == "__main__":
    main()
