"""Regenerate the §Dry-run / §Roofline tables of EXPERIMENTS.md from
results/dryrun JSONs (run after a sweep; §Perf is maintained by hand)."""

from __future__ import annotations

import glob
import json
import os


def load(results_dir="results/dryrun"):
    recs = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        recs.append(json.load(open(p)))
    return recs


def gb(x):
    return f"{x/1e9:.2f}"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | status | args GB/dev | temp GB/dev | "
        "fits 16 GB | collective ops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"],
                                         str(x.get("mesh")))):
        if r.get("variant", "baseline") != "baseline":
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']}: {r.get('reason','')[:40]} | | | | |")
            continue
        mem = r.get("mem_per_device") or {}
        arg = mem.get("argument_bytes", 0)
        tmp = mem.get("temp_bytes", 0)
        fits = "yes" if (arg + tmp) < 16e9 else "**NO**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {gb(arg)} | "
            f"{gb(tmp)} | {fits} | {r['coll_count']} |")
    return "\n".join(lines)


def roofline_table(recs, mesh_filter="16datax16model"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "collective": "overlap/restructure TP+DP collectives (ART), "
                      "cut remat recompute of collectives",
        "memory": "keep blockwise intermediates in VMEM (Pallas), "
                  "remat policy, smaller scan chunks",
        "compute": "MXU-align tiles; already compute-bound",
    }
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("variant", "baseline") != "baseline":
            continue
        if r["status"] == "skip":
            if "pod1" in str(r.get("mesh")):
                lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                             f"skip | — | — | {r['reason'][:50]} |")
            continue
        if r["status"] != "ok" or r["mesh"] != mesh_filter:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {levers[r['dominant']][:60]} |")
    return "\n".join(lines)


def main():
    recs = load()
    print("## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16×16)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
