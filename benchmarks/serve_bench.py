"""Serving sweep: chunked-prefill TTFT, EP decode crossover, arrival walls.

The perf artifact of the streamed serving path (PR 5).  Three sections:

* **chunked-prefill TTFT (modeled)** — per serve preset operating point
  (arch × prompt length) and link model, the bulk prefill (forward fully
  serialized ahead of one bulk cache PUT — the paper's ``gasnet_put`` of
  the prompt cache) against the best chunked schedule
  (``netmodel.serve_prefill_time`` swept over chunk counts: chunk *k*'s
  cache write rides under chunk *k+1*'s forward).  Compute sides follow
  the overlap_pipeline conventions: the QSFP+ rows pair the cache stream
  with the paper's streaming DLA (results at link rate — the regime ART
  exists for); the ICI rows price the forward at TPU-v5e peak bf16
  (honest: prefill is compute-dominated there, streaming buys little).
* **EP decode crossover (modeled)** — per EP preset, the decode dispatch
  payload at batch-per-rank b is priced through ``conduit.auto_select``;
  the smallest b where the policy leaves ``xla`` for a ring family is the
  decode-message-size crossover the serve ``TransportPolicy.moe="auto"``
  acts on (dense-combine stays the fallback below it).
* **paged-pool prefix cache (modeled, PR 6)** — the ``paged_prefix``
  suite: disaggregated admission pushes the prefill cache as per-block
  one-sided PUTs, and a prefix-cache hit replaces the resident fraction of
  the prompt with block-table map writes (one *short* PUT per shared
  block) plus the suffix-only chunked prefill
  (``netmodel.prefix_hit_ttft``).  The ``block_push`` suite sweeps block
  sizes for the PUT-efficiency guidance docs/serving.md quotes.
* **measured CPU walls** — the real ``runtime/server.py`` under synthetic
  arrivals on a host mesh, chunked admission vs bulk admission vs the
  paged block pool: TTFT, inter-token latency, tokens/s (functional walls
  only — no async DMA on CPU, the modeled columns are the decision
  surface), plus the bit-identity asserts: chunked prefill ≡ bulk prefill
  cache/logits, and chunked / paged server tokens ≡ bulk tokens, with
  prefix-cache hits firing on the shared-prefix workload.

Writes ``BENCH_serve.json`` at the repo root; ``tools/bench_gate.py``
gates CI on its preset rows.  ``--model-only`` skips the measured section.

Internal assertions (a failed claim is a failed run):
  * chunked prefill models ≥ 1.3× TTFT over bulk at ≥ 1 preset operating
    point on the QSFP-class link (the acceptance bar);
  * prefix-cache hits model ≥ 1.3× TTFT at ≥ 1 preset operating point on
    the QSFP-class link (the PR 6 acceptance bar);
  * every measured chunked/paged schedule is token-identical to its bulk
    counterpart.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_serve.json")

#: serve preset operating points: chunkable archs × prompt lengths
SERVE_ARCHS = ("smollm-360m", "h2o-danube-1.8b", "internvl2-2b")
PROMPT_LENS = (2048, 8192, 32768)
#: chunked-schedule candidates — deliberately EXCLUDES 1 (bulk): the gate
#: floor (streamed >= 1.0x bulk) must be falsifiable, so the best streamed
#: schedule may not fall back to the bulk schedule it is compared against
CHUNK_COUNTS = (2, 4, 8, 16, 32, 64)
#: decode batch-per-rank sweep for the EP crossover table
DECODE_BATCHES = tuple(1 << p for p in range(0, 11))

#: TPU v5e peak bf16 (the ICI link's compute side) — overlap_pipeline's
TPU_V5E_FLOPS = 197e12


def _kv_write_bytes_per_token(cfg) -> int:
    """Cache bytes one prompt token writes (K/V-like leaves only)."""
    import jax

    from repro.models.decode import init_cache

    kv_keys = {"k", "v", "ckv", "krope", "attn_k", "attn_v"}

    def tot(s):
        leaves = jax.eval_shape(lambda: init_cache(cfg, 1, s))
        return sum(v.size * v.dtype.itemsize
                   for k, v in leaves.items() if k in kv_keys)

    return tot(2) - tot(1)


def _prefill_flops(cfg, s: int) -> float:
    """~2·P·S dense-forward flops (MoE would be k/E cheaper; the ICI rows
    are the honest compute-dominated side either way)."""
    from repro.models.model import count_params_analytic

    return 2.0 * count_params_analytic(cfg) * s


def _decode_dispatch_bytes(cfg, tokens_per_rank: int) -> int:
    """Per-rank EP decode exchange: ``tokens_per_rank`` single-token rows,
    each with one capacity slot per routed expert (``s = 1`` routing —
    see ``moe_ep.build_moe_ep_runner(decode=True)``)."""
    import jax.numpy as jnp

    cap = max(1, int(cfg.experts_per_token / cfg.n_experts
                     * cfg.capacity_factor))
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    return tokens_per_rank * cfg.n_experts * cap * cfg.d_model * itemsize


def model_ttft_rows():
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in SERVE_ARCHS:
        cfg = get_config(arch)
        per_tok = _kv_write_bytes_per_token(cfg)
        for s in PROMPT_LENS:
            cache_bytes = per_tok * s
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                if link_name == "ici":
                    tc = _prefill_flops(cfg, s) / TPU_V5E_FLOPS
                else:
                    # the paper's streaming DLA: results at link rate
                    tc = cache_bytes / link.peak_bandwidth
                bulk = nm.serve_prefill_time(link, tc, cache_bytes, 1,
                                             packet)
                best = min(
                    ((nm.serve_prefill_time(link, tc, cache_bytes, c,
                                            packet), c)
                     for c in CHUNK_COUNTS))
                streamed, c = best
                rows.append({
                    "source": "preset-model", "suite": "chunked_prefill",
                    "arch": arch, "link": link_name, "prompt_len": s,
                    "cache_bytes": cache_bytes,
                    "compute_us": 1e6 * tc,
                    "bulk_ttft_us": 1e6 * bulk,
                    "streamed_ttft_us": 1e6 * streamed,
                    "n_chunks": c,
                    "chunk_tokens": -(-s // c),
                    "speedup": bulk / streamed,
                })
    return rows


#: the rest of the config zoo, now that streamed prefill is total
#: (the chunk-carry contract of PR 8): per-arch prompt lengths — SSM and
#: hybrid archs are the long-context family (constant-size carry), the
#: whisper decoder caps at 448
ZOO_ARCHS = {
    "nemotron-4-340b": PROMPT_LENS,
    "llama4-scout-17b-a16e": PROMPT_LENS,
    "grok-1-314b": PROMPT_LENS,
    "minicpm3-4b": PROMPT_LENS,
    "mamba2-2.7b": (8192, 32768, 131072),
    "zamba2-7b": (8192, 32768, 131072),
    "whisper-tiny": (128, 256, 448),
}


def _carry_bytes(cfg) -> int:
    """Constant-size per-chunk carry: the SSD state pair (fp32 state +
    conv tail) — 0 for pure ring/latent carries."""
    import jax

    from repro.models.decode import init_cache

    leaves = jax.eval_shape(lambda: init_cache(cfg, 1, 2))
    return sum(v.size * v.dtype.itemsize for k, v in leaves.items()
               if k in ("ssm_state", "conv_state"))


def _once_bytes(cfg) -> int:
    """One-time chunk-0 payload: the encdec cross-K/V the encoder
    materializes once (constant extent ``encoder_seq``)."""
    import jax

    from repro.models.decode import init_cache

    leaves = jax.eval_shape(lambda: init_cache(cfg, 1, 2))
    return sum(v.size * v.dtype.itemsize for k, v in leaves.items()
               if k in ("cross_k", "cross_v"))


def model_zoo_ttft_rows():
    """Per-arch modeled TTFT for the rest of the zoo, priced through
    ``netmodel.carried_prefill_time`` (rows split over chunks, the
    constant carry on every chunk's wire, the cross-K/V once).  Pure-state
    archs have no growing cache stream, so their QSFP compute side is
    flops-priced like ICI and the model collapses to exactly 1.0× —
    streamed admission is free, not faster, which is the honest row the
    ≥ 1.0× gate pins."""
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch, lens in ZOO_ARCHS.items():
        cfg = get_config(arch)
        per_tok = _kv_write_bytes_per_token(cfg)
        carry = _carry_bytes(cfg)
        once = _once_bytes(cfg)
        for s in lens:
            row_bytes = per_tok * s
            cache_bytes = row_bytes + carry + once
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                if link_name == "ici" or row_bytes == 0:
                    tc = _prefill_flops(cfg, s) / TPU_V5E_FLOPS
                else:
                    # streaming DLA: the growing cache stream at link rate
                    tc = cache_bytes / link.peak_bandwidth
                bulk = nm.carried_prefill_time(link, tc, row_bytes, carry,
                                               1, packet, once_bytes=once)
                streamed, c = min(
                    ((nm.carried_prefill_time(link, tc, row_bytes, carry,
                                              cc, packet, once_bytes=once),
                      cc)
                     for cc in CHUNK_COUNTS))
                rows.append({
                    "source": "preset-model", "suite": "chunked_prefill",
                    "arch": arch, "link": link_name, "prompt_len": s,
                    "cache_bytes": cache_bytes,
                    "carry_bytes": carry, "once_bytes": once,
                    "compute_us": 1e6 * tc,
                    "bulk_ttft_us": 1e6 * bulk,
                    "streamed_ttft_us": 1e6 * streamed,
                    "n_chunks": c,
                    "chunk_tokens": -(-s // c),
                    "speedup": bulk / streamed,
                })
    return rows


#: prefix-cache hit depths swept by the paged_prefix suite (fraction of
#: the prompt resident as shared full blocks)
HIT_FRACS = (0.25, 0.5, 0.75)


def model_prefix_rows():
    """Paged-pool suite: disaggregated admission (per-block PUTs) and the
    prefix-cache hit TTFT, against the same cold chunked admission the
    ``chunked_prefill`` suite prices."""
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in SERVE_ARCHS:
        cfg = get_config(arch)
        per_tok = _kv_write_bytes_per_token(cfg)
        for s in PROMPT_LENS:
            cache_bytes = per_tok * s
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                if link_name == "ici":
                    tc = _prefill_flops(cfg, s) / TPU_V5E_FLOPS
                else:
                    tc = cache_bytes / link.peak_bandwidth
                cold, c = min(
                    ((nm.serve_prefill_time(link, tc, cache_bytes, cc,
                                            packet), cc)
                     for cc in CHUNK_COUNTS))
                blk_tokens = -(-s // c)          # block = one chunk's KV
                blk_bytes = per_tok * blk_tokens
                for hf in HIT_FRACS:
                    n_shared = int(hf * s) // blk_tokens
                    hit = nm.prefix_hit_ttft(link, tc, cache_bytes, c,
                                             packet, hf, n_shared)
                    rows.append({
                        "source": "preset-model", "suite": "paged_prefix",
                        "arch": arch, "link": link_name, "prompt_len": s,
                        "hit_frac": hf, "block_tokens": blk_tokens,
                        "block_bytes": blk_bytes,
                        "n_shared_blocks": n_shared,
                        "block_push_us": 1e6 * nm.block_push_time(
                            link, blk_bytes, -(-s // blk_tokens), packet),
                        "cold_ttft_us": 1e6 * cold,
                        "hit_ttft_us": 1e6 * hit,
                        "speedup": cold / hit,
                    })
    return rows


def model_block_push_rows():
    """Block-size guidance sweep: PUT efficiency per block size and link —
    the netmodel curve docs/serving.md quotes (small blocks pay the
    per-message latency, big blocks lose sharing granularity)."""
    from repro.core import netmodel as nm

    rows = []
    for link_name, link in (("qsfp", nm.FSHMEM_QSFP), ("ici", nm.TPU_ICI)):
        packet = max(link.packet_overhead_bytes)
        for blk_bytes in (1 << p for p in range(9, 21)):
            rows.append({
                "source": "preset-model", "suite": "block_push",
                "link": link_name, "block_bytes": blk_bytes,
                "put_us": 1e6 * nm.put_time(link, blk_bytes, packet),
                "efficiency": nm.block_push_efficiency(link, blk_bytes,
                                                       packet),
            })
    return rows


def model_ep_decode_rows():
    from repro.configs import EP_PRESETS
    from repro.core import conduit
    from repro.core import netmodel as nm

    rows = []
    for name, preset in EP_PRESETS.items():
        cfg = preset.config
        n = preset.expert_axis
        for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                ("ici", nm.TPU_ICI)):
            for b in DECODE_BATCHES:
                size = _decode_dispatch_bytes(cfg, b)
                tname, chunk = conduit.auto_select(
                    "all_to_all", size_bytes=size, axis_size=n, link=link)
                wire = conduit.estimate_time(
                    "all_to_all", tname, size_bytes=size, axis_size=n,
                    link=link, chunk_bytes=chunk)
                rows.append({
                    "source": "ep-decode-model", "suite": "ep_decode",
                    "preset": name, "arch": cfg.name, "link": link_name,
                    "tokens_per_rank": b, "bytes": size, "axis_size": n,
                    "transport": tname, "chunk_bytes": chunk,
                    "dispatch_us": 1e6 * wire,
                })
    return rows


def claims_from(rows) -> dict:
    """Acceptance claims, computed from (and stored beside) the rows."""
    ttft = [r for r in rows if r["suite"] == "chunked_prefill"]
    qsfp_best = max(r["speedup"] for r in ttft if r["link"] == "qsfp")
    claims = {"ttft_max_speedup_qsfp": qsfp_best}
    assert qsfp_best >= 1.3, (
        f"chunked prefill must model >= 1.3x TTFT at some preset point on "
        f"the QSFP-class link (best: {qsfp_best:.2f}x)")
    worst = None
    for arch in SERVE_ARCHS:
        for s in PROMPT_LENS:
            best = max(r["speedup"] for r in ttft
                       if r["arch"] == arch and r["prompt_len"] == s)
            worst = best if worst is None else min(worst, best)
    claims["ttft_min_best_link_speedup"] = worst

    zoo_worst = None
    for arch, lens in ZOO_ARCHS.items():
        for s in lens:
            best = max(r["speedup"] for r in ttft
                       if r["arch"] == arch and r["prompt_len"] == s)
            zoo_worst = best if zoo_worst is None else min(zoo_worst, best)
    claims["zoo_ttft_min_best_link_speedup"] = zoo_worst
    assert zoo_worst is not None and zoo_worst >= 1.0, (
        f"streamed admission must never model slower than bulk anywhere in "
        f"the zoo (worst best-link speedup: {zoo_worst})")

    paged = [r for r in rows if r["suite"] == "paged_prefix"]
    if paged:
        hit_best = max(r["speedup"] for r in paged if r["link"] == "qsfp")
        claims["prefix_hit_max_speedup_qsfp"] = hit_best
        assert hit_best >= 1.3, (
            f"prefix-cache hits must model >= 1.3x TTFT at some preset "
            f"point on the QSFP-class link (best: {hit_best:.2f}x)")

    ep = [r for r in rows if r["suite"] == "ep_decode"]
    for name in {r["preset"] for r in ep}:
        for link in ("qsfp", "ici"):
            flips = sorted(r["tokens_per_rank"] for r in ep
                           if r["preset"] == name and r["link"] == link
                           and r["transport"] != "xla")
            claims[f"ep_decode_crossover_tok_{link}_{name}"] = (
                flips[0] if flips else None)

    # the byte-level threshold behind those token counts: where auto
    # leaves xla at all, per (axis size, link) — decode payloads above it
    # ride the ring family, below it dense-combine/xla wins
    from repro.core import conduit
    from repro.core import netmodel as nm
    axes = sorted({r["axis_size"] for r in ep})
    for n in axes:
        for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                ("ici", nm.TPU_ICI)):
            claims[f"a2a_crossover_bytes_{link_name}_n{n}"] = \
                conduit.crossover_bytes("all_to_all", axis_size=n,
                                        link=link)
    return claims


def measured_server_rows():
    """The real scheduler under synthetic arrivals on a host mesh —
    chunked admission vs bulk, token-identical by assertion."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.dist.sharding import param_pspecs, to_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import init_params
    from repro.models.prefill import prefill, prefill_chunked
    from repro.runtime.server import Server, ServerConfig, drive_arrivals

    if len(jax.devices()) < 4:
        return []
    cfg = get_config("smollm-360m").reduced()
    mesh = make_host_mesh(2, 2)
    shape = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.random.PRNGKey(0))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(0))

    # model-level bit-identity: chunked prefill == bulk prefill
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0,
                              cfg.vocab_size)
    ca, la = prefill(cfg, jax.device_get(params), toks, cache_len=32)
    cb, lb = prefill_chunked(cfg, jax.device_get(params), toks,
                             cache_len=32, n_chunks=5)
    for k in ca:
        np.testing.assert_array_equal(
            np.asarray(ca[k]), np.asarray(cb[k]),
            err_msg=f"chunked prefill != bulk ({k})")
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    rng = np.random.default_rng(0)
    sys_prefix = rng.integers(0, cfg.vocab_size, size=8)
    prompts = [np.concatenate([
        sys_prefix, rng.integers(0, cfg.vocab_size, size=4)])
        for _ in range(6)]
    rows, outs = [], {}
    for mode, chunk, paged in (("chunked(4)", 4, False), ("bulk", None,
                                                          False),
                               ("paged(4,blk4)", 4, True)):
        srv = Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=4,
            prefill_chunk=chunk, paged=paged, block_size=4))
        t0 = time.perf_counter()
        steps = drive_arrivals(srv, prompts, every=2)
        wall = time.perf_counter() - t0
        stats = srv.stats()
        outs[mode] = {r.rid: r.out_tokens for r in srv.done}
        row = {
            "source": "measured-cpu-mesh", "suite": "server_arrivals",
            "arch": cfg.name, "mode": mode,
            "requests": stats["requests"],
            "tokens": stats["tokens"], "steps": steps,
            "wall_s": wall,
            "mean_ttft_ms": 1e3 * stats["mean_ttft_s"],
            "mean_itl_ms": 1e3 * stats["mean_itl_s"],
            "tok_s": stats["throughput_tok_s"],
        }
        if paged:
            srv.pool.check_conservation()
            row["prefix_hits"] = stats["prefix_hits"]
            row["prefix_misses"] = stats["prefix_misses"]
        rows.append(row)
    assert outs["chunked(4)"] == outs["bulk"], \
        "chunked-admission tokens != bulk-admission tokens"
    assert outs["paged(4,blk4)"] == outs["bulk"], \
        "paged-pool tokens != contiguous-cache tokens"
    assert rows[-1]["prefix_hits"] > 0, \
        "shared-prefix workload produced no prefix-cache hits"
    return rows


def main(model_only: bool = False) -> dict:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows = (model_ttft_rows() + model_zoo_ttft_rows() + model_prefix_rows()
            + model_block_push_rows() + model_ep_decode_rows())
    claims = claims_from(rows)
    if not model_only:
        rows += measured_server_rows()
    payload = {
        "suite": "serve_bench",
        "claims": claims,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"serve_bench: {len(rows)} rows -> {OUT_PATH}")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
