"""Table III reproduction: PUT/GET latency, short and long messages.

The netmodel's five latency stages decompose the paper's four measured
numbers exactly (netmodel.py docstring); the assertions pin them.  Prior-
work rows are the table's published values; the ICI row is the projection
of the same mechanism onto TPU constants.
"""

from __future__ import annotations

from repro.core import netmodel as nm

PRIOR = [
    ("TMD-MPI (inter-m2b)", 2.0, None),
    ("One-sided MPI", 0.36, 0.62),
    ("THe GASNet (short message)", 0.17, 0.35),
    ("THe GASNet (single word)", 0.29, 0.47),
]


def rows():
    q = nm.FSHMEM_QSFP.latency
    i = nm.TPU_ICI.latency
    out = [{"impl": name, "put_us": p, "get_us": g} for name, p, g in PRIOR]
    out += [
        {"impl": "FSHMEM (short message)", "put_us": q.put_short * 1e6,
         "get_us": q.get_short * 1e6},
        {"impl": "FSHMEM (long message)", "put_us": q.put_long * 1e6,
         "get_us": q.get_long * 1e6},
        {"impl": "FSHMEM-on-ICI projection (short)",
         "put_us": i.put_short * 1e6, "get_us": i.get_short * 1e6},
        {"impl": "FSHMEM-on-ICI projection (long)",
         "put_us": i.put_long * 1e6, "get_us": i.get_long * 1e6},
    ]
    return out


def verify_paper_claims():
    q = nm.FSHMEM_QSFP.latency
    got = {
        "put_short_us": round(q.put_short * 1e6, 2),
        "get_short_us": round(q.get_short * 1e6, 2),
        "put_long_us": round(q.put_long * 1e6, 2),
        "get_long_us": round(q.get_long * 1e6, 2),
    }
    want = {"put_short_us": 0.21, "get_short_us": 0.45,
            "put_long_us": 0.35, "get_long_us": 0.59}
    for k in want:
        assert abs(got[k] - want[k]) < 0.005, (k, got[k], want[k])
    # average of long PUT/GET = the abstract's 0.47 us
    avg = (got["put_long_us"] + got["get_long_us"]) / 2
    assert abs(avg - 0.47) < 0.01, avg
    return got


def main():
    got = verify_paper_claims()
    print("latency: Table III verification PASS", got)
    for r in rows():
        g = f"{r['get_us']:.2f}" if r["get_us"] is not None else "  - "
        print(f"  {r['impl']:38s} PUT {r['put_us']:.2f} us  GET {g} us")
    return got


if __name__ == "__main__":
    main()
