"""Per-layer ART-TP vs GSPMD collective schedule (beyond-paper §Perf).

One nemotron-scale transformer layer (attention + MLP), forward + backward,
lowered two ways on a pure TP mesh:

  baseline — GSPMD: weights TP-sharded, activations sequence-sharded,
             the partitioner inserts all-reduces around each block;
  art      — full-manual shard_map: every TP collective is a ring schedule
             from ``core.overlap`` (the paper's ART applied per layer).

Both are *lowered only* (ShapeDtypeStructs, no allocation) and compared by
the loop-aware HLO census: the ART schedule must (a) eliminate blocking
all-reduces, (b) move fewer collective bytes, and (c) interleave its
permutes with the sub-matmuls (the overlap window the paper's Fig. 6(a)
pseudo-code creates).  Numerical equivalence of the two layers is asserted
in tests/test_dist.py::TestTrainStep (full step) and here at reduced size.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_cost import summarize


@dataclasses.dataclass(frozen=True)
class LayerDims:
    d_model: int = 18_432     # nemotron-4-340b
    n_heads: int = 96
    n_kv: int = 8
    head_dim: int = 192
    d_ff: int = 73_728
    seq: int = 4_096
    batch: int = 1


def _weights_spec(tp_axis="model"):
    return {
        "wq": P(None, tp_axis), "wk": P(None, tp_axis), "wv": P(None, tp_axis),
        "wo": P(tp_axis, None),
        "w_up": P(None, tp_axis), "w_down": P(tp_axis, None),
    }


def _weight_shapes(d: LayerDims):
    return {
        "wq": (d.d_model, d.n_heads * d.head_dim),
        "wk": (d.d_model, d.n_kv * d.head_dim),
        "wv": (d.d_model, d.n_kv * d.head_dim),
        "wo": (d.n_heads * d.head_dim, d.d_model),
        "w_up": (d.d_model, d.d_ff),
        "w_down": (d.d_ff, d.d_model),
    }


def _relu2(x):
    r = jnp.maximum(x, 0.0)
    return r * r


def _attention(q, k, v, n_heads, n_kv, hd):
    b, s, _ = q.shape
    qh = q.reshape(b, s, -1, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    kh = k.reshape(b, s, n_kv, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    vh = v.reshape(b, s, n_kv, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    group = qh.shape[1] // n_kv
    kh = jnp.repeat(kh, group, axis=1)
    vh = jnp.repeat(vh, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * hd ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
    return o.transpose(0, 2, 1, 3).reshape(b, s, -1)


def baseline_layer(d: LayerDims, mesh, tp="model"):
    """GSPMD: one jit with TP constraints; returns lowered."""
    cd = jnp.bfloat16

    def layer(x, w):
        q = jnp.einsum("bsd,dh->bsh", x, w["wq"].astype(cd))
        k = jnp.einsum("bsd,dh->bsh", x, w["wk"].astype(cd))
        v = jnp.einsum("bsd,dh->bsh", x, w["wv"].astype(cd))
        o = _attention(q, k, v, d.n_heads, d.n_kv, d.head_dim).astype(cd)
        h = x + jnp.einsum("bsh,hd->bsd", o, w["wo"].astype(cd))
        up = _relu2(jnp.einsum("bsd,df->bsf", h, w["w_up"].astype(cd)))
        h = h + jnp.einsum("bsf,fd->bsd", up, w["w_down"].astype(cd))
        return h

    def loss(x, w):
        return jnp.sum(layer(x, w).astype(jnp.float32) ** 2)

    x = jax.ShapeDtypeStruct((d.batch, d.seq, d.d_model), cd)
    ws = {k_: jax.ShapeDtypeStruct(s, cd)
          for k_, s in _weight_shapes(d).items()}
    in_sh = (NamedSharding(mesh, P(None, tp, None)),
             {k_: NamedSharding(mesh, s)
              for k_, s in _weights_spec(tp).items()})
    fn = jax.jit(jax.grad(loss, argnums=(0, 1)), in_shardings=in_sh)
    return fn.lower(x, ws)


def art_layer(d: LayerDims, mesh, tp="model"):
    """Full-manual: core.overlap rings for every TP collective, all bound
    to one ``Conduit`` handle (the ``TransportPolicy.tp`` spelling)."""
    from repro.core.conduit import Conduit
    from repro.core.overlap import allgather_matmul, matmul_reducescatter
    cd = jnp.bfloat16
    tp_n = mesh.shape[tp]
    hq_loc = d.n_heads // tp_n
    conduit = Conduit(axis=tp, transport="bidir")

    def layer(x, w):
        def per_b(xb, w):
            q = allgather_matmul(xb, w["wq"].astype(cd),
                                 conduit=conduit)  # (S, nq)
            k = conduit.all_gather(
                jnp.einsum("sd,dh->sh", xb, w["wk"].astype(cd)))
            v = conduit.all_gather(
                jnp.einsum("sd,dh->sh", xb, w["wv"].astype(cd)))
            o = _attention(q[None].astype(cd), k[None].astype(cd),
                           v[None].astype(cd),
                           hq_loc, max(1, d.n_kv // tp_n) if d.n_kv >= tp_n
                           else d.n_kv, d.head_dim)[0]
            # kv replicated case: select this shard's kv groups
            if d.n_kv < tp_n:
                pass  # _attention above already repeated kv to hq_loc
            h = xb + matmul_reducescatter(
                o.astype(cd), w["wo"].astype(cd), conduit=conduit).astype(cd)
            up = _relu2(allgather_matmul(h, w["w_up"].astype(cd),
                                         conduit=conduit))
            h = h + matmul_reducescatter(
                up.astype(cd), w["w_down"].astype(cd),
                conduit=conduit).astype(cd)
            return h
        return jax.vmap(lambda xb: per_b(xb, w))(x)

    specs = dict(_weights_spec(tp))
    fn = jax.shard_map(
        layer, mesh=mesh,
        in_specs=(P(None, tp, None), specs),
        out_specs=P(None, tp, None))

    def loss(x, w):
        return jnp.sum(fn(x, w).astype(jnp.float32) ** 2)

    x = jax.ShapeDtypeStruct((d.batch, d.seq, d.d_model), cd)
    ws = {k_: jax.ShapeDtypeStruct(s, cd)
          for k_, s in _weight_shapes(d).items()}
    in_sh = (NamedSharding(mesh, P(None, tp, None)),
             {k_: NamedSharding(mesh, s)
              for k_, s in _weights_spec(tp).items()})
    return jax.jit(jax.grad(loss, argnums=(0, 1)),
                   in_shardings=in_sh).lower(x, ws)


def compare(d: LayerDims = LayerDims()):
    # the ART ring gathers K/V whole per rank, so the schedule needs
    # tp <= n_kv (GQA); cap the mesh accordingly on large host counts
    n = min(len(jax.devices()), 16, d.n_kv)
    mesh = jax.make_mesh((n,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    out = {}
    for name, build in (("gspmd", baseline_layer), ("art", art_layer)):
        lowered = build(d, mesh)
        s = summarize(lowered.compile().as_text())
        out[name] = {
            "coll_bytes": s.total_coll_bytes,
            "by_op": dict(s.coll_bytes),
            "counts": dict(s.coll_count),
            "flops": s.flops,
        }
    return out


def main():
    # full nemotron dims are lowered only — but XLA-CPU still builds big
    # constant buffers for tril masks etc., so default to a 4×-reduced
    # structural replica (all ratios preserved, S/tp still 1024).
    d = LayerDims(d_model=4608, n_heads=24, n_kv=8, head_dim=192,
                  d_ff=18432, seq=4096, batch=1)
    out = compare(d)
    g, a = out["gspmd"], out["art"]
    print("artlayer: per-layer fwd+bwd TP collective census "
          f"(nemotron/4 dims, tp={min(len(jax.devices()), 16)})")
    for name, o in out.items():
        print(f"  {name:6s} coll {o['coll_bytes']:.3e} B  "
              f"{ {k: f'{v:.2e}' for k, v in o['by_op'].items()} }  "
              f"counts {o['counts']}")
    ar_g = g["by_op"].get("all-reduce", 0)
    ar_a = a["by_op"].get("all-reduce", 0)
    print(f"  all-reduce bytes: {ar_g:.3e} -> {ar_a:.3e}")
    print(f"  total collective bytes ratio gspmd/art: "
          f"{g['coll_bytes'] / max(a['coll_bytes'], 1):.2f}x")
    assert ar_a < 0.05 * max(ar_g, 1), (
        "ART layer must eliminate blocking all-reduces")
    assert a["coll_bytes"] < g["coll_bytes"], out
    return out


if __name__ == "__main__":
    main()
