"""Transport sweep: op × transport × message size over the conduit layer.

The perf-trajectory artifact of the conduit refactor: for every collective
op and every registered transport, the modeled time (QSFP+ and ICI
netmodels, per message size and axis size) plus the ``auto`` policy's
choice — the paper's Fig. 5 packet-size sweep generalized into a transport
*selection* surface.  A second, measured section times the real schedules
on a host-device CPU mesh (functional wall-clock only; CPU numbers are
never reported as link performance).

Writes ``BENCH_transport.json`` at the repo root.  ``--model-only`` skips
the measured section (CI smoke).

Internal assertions (a failed claim is a failed run):
  * every op is servable by ≥ 3 transports;
  * ``auto`` picks different transports for small vs large messages on the
    QSFP+ link (the Fig. 5 tradeoff is actually exercised);
  * every measured transport agrees numerically with the XLA builtin.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_transport.json")

SIZES = tuple(1 << p for p in range(8, 25, 2))     # 256 B .. 16 MB
AXIS_SIZES = (4, 8, 64)
MEASURED_OPS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all")


def model_rows():
    from repro.core import conduit
    from repro.core import netmodel as nm

    rows = []
    for link_name, link in (("qsfp", nm.FSHMEM_QSFP), ("ici", nm.TPU_ICI)):
        for op in conduit.OPS:
            names = conduit.transports(op)
            assert len(names) >= 3, (op, names)
            for n in AXIS_SIZES:
                for size in SIZES:
                    for t in names:
                        rows.append({
                            "source": "model", "link": link_name, "op": op,
                            "transport": t, "axis_size": n, "bytes": size,
                            "time_us": 1e6 * conduit.estimate_time(
                                op, t, size_bytes=size, axis_size=n,
                                link=link),
                        })
                    choice, chunk = conduit.auto_select(
                        op, size_bytes=size, axis_size=n, link=link)
                    rows.append({
                        "source": "auto", "link": link_name, "op": op,
                        "transport": choice, "axis_size": n, "bytes": size,
                        "chunk_bytes": chunk,
                    })
    return rows


def verify_model_claims(rows) -> dict:
    """auto must flip transports across the size sweep (Fig. 5 as policy)."""
    auto_ar = {r["bytes"]: r["transport"] for r in rows
               if r["source"] == "auto" and r["op"] == "all_reduce"
               and r["link"] == "qsfp" and r["axis_size"] == 8}
    small, large = auto_ar[min(auto_ar)], auto_ar[max(auto_ar)]
    assert small != large, (small, large)
    assert small == "xla", small
    assert large in ("ring", "bidir"), large
    return {"auto_small_transport": small, "auto_large_transport": large}


def measured_rows(n_iters: int = 5):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import conduit

    n = min(4, len(jax.devices()))
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("x",))
    rows = []
    for op in MEASURED_OPS:
        for size in (1 << 12, 1 << 18):          # 4 KB / 256 KB per rank
            elems = size // 4
            if op == "all_to_all":
                x = jnp.arange(n * n * elems, dtype=jnp.float32
                               ).reshape(n, n, elems)
                spec, call = P("x"), lambda cd, v: cd.all_to_all(v[0])[None]
            elif op == "reduce_scatter":
                x = jnp.arange(n * n * elems, dtype=jnp.float32
                               ).reshape(n * n, elems)
                spec, call = P("x"), lambda cd, v: cd.reduce_scatter(v)
            else:
                x = jnp.arange(n * elems, dtype=jnp.float32
                               ).reshape(n, elems)
                spec, call = P("x"), (
                    (lambda cd, v: cd.all_reduce(v[0])[None])
                    if op == "all_reduce"
                    else (lambda cd, v: cd.all_gather(v)))
            ref = None
            for t in conduit.transports(op):
                cd = conduit.Conduit("x", t)
                f = jax.jit(jax.shard_map(
                    lambda v, cd=cd, call=call: call(cd, v),
                    mesh=mesh, in_specs=spec, out_specs=P("x")))
                out = np.asarray(f(x))           # compile + correctness
                if ref is None:
                    ref = out
                else:
                    np.testing.assert_allclose(
                        out, ref, rtol=1e-5, atol=1e-5,
                        err_msg=f"{op}/{t} disagrees with other transports")
                t0 = time.perf_counter()
                for _ in range(n_iters):
                    jax.block_until_ready(f(x))
                dt = (time.perf_counter() - t0) / n_iters
                rows.append({
                    "source": "measured-cpu-mesh", "op": op, "transport": t,
                    "axis_size": n, "bytes": size,
                    "wall_us": 1e6 * dt,
                })
    return rows


def main(model_only: bool = False) -> dict:
    rows = model_rows()
    claims = verify_model_claims(rows)
    if not model_only:
        rows += measured_rows()
    payload = {
        "suite": "transport_sweep",
        "claims": claims,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"transport_sweep: {len(rows)} rows -> {OUT_PATH}")
    print(f"  auto(QSFP, all_reduce, n=8): small -> "
          f"{claims['auto_small_transport']}, large -> "
          f"{claims['auto_large_transport']}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
