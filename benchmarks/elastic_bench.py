"""Elastic recovery sweep: what a rank loss costs, modeled and measured.

The robustness artifact of the elastic membership PRs.  Five sections:

* **train recovery vs checkpoint interval (modeled)** — per arch × link
  class, ``netmodel.train_recovery_time`` decomposed into its three
  terms: the control-plane re-form (3 rounds of short AMs over the
  survivors), the resharded checkpoint restore (one bulk PUT of the
  state bytes onto the shrunk mesh), and the expected replay (half the
  checkpoint interval at the modeled step time).  The swept interval is
  the knob an operator actually holds; the rows quantify the
  restore-bandwidth vs replay tradeoff per link class (QSFP pays more
  for the restore, so its replay-optimal interval is shorter).
* **serve recovery vs surviving prefix (modeled)** — per arch × prompt
  length × surviving-prefix fraction, ``netmodel.serve_recovery_time``
  for the drain/re-admit path the server runs: victims re-enter through
  the prefix cache, committed blocks on surviving ranks are COW-reused,
  and only the lost tail re-prefills.  The ``speedup`` column is the
  full-re-prefill recovery (no prefix reuse — what a pool without
  cache-aware re-admission would pay) over the tail-only recovery.
* **detection latency and false positives (measured host detector)** —
  per lease period × K, the real ``runtime/membership.MembershipService``
  driven through a lease-suppressed kill: steps from suppression to the
  epoch bump, gated against the closed-form ``netmodel.detection_latency``
  bound ``lease_period x (K+1)``; plus the false-positive rate over a
  ``delay_am`` jitter sweep up to ``(K-1)`` lease periods (must be 0) and
  the modeled heartbeat wire overhead per link class.
* **join MTTR (modeled)** — per arch × link,
  ``netmodel.scaleout_mttr``: announce, epoch-boundary admit, conduit
  re-form at ``n+1``, resharded state hand-off to the joiner.
* **measured CPU-mesh recovery** — the real ``runtime/server.py`` on a
  host mesh, an unfailed run against (a) a run with a scripted
  decode-rank kill mid-stream (``runtime/faults.FaultPlan``) and (b) a
  live-detector churn run (two ranks lose their lease in one window —
  one epoch bump — and one rejoins): drain/re-admit wall, recoveries,
  re-prefilled tokens, and the bit-identity assert — every request's
  tokens must match the unfailed run exactly.

Writes ``BENCH_elastic.json`` at the repo root; ``tools/bench_gate.py``
gates CI on its preset rows.  ``--model-only`` skips the measured section.

Internal assertions (a failed claim is a failed run):
  * prefix-reusing re-admission models ≥ 1.3× over full re-prefill at
    ≥ 1 operating point on the QSFP-class link;
  * recovery time is monotone in the checkpoint interval (more replay
    can never be free);
  * the measured failed run is token-identical to the unfailed run.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_elastic.json")

try:
    from benchmarks.serve_bench import (TPU_V5E_FLOPS, _kv_write_bytes_per_token,
                                        _prefill_flops)
except ImportError:                      # run as `python benchmarks/...`
    from serve_bench import (TPU_V5E_FLOPS, _kv_write_bytes_per_token,
                             _prefill_flops)

#: archs swept (the serve presets: dense, GQA, multimodal)
ARCHS = ("smollm-360m", "h2o-danube-1.8b", "internvl2-2b")
#: checkpoint intervals swept (steps between saves — the operator's knob)
CKPT_INTERVALS = (10, 50, 100, 500)
#: surviving-prefix fractions: how much of a victim's committed KV the
#: prefix cache can COW-reuse from surviving ranks' partitions
SURVIVE_FRACS = (0.25, 0.5, 0.75)
PROMPT_LENS = (2048, 8192)
#: tokens per optimizer step at the modeled operating point
TRAIN_TOKENS_PER_STEP = 1 << 20
#: survivors after the loss (the modeled job ran data=9 before it)
N_SURVIVORS = 8
N_CHUNKS = 8


def _param_bytes(cfg) -> int:
    """At-rest checkpoint bytes of the arch (shape-only eval)."""
    import jax

    from repro.models.model import init_params

    leaves = jax.tree.leaves(jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)))
    return sum(v.size * v.dtype.itemsize for v in leaves)


def _step_time(cfg) -> float:
    """Modeled optimizer-step wall: forward+backward ~ 3x forward flops
    at accelerator peak (both link classes — replay is compute-bound)."""
    return 3 * _prefill_flops(cfg, TRAIN_TOKENS_PER_STEP) / TPU_V5E_FLOPS


def model_train_recovery_rows():
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        ckpt_bytes = _param_bytes(cfg)
        step_time = _step_time(cfg)
        for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                ("ici", nm.TPU_ICI)):
            packet = max(link.packet_overhead_bytes)
            worst = nm.train_recovery_time(
                link, n_ranks=N_SURVIVORS, ckpt_bytes=ckpt_bytes,
                ckpt_interval_steps=max(CKPT_INTERVALS),
                step_time=step_time, packet_size=packet)
            for interval in CKPT_INTERVALS:
                t = nm.train_recovery_time(
                    link, n_ranks=N_SURVIVORS, ckpt_bytes=ckpt_bytes,
                    ckpt_interval_steps=interval, step_time=step_time,
                    packet_size=packet)
                rows.append({
                    "source": "preset-model", "suite": "train_recovery",
                    "arch": arch, "link": link_name,
                    "ckpt_interval": interval,
                    "ckpt_bytes": ckpt_bytes,
                    "step_time_s": step_time,
                    "reform_us": 1e6 * nm.reform_time(link, N_SURVIVORS,
                                                      packet),
                    "restore_s": nm.put_time(link, ckpt_bytes, packet),
                    "replay_s": 0.5 * interval * step_time,
                    "recovery_s": t,
                    # floor metric: vs the longest swept interval —
                    # shorter intervals must never model slower
                    "speedup": worst / t,
                })
    return rows


def model_serve_recovery_rows():
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        per_tok = _kv_write_bytes_per_token(cfg)
        for s in PROMPT_LENS:
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                if link_name == "ici":
                    tc = _prefill_flops(cfg, s) / TPU_V5E_FLOPS / s
                else:
                    tc = per_tok / link.peak_bandwidth
                full = nm.serve_recovery_time(
                    link, n_ranks=N_SURVIVORS, t_compute_per_tok=tc,
                    reprefill_tokens=s, kv_bytes_per_tok=per_tok,
                    n_chunks=N_CHUNKS, packet_size=packet)
                for f in SURVIVE_FRACS:
                    tail = int((1 - f) * s)
                    t = nm.serve_recovery_time(
                        link, n_ranks=N_SURVIVORS, t_compute_per_tok=tc,
                        reprefill_tokens=tail, kv_bytes_per_tok=per_tok,
                        n_chunks=N_CHUNKS, packet_size=packet)
                    rows.append({
                        "source": "preset-model", "suite": "serve_recovery",
                        "arch": arch, "link": link_name, "prompt_len": s,
                        "survive_frac": f,
                        "reprefill_tokens": tail,
                        "full_recovery_s": full,
                        "tail_recovery_s": t,
                        "speedup": full / t,
                    })
    return rows


#: lease periods swept, in host steps (detection suite)
LEASE_PERIODS = (1, 2, 5)
#: miss thresholds swept (K consecutive missed deadlines => dead)
K_SWEEP = (2, 3, 5)
#: host-step wall at the modeled serving operating point
STEP_TIME_S = 1e-3


def detection_rows():
    """Detector latency and false-positive rows, *measured* against the
    real :class:`~repro.runtime.membership.MembershipService` (a pure
    host simulation — no mesh needed) and gated against the closed-form
    ``netmodel.detection_latency`` bound.  The jitter sweep spans
    ``delay_am`` bursts up to ``(K-1)`` lease periods — the worst lag the
    detector must absorb without a false positive."""
    from repro.core import netmodel as nm
    from repro.runtime.faults import FaultPlan
    from repro.runtime.membership import LeaseConfig, MembershipService

    rows = []
    for p in LEASE_PERIODS:
        for k in K_SWEEP:
            p_s = p * STEP_TIME_S
            kill_at = 3 * p + 1
            plan = FaultPlan(deliver="lease").kill_rank(1, at_step=kill_at)
            svc = MembershipService(
                4, LeaseConfig(lease_period=p, k_misses=k,
                               step_time_s=STEP_TIME_S), fault_plan=plan)
            ev = None
            for s in range(kill_at + p * (k + 2) + 2):
                ev = svc.on_step(s) or ev
            assert ev is not None and ev.died == (1,), (p, k, ev)
            latency_s = (ev.step - kill_at) * STEP_TIME_S
            bound_s = nm.detection_latency(p_s, k)
            # jitter the detector must ride out without declaring anyone
            delays = (0.0, 0.5 * p_s, (k - 1) * p_s)
            fp = nm.false_positive_rate(p_s, k, delays)
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                rows.append({
                    "source": "measured-host-detector", "suite": "detection",
                    "link": link_name,
                    "lease_period_s": p_s, "k_misses": k,
                    "detection_latency_s": latency_s,
                    "bound_s": bound_s,
                    "fp_rate": fp,
                    "lease_overhead": nm.lease_overhead(
                        link, N_SURVIVORS, p_s, packet),
                })
    return rows


def join_mttr_rows():
    """Scale-out MTTR rows: announce -> epoch-boundary admit -> conduit
    re-form at ``n+1`` -> resharded state hand-off to the joiner
    (``netmodel.scaleout_mttr``)."""
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        state_bytes = _param_bytes(cfg)
        for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                ("ici", nm.TPU_ICI)):
            packet = max(link.packet_overhead_bytes)
            p_s = STEP_TIME_S
            admit = nm.join_admit_time(link, n_ranks=N_SURVIVORS,
                                       lease_period_s=p_s,
                                       packet_size=packet)
            mttr = nm.scaleout_mttr(link, n_ranks=N_SURVIVORS,
                                    state_bytes=state_bytes,
                                    lease_period_s=p_s, packet_size=packet)
            rows.append({
                "source": "preset-model", "suite": "join_mttr",
                "arch": arch, "link": link_name,
                "state_bytes": state_bytes,
                "lease_period_s": p_s,
                "join_admit_s": admit,
                "mttr_s": mttr,
            })
    return rows


def measured_recovery_rows():
    """The real server on a host mesh: unfailed vs scripted mid-stream
    decode-rank kill, with the token-identity assert."""
    import jax

    if len(jax.devices()) < 4:
        return []

    import numpy as np

    from repro.configs import get_config
    from repro.dist.sharding import param_pspecs, to_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import init_params
    from repro.runtime.faults import FaultPlan
    from repro.runtime.server import Server, ServerConfig

    cfg = get_config("smollm-360m").reduced()
    mesh = make_host_mesh(2, 2)
    shape = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.random.PRNGKey(0))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s) for s in (8, 11, 7)]

    from repro.runtime.membership import LeaseConfig, MembershipService

    def _chaos():
        # live-detector churn: two ranks lose their lease in one window,
        # one of them rejoins later — all via heartbeats, no scripted raise
        plan = (FaultPlan(deliver="lease")
                .kill_rank(1, at_step=6).kill_rank(2, at_step=6))
        svc = MembershipService(4, LeaseConfig(lease_period=1, k_misses=2),
                                fault_plan=plan)
        svc.schedule_join(1, at_step=16)
        return plan, svc

    rows, outs = [], {}
    epochs = {}
    for mode, mk in (("clean", lambda: (None, None)),
                     ("fail@6",
                      lambda: (FaultPlan().kill_rank(1, at_step=6), None)),
                     ("chaos@lease", _chaos)):
        plan, membership = mk()
        srv = Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=6, prefill_chunk=4,
            paged=True, block_size=4), fault_plan=plan,
            membership=membership)
        for p in prompts:
            srv.submit(p)
        t0 = time.perf_counter()
        steps = srv.run()
        if membership is not None:
            while (not any(ev.joined for ev in membership.events)
                   and steps < 200):
                srv.step()
                steps += 1
        wall = time.perf_counter() - t0
        stats = srv.stats()
        srv.pool.check_conservation()
        outs[mode] = {r.rid: r.out_tokens for r in srv.done}
        row = {
            "source": "measured-cpu-mesh", "suite": "measured_recovery",
            "arch": cfg.name, "mode": mode,
            "requests": stats["requests"], "tokens": stats["tokens"],
            "steps": steps, "wall_s": wall,
            "recoveries": stats["recoveries"],
            "reprefilled_tokens": stats["reprefilled_tokens"],
            "lost_blocks": stats["lost_blocks"],
        }
        if membership is not None:
            deaths = [ev for ev in membership.events if ev.died]
            epochs[mode] = (membership.epoch, deaths)
            row["epoch"] = membership.epoch
            row["quarantined_blocks"] = stats["quarantined_blocks"]
        rows.append(row)
    assert outs["fail@6"] == outs["clean"], \
        "recovered tokens != unfailed tokens"
    assert outs["chaos@lease"] == outs["clean"], \
        "detector-recovered tokens != unfailed tokens"
    assert any(r["mode"] == "fail@6" and r["recoveries"] >= 1
               for r in rows), "scripted kill never fired"
    _, deaths = epochs["chaos@lease"]
    assert len(deaths) == 1 and deaths[0].died == (1, 2), \
        f"double loss must be one epoch bump, got {deaths}"
    return rows


def claims_from(rows) -> dict:
    """Acceptance claims, computed from (and stored beside) the rows."""
    serve = [r for r in rows if r["suite"] == "serve_recovery"]
    qsfp_best = max(r["speedup"] for r in serve if r["link"] == "qsfp")
    assert qsfp_best >= 1.3, \
        f"prefix-reusing re-admission models only {qsfp_best:.2f}x on qsfp"

    train = [r for r in rows if r["suite"] == "train_recovery"]
    for (arch, link) in {(r["arch"], r["link"]) for r in train}:
        ts = sorted((r["ckpt_interval"], r["recovery_s"]) for r in train
                    if r["arch"] == arch and r["link"] == link)
        assert all(a[1] <= b[1] for a, b in zip(ts, ts[1:])), \
            f"recovery not monotone in ckpt interval ({arch}, {link})"

    detect = [r for r in rows if r["suite"] == "detection"]
    assert detect, "no detection rows"
    for r in detect:
        assert r["detection_latency_s"] <= r["bound_s"], \
            (f"measured detection {r['detection_latency_s']} beyond the "
             f"modeled bound {r['bound_s']} at {r}")
        assert r["fp_rate"] == 0.0, f"false positive under jitter: {r}"

    worst_serve = min(r["speedup"] for r in serve)
    worst_train = min(r["speedup"] for r in train)
    return {
        "serve_recovery_max_speedup_qsfp": qsfp_best,
        "serve_recovery_min_speedup": worst_serve,
        "train_recovery_min_speedup": worst_train,
        "detection_latency_max_ratio": max(
            r["detection_latency_s"] / r["bound_s"] for r in detect),
        "detection_fp_rate_max": max(r["fp_rate"] for r in detect),
    }


def main(model_only: bool = False) -> dict:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows = (model_train_recovery_rows() + model_serve_recovery_rows()
            + detection_rows() + join_mttr_rows())
    claims = claims_from(rows)
    if not model_only:
        rows += measured_recovery_rows()
    payload = {
        "suite": "elastic_bench",
        "claims": claims,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"elastic_bench: {len(rows)} rows -> {OUT_PATH}")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
