"""Elastic recovery sweep: what a rank loss costs, modeled and measured.

The robustness artifact of the elastic membership PR.  Three sections:

* **train recovery vs checkpoint interval (modeled)** — per arch × link
  class, ``netmodel.train_recovery_time`` decomposed into its three
  terms: the control-plane re-form (3 rounds of short AMs over the
  survivors), the resharded checkpoint restore (one bulk PUT of the
  state bytes onto the shrunk mesh), and the expected replay (half the
  checkpoint interval at the modeled step time).  The swept interval is
  the knob an operator actually holds; the rows quantify the
  restore-bandwidth vs replay tradeoff per link class (QSFP pays more
  for the restore, so its replay-optimal interval is shorter).
* **serve recovery vs surviving prefix (modeled)** — per arch × prompt
  length × surviving-prefix fraction, ``netmodel.serve_recovery_time``
  for the drain/re-admit path the server runs: victims re-enter through
  the prefix cache, committed blocks on surviving ranks are COW-reused,
  and only the lost tail re-prefills.  The ``speedup`` column is the
  full-re-prefill recovery (no prefix reuse — what a pool without
  cache-aware re-admission would pay) over the tail-only recovery.
* **measured CPU-mesh recovery** — the real ``runtime/server.py`` on a
  host mesh, an unfailed run against a run with a scripted decode-rank
  kill mid-stream (``runtime/faults.FaultPlan``): drain/re-admit wall,
  recoveries, re-prefilled tokens, and the bit-identity assert — every
  request's tokens must match the unfailed run exactly.

Writes ``BENCH_elastic.json`` at the repo root; ``tools/bench_gate.py``
gates CI on its preset rows.  ``--model-only`` skips the measured section.

Internal assertions (a failed claim is a failed run):
  * prefix-reusing re-admission models ≥ 1.3× over full re-prefill at
    ≥ 1 operating point on the QSFP-class link;
  * recovery time is monotone in the checkpoint interval (more replay
    can never be free);
  * the measured failed run is token-identical to the unfailed run.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_elastic.json")

try:
    from benchmarks.serve_bench import (TPU_V5E_FLOPS, _kv_write_bytes_per_token,
                                        _prefill_flops)
except ImportError:                      # run as `python benchmarks/...`
    from serve_bench import (TPU_V5E_FLOPS, _kv_write_bytes_per_token,
                             _prefill_flops)

#: archs swept (the serve presets: dense, GQA, multimodal)
ARCHS = ("smollm-360m", "h2o-danube-1.8b", "internvl2-2b")
#: checkpoint intervals swept (steps between saves — the operator's knob)
CKPT_INTERVALS = (10, 50, 100, 500)
#: surviving-prefix fractions: how much of a victim's committed KV the
#: prefix cache can COW-reuse from surviving ranks' partitions
SURVIVE_FRACS = (0.25, 0.5, 0.75)
PROMPT_LENS = (2048, 8192)
#: tokens per optimizer step at the modeled operating point
TRAIN_TOKENS_PER_STEP = 1 << 20
#: survivors after the loss (the modeled job ran data=9 before it)
N_SURVIVORS = 8
N_CHUNKS = 8


def _param_bytes(cfg) -> int:
    """At-rest checkpoint bytes of the arch (shape-only eval)."""
    import jax

    from repro.models.model import init_params

    leaves = jax.tree.leaves(jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)))
    return sum(v.size * v.dtype.itemsize for v in leaves)


def _step_time(cfg) -> float:
    """Modeled optimizer-step wall: forward+backward ~ 3x forward flops
    at accelerator peak (both link classes — replay is compute-bound)."""
    return 3 * _prefill_flops(cfg, TRAIN_TOKENS_PER_STEP) / TPU_V5E_FLOPS


def model_train_recovery_rows():
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        ckpt_bytes = _param_bytes(cfg)
        step_time = _step_time(cfg)
        for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                ("ici", nm.TPU_ICI)):
            packet = max(link.packet_overhead_bytes)
            worst = nm.train_recovery_time(
                link, n_ranks=N_SURVIVORS, ckpt_bytes=ckpt_bytes,
                ckpt_interval_steps=max(CKPT_INTERVALS),
                step_time=step_time, packet_size=packet)
            for interval in CKPT_INTERVALS:
                t = nm.train_recovery_time(
                    link, n_ranks=N_SURVIVORS, ckpt_bytes=ckpt_bytes,
                    ckpt_interval_steps=interval, step_time=step_time,
                    packet_size=packet)
                rows.append({
                    "source": "preset-model", "suite": "train_recovery",
                    "arch": arch, "link": link_name,
                    "ckpt_interval": interval,
                    "ckpt_bytes": ckpt_bytes,
                    "step_time_s": step_time,
                    "reform_us": 1e6 * nm.reform_time(link, N_SURVIVORS,
                                                      packet),
                    "restore_s": nm.put_time(link, ckpt_bytes, packet),
                    "replay_s": 0.5 * interval * step_time,
                    "recovery_s": t,
                    # floor metric: vs the longest swept interval —
                    # shorter intervals must never model slower
                    "speedup": worst / t,
                })
    return rows


def model_serve_recovery_rows():
    from repro.configs import get_config
    from repro.core import netmodel as nm

    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        per_tok = _kv_write_bytes_per_token(cfg)
        for s in PROMPT_LENS:
            for link_name, link in (("qsfp", nm.FSHMEM_QSFP),
                                    ("ici", nm.TPU_ICI)):
                packet = max(link.packet_overhead_bytes)
                if link_name == "ici":
                    tc = _prefill_flops(cfg, s) / TPU_V5E_FLOPS / s
                else:
                    tc = per_tok / link.peak_bandwidth
                full = nm.serve_recovery_time(
                    link, n_ranks=N_SURVIVORS, t_compute_per_tok=tc,
                    reprefill_tokens=s, kv_bytes_per_tok=per_tok,
                    n_chunks=N_CHUNKS, packet_size=packet)
                for f in SURVIVE_FRACS:
                    tail = int((1 - f) * s)
                    t = nm.serve_recovery_time(
                        link, n_ranks=N_SURVIVORS, t_compute_per_tok=tc,
                        reprefill_tokens=tail, kv_bytes_per_tok=per_tok,
                        n_chunks=N_CHUNKS, packet_size=packet)
                    rows.append({
                        "source": "preset-model", "suite": "serve_recovery",
                        "arch": arch, "link": link_name, "prompt_len": s,
                        "survive_frac": f,
                        "reprefill_tokens": tail,
                        "full_recovery_s": full,
                        "tail_recovery_s": t,
                        "speedup": full / t,
                    })
    return rows


def measured_recovery_rows():
    """The real server on a host mesh: unfailed vs scripted mid-stream
    decode-rank kill, with the token-identity assert."""
    import jax

    if len(jax.devices()) < 4:
        return []

    import numpy as np

    from repro.configs import get_config
    from repro.dist.sharding import param_pspecs, to_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import init_params
    from repro.runtime.faults import FaultPlan
    from repro.runtime.server import Server, ServerConfig

    cfg = get_config("smollm-360m").reduced()
    mesh = make_host_mesh(2, 2)
    shape = jax.eval_shape(lambda k: init_params(cfg, k),
                           jax.random.PRNGKey(0))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, shape))
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s) for s in (8, 11, 7)]

    rows, outs = [], {}
    for mode, plan in (("clean", None),
                       ("fail@6", FaultPlan().kill_rank(1, at_step=6))):
        srv = Server(cfg, params, mesh, srv=ServerConfig(
            max_batch=2, max_seq=64, max_new_tokens=6, prefill_chunk=4,
            paged=True, block_size=4), fault_plan=plan)
        for p in prompts:
            srv.submit(p)
        t0 = time.perf_counter()
        steps = srv.run()
        wall = time.perf_counter() - t0
        stats = srv.stats()
        srv.pool.check_conservation()
        outs[mode] = {r.rid: r.out_tokens for r in srv.done}
        rows.append({
            "source": "measured-cpu-mesh", "suite": "measured_recovery",
            "arch": cfg.name, "mode": mode,
            "requests": stats["requests"], "tokens": stats["tokens"],
            "steps": steps, "wall_s": wall,
            "recoveries": stats["recoveries"],
            "reprefilled_tokens": stats["reprefilled_tokens"],
            "lost_blocks": stats["lost_blocks"],
        })
    assert outs["fail@6"] == outs["clean"], \
        "recovered tokens != unfailed tokens"
    assert rows[-1]["recoveries"] >= 1, "scripted kill never fired"
    return rows


def claims_from(rows) -> dict:
    """Acceptance claims, computed from (and stored beside) the rows."""
    serve = [r for r in rows if r["suite"] == "serve_recovery"]
    qsfp_best = max(r["speedup"] for r in serve if r["link"] == "qsfp")
    assert qsfp_best >= 1.3, \
        f"prefix-reusing re-admission models only {qsfp_best:.2f}x on qsfp"

    train = [r for r in rows if r["suite"] == "train_recovery"]
    for (arch, link) in {(r["arch"], r["link"]) for r in train}:
        ts = sorted((r["ckpt_interval"], r["recovery_s"]) for r in train
                    if r["arch"] == arch and r["link"] == link)
        assert all(a[1] <= b[1] for a, b in zip(ts, ts[1:])), \
            f"recovery not monotone in ckpt interval ({arch}, {link})"

    worst_serve = min(r["speedup"] for r in serve)
    worst_train = min(r["speedup"] for r in train)
    return {
        "serve_recovery_max_speedup_qsfp": qsfp_best,
        "serve_recovery_min_speedup": worst_serve,
        "train_recovery_min_speedup": worst_train,
    }


def main(model_only: bool = False) -> dict:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows = model_train_recovery_rows() + model_serve_recovery_rows()
    claims = claims_from(rows)
    if not model_only:
        rows += measured_recovery_rows()
    payload = {
        "suite": "elastic_bench",
        "claims": claims,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"elastic_bench: {len(rows)} rows -> {OUT_PATH}")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
