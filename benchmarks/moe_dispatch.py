"""MoE dispatch sweep: the all_to_all traffic class over the conduit.

PR 2's transport sweep measured the *collective* surface; this one
measures the traffic class the expert-parallel MoE path
(``models/moe_ep.py``) actually puts on the wire: bucketed token
exchanges of ``tokens/rank × capacity × d_model`` bytes riding
``all_to_all`` over the ``expert`` axis.  For every MoE arch preset the
modeled section sweeps payload size × transport × expert-axis size on
both link models and records where the ``auto`` policy flips from ``xla``
(latency-lean doubling) to a ring family (bandwidth) — the paper's
Fig.-5-style crossover, now measurable for MoE dispatch.  A measured
section times the real EP layer against the dense-GSPMD layer on a
host-device CPU mesh (functional wall-clock only) and asserts the two
agree numerically.

Writes ``BENCH_moe.json`` at the repo root.  ``--model-only`` skips the
measured section (CI smoke).

Internal assertions (a failed claim is a failed run):
  * ``auto`` resolves all_to_all to ``xla`` for small dispatches and to a
    ring family for large ones on the QSFP+ link (a crossover exists);
  * every transport's EP layer output equals the dense layer's.
"""

from __future__ import annotations

import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_moe.json")

SIZES = tuple(1 << p for p in range(8, 25, 2))     # 256 B .. 16 MB
EXPERT_AXES = (4, 8)
TRANSPORTS = ("xla", "ring", "bidir")


def _dispatch_bytes(cfg, tokens_per_rank: int) -> int:
    """Bytes one rank puts on the wire per MoE layer dispatch: the
    (E, cap, D) capacity buffer in compute dtype (both directions ride the
    same payload; capacity per the dense path's per-row rule)."""
    import jax.numpy as jnp

    cap = max(1, int(tokens_per_rank * cfg.experts_per_token
                     / cfg.n_experts * cfg.capacity_factor))
    itemsize = jnp.dtype(cfg.compute_dtype).itemsize
    return cfg.n_experts * cap * cfg.d_model * itemsize


def model_rows():
    from repro.configs import EP_PRESETS
    from repro.core import conduit
    from repro.core import netmodel as nm

    rows = []
    for link_name, link in (("qsfp", nm.FSHMEM_QSFP), ("ici", nm.TPU_ICI)):
        for n in EXPERT_AXES:
            for size in SIZES:
                for t in TRANSPORTS:
                    rows.append({
                        "source": "model", "link": link_name,
                        "op": "all_to_all", "transport": t,
                        "axis_size": n, "bytes": size,
                        "time_us": 1e6 * conduit.estimate_time(
                            "all_to_all", t, size_bytes=size,
                            axis_size=n, link=link),
                    })
                choice, chunk = conduit.auto_select(
                    "all_to_all", size_bytes=size, axis_size=n, link=link)
                rows.append({
                    "source": "auto", "link": link_name, "op": "all_to_all",
                    "transport": choice, "axis_size": n, "bytes": size,
                    "chunk_bytes": chunk,
                })
    # per-arch operating points: where each preset's train_4k dispatch
    # lands on the sweep (tokens/rank at the preset's expert-axis extent)
    for name, preset in EP_PRESETS.items():
        cfg = preset.config
        for tokens in (512, 4096, 32768):
            size = _dispatch_bytes(cfg, tokens)
            from repro.core import conduit as _c
            choice, chunk = _c.auto_select(
                "all_to_all", size_bytes=size,
                axis_size=preset.expert_axis, link=nm.FSHMEM_QSFP)
            rows.append({
                "source": "preset", "preset": name, "arch": cfg.name,
                "tokens_per_rank": tokens, "bytes": size,
                "axis_size": preset.expert_axis,
                "transport": choice, "chunk_bytes": chunk,
            })
    return rows


def crossover_claims(rows) -> dict:
    """Smallest swept dispatch size where auto leaves xla, per (link, n)."""
    claims = {}
    for link in ("qsfp", "ici"):
        for n in EXPERT_AXES:
            auto = {r["bytes"]: r["transport"] for r in rows
                    if r["source"] == "auto" and r["link"] == link
                    and r["axis_size"] == n}
            flip = [s for s in sorted(auto) if auto[s] != "xla"]
            claims[f"{link}_n{n}_crossover_bytes"] = flip[0] if flip else None
    small = claims["qsfp_n8_crossover_bytes"]
    assert small is not None, "auto never leaves xla on qsfp (no crossover)"
    assert small > min(SIZES), "auto must keep xla for the smallest dispatch"
    return claims


def measured_rows(n_iters: int = 5):
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models import moe_ep
    from repro.models.model import init_params

    cfg = get_config("grok-1-314b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    n = min(4, len(jax.devices()))
    while n > 1 and cfg.n_experts % n:
        n -= 1
    if n < 2:       # single-device host: no expert axis to exchange over
        return []
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n]), ("expert",))
    x = jax.random.normal(jax.random.PRNGKey(1), (n * 2, 64, cfg.d_model))

    dense_fn = jax.jit(lambda p, v: L.moe(cfg, p, v))
    ref = np.asarray(dense_fn(moe_p, x))
    rows = []
    for t in ("dense-gspmd",) + TRANSPORTS:
        if t == "dense-gspmd":
            fn = dense_fn
        else:
            runner = moe_ep.build_moe_ep_runner(cfg, mesh, transport=t)
            fn = jax.jit(lambda p, v, r=runner: r(cfg, p, v))
        out = np.asarray(fn(moe_p, x))      # compile + correctness
        np.testing.assert_allclose(
            out, ref, rtol=1e-5, atol=1e-5,
            err_msg=f"EP/{t} disagrees with the dense layer")
        t0 = time.perf_counter()
        for _ in range(n_iters):
            jax.block_until_ready(fn(moe_p, x))
        dt = (time.perf_counter() - t0) / n_iters
        rows.append({
            "source": "measured-cpu-mesh", "op": "moe_layer",
            "transport": t, "axis_size": n,
            "tokens_per_rank": int(x.shape[0] // n * x.shape[1]),
            "wall_us": 1e6 * dt,
        })
    return rows


def main(model_only: bool = False) -> dict:
    # the measured section builds a host-device expert mesh; harmless if a
    # caller (benchmarks/run.py) or the environment already chose a count
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
    rows = model_rows()
    claims = crossover_claims(rows)
    if not model_only:
        rows += measured_rows()
    payload = {
        "suite": "moe_dispatch",
        "claims": claims,
        "n_rows": len(rows),
        "rows": rows,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"moe_dispatch: {len(rows)} rows -> {OUT_PATH}")
    for k, v in claims.items():
        print(f"  {k}: {v}")
    return payload


if __name__ == "__main__":
    # failures surface as uncaught assertions (nonzero exit)
    main("--model-only" in sys.argv[1:])
