"""Fig. 6/7 reproduction: 2-node parallel matmul (ART) + convolution.

Two parts, kept separate per DESIGN §2:

1. **Functional**: the exact Fig. 6 schedules — ART-chunked matmul with
   partial-sum exchange and kernel-split convolution with end-sync — run on
   a real 2-device mesh and are asserted allclose against single-node math.

2. **Modeled speedup**: Fig. 7's trends from the analytic model.  Constants:
   the paper reports 979.4 GOPS single-node at "95.6 % of the theoretical
   maximum" ⇒ DLA peak = 1024 GOPS (the 16×8 PE array retires 8 MACs/PE/
   cycle at 250 MHz); activations/results move as 8-bit (the DLA's
   low-precision inference datapath), partial-sum exchange ART-chunked over
   the 3.813 GB/s QSFP+ link; conv pays its exchange exposed at the end.
   Reproduced: magnitudes (~1.9–2.0×), speedup growth with problem size,
   and conv never reaching 2×.  NOT reproduced: the paper's conv-avg >
   matmul-avg ordering — under uniform constants the conv end-sync costs
   slightly more than the ART-hidden matmul exchange at these sizes; the
   per-size Fig. 7 values are not published, so the ordering cannot be
   calibrated further without guessing (recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools

from repro.configs.fshmem_case_study import config as CS
from repro.core import netmodel as nm

DLA_GOPS_PEAK = 1024.0      # 979.4 GOPS measured = 95.6 % of this (Sec. V)
DLA_UTIL = 0.956            # paper Sec. V
DATA_BYTES = 1              # low-precision DLA datapath


def _matmul_times(size: int):
    """(single-node s, two-node s) for size×size×size matmul."""
    flops = 2.0 * size ** 3
    t1 = flops / (DLA_GOPS_PEAK * 1e9 * DLA_UTIL)
    # two nodes: half the FLOPs each; exchange this node's half of the
    # partial sums (size × size/2), ART-chunked under the remaining compute
    t_half = t1 / 2
    comm = size * (size // 2) * DATA_BYTES / nm.FSHMEM_QSFP.peak_bandwidth
    t_msg = nm.FSHMEM_QSFP.latency.put_long
    t2 = nm.art_time(t_half, comm, t_msg, CS.art_chunks)
    return t1, t2


def _conv_times(n_k: int, ksz: int):
    """Conv 64×64 fmaps, n_k kernels of ksz×ksz×n_k (paper's sets)."""
    h = w = CS.conv_fmap
    cin = n_k            # paper: e.g. 3×3×256 with 256 kernels
    ho, wo = h - ksz + 1, w - ksz + 1
    flops = 2.0 * ho * wo * ksz * ksz * cin * n_k
    t1 = flops / (DLA_GOPS_PEAK * 1e9 * DLA_UTIL)
    # kernel-split: each node computes half the output channels, then the
    # halves are exchanged and concatenated at the END (not overlapped).
    t_half = t1 / 2
    out_bytes = ho * wo * (n_k // 2) * DATA_BYTES
    comm = out_bytes / nm.FSHMEM_QSFP.peak_bandwidth
    t_msg = nm.FSHMEM_QSFP.latency.put_long
    t2 = t_half + comm + t_msg           # exposed end-sync (paper Sec. V)
    return t1, t2


def modeled_speedups():
    mm = {}
    for s in CS.matmul_sizes:
        t1, t2 = _matmul_times(s)
        mm[f"matmul_{s}"] = t1 / t2
    cv = {}
    for n_k, ksz in CS.conv_sets:
        t1, t2 = _conv_times(n_k, ksz)
        cv[f"conv_{n_k}x{ksz}x{ksz}"] = t1 / t2
    return mm, cv


def verify_paper_claims():
    mm, cv = modeled_speedups()
    mm_avg = sum(mm.values()) / len(mm)
    cv_avg = sum(cv.values()) / len(cv)
    # paper targets: 1.94× matmul avg, 1.98× conv avg, ~1.95× overall;
    # qualitative: speedup grows with matmul size; conv never reaches 2×.
    assert 1.85 <= mm_avg <= 2.0, (mm, mm_avg)
    assert 1.90 <= cv_avg <= 2.0, (cv, cv_avg)
    overall = (sum(mm.values()) + sum(cv.values())) / (len(mm) + len(cv))
    assert 1.88 <= overall <= 2.0, overall
    sizes = list(mm.values())
    assert sizes == sorted(sizes), f"matmul speedup must grow with size {mm}"
    assert all(v < 2.0 for v in cv.values()), cv
    return {"matmul": mm, "matmul_avg": mm_avg,
            "conv": cv, "conv_avg": cv_avg, "overall_avg": overall,
            "paper": {"matmul_avg": 1.94, "conv_avg": 1.98,
                      "overall": 1.95}}


def functional_check():
    """Run the actual Fig. 6 schedules on 2 CPU devices, assert allclose."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import art

    if len(jax.devices()) < 2:
        return {"note": "single device; functional check skipped"}
    mesh = jax.make_mesh((2,), ("x",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(0)
    size = 128
    m = jax.random.normal(key, (size, size), jnp.float32)
    n = jax.random.normal(jax.random.PRNGKey(1), (size, size), jnp.float32)
    ms = jax.device_put(m, jax.sharding.NamedSharding(mesh, P(None, "x")))
    ns = jax.device_put(n, jax.sharding.NamedSharding(mesh, P("x", None)))
    f_art = jax.jit(jax.shard_map(
        functools.partial(art.art_matmul_reducescatter, axis="x",
                          n_chunks=CS.art_chunks),
        mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
        out_specs=P(None, "x")))
    got = np.asarray(f_art(ms, ns))
    np.testing.assert_allclose(got, np.asarray(m) @ np.asarray(n),
                               rtol=1e-4, atol=1e-4)

    imgs = jax.random.normal(key, (2, 16, 16, 8), jnp.float32)
    kern = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 8, 16), jnp.float32)
    ks = jax.device_put(kern, jax.sharding.NamedSharding(
        mesh, P(None, None, None, "x")))
    # out_specs=P(): the all-gather makes the result replicated in *value*,
    # which vma tracking cannot prove statically — disable just that check.
    f_conv = jax.jit(jax.shard_map(
        functools.partial(art.split_conv_allgather, axis="x"),
        mesh=mesh, in_specs=(P(), P(None, None, None, "x")),
        out_specs=P(), check_vma=False))
    got = np.asarray(f_conv(imgs, ks))
    want = jax.lax.conv_general_dilated(
        imgs, kern, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)
    return {"matmul_allclose": True, "conv_allclose": True}


def main():
    claims = verify_paper_claims()
    print("casestudy: Fig. 7 modeled speedups "
          f"(matmul avg {claims['matmul_avg']:.2f}x, "
          f"conv avg {claims['conv_avg']:.2f}x) PASS")
    for k, v in {**claims["matmul"], **claims["conv"]}.items():
        print(f"  {k}: {v:.3f}x")
    f = functional_check()
    print(f"  functional (2-device mesh): {f}")
    return claims


if __name__ == "__main__":
    main()
