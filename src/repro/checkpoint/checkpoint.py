"""Atomic, sharded, resumable checkpoints (no orbax dependency).

Write protocol (crash-safe at every point):
  1. serialize every leaf to ``<dir>/step_K.tmp/<leaf>.npy``
  2. write a manifest (tree structure, shapes, dtypes, step, timestamp)
  3. fsync all files, then fsync the directory
  4. atomic ``rename(step_K.tmp -> step_K)`` — the commit point
  5. update ``latest`` symlink (best-effort; recovery scans dirs anyway)

A reader only ever sees fully-committed checkpoints: ``step_K`` either
exists completely or not at all.  ``keep_last`` old checkpoints are GC'd
after a successful commit, never before.

Sharding: each leaf is saved from host RAM (fully-addressable arrays).  On a
real multi-host pod each host writes only the shards it owns under
``<dir>/step_K.tmp/shard_<proc>/`` with the same manifest/rename protocol;
the layout here is the single-process specialization (proc 0 owns all).
Restore targets are resharded by ``jax.device_put`` against the current
mesh, which is what makes restore-after-remesh (elastic scaling) work: the
checkpoint stores *logical* arrays, the mesh maps them physically.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # registers bfloat16 etc. with numpy
import numpy as np

# numpy's .npy format forgets extension dtypes (bf16 loads back as V2);
# store them as a same-width integer view and record the logical dtype.
_VIEW_AS = {np.dtype(ml_dtypes.bfloat16): np.uint16,
            np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
            np.dtype(ml_dtypes.float8_e5m2): np.uint8}


def _flatten_with_paths(tree):
    # tree_util spelling: works on every jax this package supports
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(os.path.join(final, "manifest.json")):
        # idempotent: a committed checkpoint for this step already exists
        # (e.g. interval save followed by final save at the same step)
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "extra": extra or {},
        "leaves": [],
    }
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype in _VIEW_AS:
            arr = arr.view(_VIEW_AS[arr.dtype])
        fname = f"leaf_{i:05d}.npy"
        path = os.path.join(tmp, fname)
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "name": name, "file": fname,
            "shape": list(arr.shape), "dtype": logical_dtype,
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    dfd = os.open(tmp, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    os.rename(tmp, final)          # commit point
    dfd = os.open(directory, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return final


def list_checkpoints(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append((int(name[5:]), os.path.join(directory, name)))
    return sorted(out)


def load_checkpoint(directory: str, template, *, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings — this is the elastic-restore path: the stored logical
    arrays are placed onto whatever mesh is current."""
    ckpts = list_checkpoints(directory)
    if not ckpts:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    if step is None:
        step, path = ckpts[-1]
    else:
        match = [p for s, p in ckpts if s == step]
        if not match:
            raise FileNotFoundError(f"step {step} not in {directory}")
        path = match[0]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    names, leaves, treedef = _flatten_with_paths(template)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    restored = []
    flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
               else [None] * len(leaves))
    for name, leaf, sh in zip(names, leaves, flat_sh):
        entry = by_name.get(name)
        if entry is None:
            raise KeyError(f"leaf {name!r} missing from checkpoint {path}")
        arr = np.load(os.path.join(path, entry["file"]))
        logical = np.dtype(entry["dtype"])
        if arr.dtype != logical:
            arr = arr.view(logical)
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != template {want_shape}")
        if sh is not None:
            restored.append(jax.device_put(arr, sh))
        else:
            restored.append(jax.numpy.asarray(arr))
    return treedef.unflatten(restored), manifest


@dataclasses.dataclass
class CheckpointManager:
    """Periodic + preemption checkpointing with GC of old steps."""

    directory: str
    interval: int = 100
    keep_last: int = 3

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.interval == 0

    def save(self, step: int, tree, *, extra=None) -> str:
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return path

    def restore_or_none(self, template, shardings=None):
        try:
            return load_checkpoint(self.directory, template,
                                   shardings=shardings)
        except FileNotFoundError:
            return None

    def latest_step(self) -> Optional[int]:
        ckpts = list_checkpoints(self.directory)
        return ckpts[-1][0] if ckpts else None

    def _gc(self):
        ckpts = list_checkpoints(self.directory)
        for _, path in ckpts[: -self.keep_last]:
            shutil.rmtree(path, ignore_errors=True)
