"""Deterministic, shard-aware, resumable synthetic data pipeline."""

from repro.data.pipeline import DataConfig, SyntheticLM, batch_specs

__all__ = ["DataConfig", "SyntheticLM", "batch_specs"]
