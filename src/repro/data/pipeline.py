"""Synthetic LM data pipeline — deterministic, shard-aware, resumable.

Fault-tolerance property (DESIGN §6): the batch for step *k* is a pure
function of ``(seed, k)`` — ``jax.random.fold_in(key, step)`` — so the
pipeline carries **no state to checkpoint or lose**.  After a restart at
step *k*, every host regenerates exactly the batch it would have seen, and
elastic re-meshing only changes *which shard* of that batch a host
materializes, never its content.

The synthetic distribution is a compressible orderful stream (a mixture of
repeated n-grams + noise tokens) rather than uniform noise, so a ~100M model
trained on it shows a real, monotonically decreasing loss curve — used by
examples/train_lm.py and the convergence test.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_grams: int = 64          # distinct memorizable n-grams
    gram_len: int = 8
    noise_prob: float = 0.1


class SyntheticLM:
    """``batch(step, shard, n_shards)`` -> tokens/labels for that DP shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = jax.random.PRNGKey(cfg.seed)
        # The "corpus": a fixed bank of n-grams every batch draws from.
        self.grams = jax.random.randint(
            jax.random.fold_in(base, 0xC0FFEE),
            (cfg.n_grams, cfg.gram_len), 0, cfg.vocab_size)
        self._base = base

    def _tokens(self, key, batch: int) -> jnp.ndarray:
        cfg = self.cfg
        n_slots = -(-cfg.seq_len // cfg.gram_len)
        k1, k2, k3 = jax.random.split(key, 3)
        slot_ids = jax.random.randint(k1, (batch, n_slots), 0, cfg.n_grams)
        seq = self.grams[slot_ids].reshape(batch, n_slots * cfg.gram_len)
        seq = seq[:, : cfg.seq_len]
        noise = jax.random.randint(k2, seq.shape, 0, cfg.vocab_size)
        mask = jax.random.uniform(k3, seq.shape) < cfg.noise_prob
        return jnp.where(mask, noise, seq)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1
              ) -> Dict[str, jnp.ndarray]:
        """Deterministic global batch for ``step``, sliced to this DP shard."""
        cfg = self.cfg
        assert cfg.global_batch % n_shards == 0, (cfg.global_batch, n_shards)
        per = cfg.global_batch // n_shards
        key = jax.random.fold_in(self._base, step)
        # Generate only this shard's rows: fold the shard id separately so a
        # host never materializes the full global batch.
        key_s = jax.random.fold_in(key, shard)
        toks = self._tokens(key_s, per)
        tokens = toks[:, :-1]
        labels = toks[:, 1:]
        return {"tokens": tokens, "labels": labels}

    def global_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """All shards concatenated (tests / single-host)."""
        return self.batch(step, 0, 1)


def batch_specs(seq_len: int, global_batch: int,
                vocab_size: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (dry-run input_specs)."""
    return {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
