"""Runtime compatibility shims for the installed jax version.

The codebase is written against the current public jax API —
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.tree.flatten_with_path`` — but the pinned CPU
toolchain in the container ships jax 0.4.x, where the same programs are
expressible under older spellings (``jax.experimental.shard_map``, no axis
types, ``jax.tree_util``).  :func:`install` backfills the missing attributes
so library code, tests, and examples are written exactly once against the
new spelling.

Every shim is strictly additive and a no-op on a jax that already provides
the API, so the package runs unmodified on both the pinned container and a
current-jax CI runner.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _ensure_axis_type() -> None:
    """``jax.sharding.AxisType`` (Auto/Explicit/Manual) for jax < 0.5."""
    import jax.sharding as jsharding

    if hasattr(jsharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jsharding.AxisType = AxisType


def _ensure_make_mesh_axis_types() -> None:
    """Accept (and drop) ``axis_types=`` on old ``jax.make_mesh``: pre-0.5
    meshes have no axis-type concept — every axis behaves as Auto, which is
    the only type this codebase uses."""
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # builtins without signatures
        return
    if "axis_types" in params:
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        del axis_types
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _ensure_shard_map() -> None:
    """``jax.shard_map`` for jax < 0.6 (lives under jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        # new-jax spelling check_vma= maps onto old check_rep=
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map


def _ensure_axis_size() -> None:
    """``lax.axis_size`` for jax < 0.6.  ``lax.psum(1, axis)`` constant-folds
    to a Python int under tracing on old jax, which is exactly the static
    extent the ring schedules need for their python-loop trip counts."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return

    def axis_size(axis_name):
        return lax.psum(1, axis_name)

    lax.axis_size = axis_size


def _ensure_tree_paths() -> None:
    """``jax.tree.flatten_with_path`` / ``map_with_path`` for jax < 0.5."""
    import jax.tree as jtree
    import jax.tree_util as jtu

    if not hasattr(jtree, "flatten_with_path"):
        jtree.flatten_with_path = jtu.tree_flatten_with_path
    if not hasattr(jtree, "map_with_path"):
        jtree.map_with_path = jtu.tree_map_with_path


def install() -> None:
    _ensure_axis_type()
    _ensure_make_mesh_axis_types()
    _ensure_shard_map()
    _ensure_axis_size()
    _ensure_tree_paths()
