"""Pallas flash attention (online softmax) with GQA and sliding windows.

The perf-critical compute of every assigned transformer arch.  TPU-native
tiling: the grid walks (batch·q_heads, q_blocks, kv_blocks); each step stages
a q tile and a kv tile in VMEM and maintains the running max / normalizer /
accumulator in fp32 VMEM scratch — the memory hierarchy expressly replaces
the HBM-resident (S×S) score matrix, which at the prefill_32k shape would be
32768² × 4 B = 4 GB per head.

GQA is handled in the *index map*: the kv block index is derived from the q
head (``kvh = qh // group``), so kv tiles are fetched once per kv head and
never replicated in HBM.  Sliding-window attention (h2o-danube) adds a lower
bound to the visible column range; fully-masked tiles short-circuit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_kv: int, n_kv_blocks: int,
):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # visibility interval of this (i, j) tile pair
    def tile_visible():
        if not causal and window is None:
            return True
        vis = True
        if causal:
            # lowest q row is i*bq; highest kv col is j*bkv + bkv - 1
            vis = vis & (j * block_kv <= i * block_q + block_q - 1)
        if window is not None:
            # highest kv col must be >= lowest visible col of highest q row
            vis = vis & (j * block_kv + block_kv - 1 >= i * block_q - window + 1)
        return vis

    @pl.when(tile_visible())
    def _compute():
        q = q_ref[0].astype(jnp.float32)   # (bq, d)
        k = k_ref[0].astype(jnp.float32)   # (bkv, d)
        v = v_ref[0].astype(jnp.float32)   # (bkv, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                           # (bq, bkv)

        rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0:1]                      # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # exp of masked entries must be exactly 0 (not exp(-inf - -inf)=1)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_cur))
        alpha = jnp.exp(m_prev - m_cur)             # (bq, 1)
        l_new = l_ref[:, 0:1] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_kv_blocks - 1)
    def _finish():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    Sq/Skv must be divisible by the block sizes (ops.flash_attention pads).
    Returns (B, Hq, Sq, D) in q.dtype.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv, block_q, block_kv)
    scale = scale if scale is not None else d ** -0.5

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    n_kv_blocks = skv // block_kv

    def kv_index(bh, i, j):
        return ((bh // hq) * hkv + (bh % hq) // group, j, 0)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv_blocks=n_kv_blocks,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, sq // block_q, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, d), kv_index),
            pl.BlockSpec((1, block_kv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
