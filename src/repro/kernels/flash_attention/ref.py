"""Pure-jnp oracle for flash attention (materializes the score matrix)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  GQA by kv-head repeat."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)
    ) * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isnan(p), 0.0, p)
    denom = p.sum(axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / denom, vv.astype(jnp.float32))
    return out.astype(q.dtype)
