"""jit'd wrapper for flash attention: padding + CPU interpret fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import should_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _pad_seq(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    pad = (-x.shape[2]) % mult
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Flash attention over (B, H, S, D) tensors with GQA kv (B, Hkv, S, D).

    Sequence lengths are padded to block multiples; because padding keys are
    *future* positions under the causal mask (and windowed mask), they are
    invisible to real queries, and padded query rows are cropped.
    For non-causal use, padded kv would attend — so we require causal or
    explicit full blocks there (asserted).
    """
    if interpret is None:
        interpret = should_interpret()
    sq, skv = q.shape[2], k.shape[2]
    if not causal:
        assert sq % block_q == 0 and skv % block_kv == 0, (
            "non-causal attention requires block-aligned sequence lengths "
            f"(got {sq=}, {skv=})")
    qp, kp, vp = _pad_seq(q, block_q), _pad_seq(k, block_kv), _pad_seq(v, block_kv)
    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return out[:, :, :sq, :]
