"""Lax oracles for the fused collective matmuls.

These are the *semantic* references — the unfused composition of an XLA
builtin collective with a plain matmul.  The fused kernels must match them
to float tolerance (accumulation order differs: the ring adds partial sums
in hop order, ``psum_scatter`` in whatever order XLA picks).  The
*bitwise* reference is ``core/overlap.py``, whose schedules the fused
kernels reproduce op-for-op (asserted in ``tests/test_overlap.py``).

Both run inside ``shard_map`` over ``axis``, like every collective in
``repro.core``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def allgather_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, *,
                         axis: str) -> jnp.ndarray:
    """``all_gather(x, axis) @ w`` materialized: (B/n, K) → (B, N/n) f32."""
    full = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    return jnp.dot(full, w, preferred_element_type=jnp.float32)


def matmul_reducescatter_ref(x: jnp.ndarray, w: jnp.ndarray, *,
                             axis: str) -> jnp.ndarray:
    """``reduce_scatter(x @ w, axis)`` materialized: (B, K/n) → (B/n, N) f32."""
    partial = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return lax.psum_scatter(partial, axis, scatter_dimension=x.ndim - 2,
                            tiled=True)


__all__ = ["allgather_matmul_ref", "matmul_reducescatter_ref"]
