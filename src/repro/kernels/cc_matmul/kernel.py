"""Pallas collective-matmul kernels: the ring hop consumed *inside* the
kernel (SMI-style), instead of alternating ``ppermute`` with whole XLA
sub-matmul calls like ``core/overlap.py``.

Two paths, one schedule:

* **Remote-DMA path** (:func:`ag_matmul_ring_tpu`,
  :func:`rs_matmul_ring_tpu`) — a single ``pallas_call`` per collective
  matmul.  Hop *k+1*'s chunk is launched with
  ``pltpu.make_async_remote_copy`` into the free slot of a double-buffered
  VMEM scratch while hop *k*'s tile multiplies on the MXU; send/recv DMA
  semaphores fence slot reuse.  No XLA launch or HBM repack boundary
  between hops — the FPGA-native overlap of the Streaming Message
  Interface, played by the TPU DMA engines.  Requires a TPU backend
  (``kernels.common.supports_remote_dma``); there is no interpreter
  emulation of remote DMA.
* **Emulated path** (:func:`consume_matmul`, :func:`consume_matmul_acc`,
  :func:`matmul_tile`) — the hop itself stays a ``lax.ppermute`` (driven
  by ``ops.py``), but every arrival lands in the same double-buffered
  scratch layout and is consumed by a Pallas kernel reading its slot, so
  CPU CI exercises the identical code structure.  Under the interpreter
  the consume kernel lowers to the same ``jnp.dot`` the reference schedule
  issues, so the emulated path is **bit-identical** to ``core/overlap.py``
  (asserted in ``tests/test_overlap.py``).

Both paths run inside ``shard_map`` over a 1-D ring axis.  The per-hop
schedules mirror ``core/overlap.py`` op-for-op:

* all-gather matmul, hop *k* (direction *d*): multiply the block of rank
  ``(my − d·k) % n`` that just landed, place it at row
  ``src · b_stride + row_off`` of the output, while block *k+1* is in
  flight.
* matmul reduce-scatter, hop *k*: the accumulator rides the ring; after it
  lands, add the local partial ``dot(row_block(−d·(k+1)), w)`` computed
  under its flight, and forward.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compiler_params(collective_id: int):
    """Cross-version compiler params (renamed TPUCompilerParams → ...)."""
    cls = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams
    return cls(has_side_effects=True, collective_id=collective_id)


# ---------------------------------------------------------------------------
# Emulated path: per-hop consume kernels over the double-buffered scratch
# ---------------------------------------------------------------------------


def _matmul_tile_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def matmul_tile(x: jnp.ndarray, w: jnp.ndarray, *,
                interpret: bool) -> jnp.ndarray:
    """The resident block's tile: ``dot(x, w)`` in f32 (hop 0 has no
    arrival to consume, but still runs through the kernel surface)."""
    return pl.pallas_call(
        _matmul_tile_kernel,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(x, w)


def _consume_kernel(scr_ref, w_ref, o_ref, *, slot: int):
    # the hop's chunk is read straight out of its scratch slot — the
    # in-kernel message consumption the remote-DMA path does for real
    o_ref[...] = jnp.dot(scr_ref[slot], w_ref[...],
                         preferred_element_type=jnp.float32)


def consume_matmul(scratch: jnp.ndarray, w: jnp.ndarray, *, slot: int,
                   interpret: bool) -> jnp.ndarray:
    """AG hop consume: ``dot(scratch[slot], w)`` → (b, N) f32.

    ``scratch``: (2, b, K) double buffer; ``slot`` is static (the ring
    loop is python-unrolled, hop *k* lands in slot ``k % 2``).
    """
    return pl.pallas_call(
        functools.partial(_consume_kernel, slot=slot),
        out_shape=jax.ShapeDtypeStruct((scratch.shape[1], w.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(scratch, w)


def _consume_acc_kernel(scr_ref, x_ref, w_ref, o_ref, *, slot: int):
    # arrived accumulator + the local partial computed under its flight —
    # same add order as core/overlap.py (arr + dot), so bit-identical
    o_ref[...] = scr_ref[slot] + jnp.dot(x_ref[...], w_ref[...],
                                         preferred_element_type=jnp.float32)


def consume_matmul_acc(scratch: jnp.ndarray, x: jnp.ndarray,
                       w: jnp.ndarray, *, slot: int,
                       interpret: bool) -> jnp.ndarray:
    """RS hop consume: ``scratch[slot] + dot(x, w)`` → (b, N) f32.

    ``scratch``: (2, b, N) f32 double buffer of in-flight accumulators.
    """
    return pl.pallas_call(
        functools.partial(_consume_acc_kernel, slot=slot),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]),
                                       jnp.float32),
        interpret=interpret,
    )(scratch, x, w)


# ---------------------------------------------------------------------------
# Remote-DMA path: the whole ring inside one pallas_call (TPU only)
# ---------------------------------------------------------------------------


def _neighbor_barrier(axis: str, n: int):
    """Rendezvous with both ring neighbors before touching their VMEM —
    the standard guard against a fast rank DMA-ing into a peer whose
    previous kernel still owns the comm buffer."""
    my = lax.axis_index(axis)
    barrier = pltpu.get_barrier_semaphore()
    for nb in (1, n - 1):
        pltpu.semaphore_signal(
            barrier, inc=1, device_id=((my + nb) % n,),
            device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(barrier, 2)


def _ag_ring_kernel(x_ref, w_ref, o_ref, comm_ref, local_sem, send_sem,
                    recv_sem, *, axis: str, n: int, direction: int):
    my = lax.axis_index(axis)
    b = x_ref.shape[0]
    _neighbor_barrier(axis, n)

    # seed slot 0 with the resident block
    seed = pltpu.make_async_copy(x_ref, comm_ref.at[0], local_sem)
    seed.start()
    seed.wait()

    def rdma(hop):
        # forward the block in hand to the next rank's free slot
        return pltpu.make_async_remote_copy(
            src_ref=comm_ref.at[hop % 2],
            dst_ref=comm_ref.at[(hop + 1) % 2],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=((my + direction) % n,),
            device_id_type=pltpu.DeviceIdType.LOGICAL)

    for hop in range(n):
        if hop + 1 < n:
            rdma(hop).start()               # hop k+1's chunk in flight ...
        src = (my - direction * hop) % n
        o_ref[pl.ds(src * b, b), :] = jnp.dot(
            comm_ref[hop % 2], w_ref[...],
            preferred_element_type=jnp.float32)  # ... while hop k multiplies
        if hop + 1 < n:
            rdma(hop).wait()                # fence both slots before reuse


def ag_matmul_ring_tpu(x: jnp.ndarray, w: jnp.ndarray, *, axis: str,
                       n: int, direction: int = 1, collective_id: int = 0):
    """One-direction in-kernel AG matmul: (b, K) @ (K, N) → (n·b, N) f32,
    blocks in axis-index order.  The bidirectional composition in
    ``ops.py`` runs this twice (counter-rotating halves, distinct
    ``collective_id``) and interleaves the compact outputs."""
    b = x.shape[0]
    kernel = functools.partial(
        _ag_ring_kernel, axis=axis, n=n, direction=direction)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n * b, w.shape[1]), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, b, x.shape[1]), x.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=_compiler_params(collective_id),
    )(x, w)


def _rs_ring_kernel(x_ref, w_ref, o_ref, comm_ref, send_sem, recv_sem,
                    *, axis: str, n: int, direction: int, b_loc: int):
    my = lax.axis_index(axis)

    def partial_block(hop):
        # the block that must travel farthest next (overlap.py row_block)
        off = -direction * (hop + 1)
        start = ((my + off) % n) * b_loc
        return jnp.dot(x_ref[pl.ds(start, b_loc), :], w_ref[...],
                       preferred_element_type=jnp.float32)

    _neighbor_barrier(axis, n)
    comm_ref[0] = partial_block(0)

    def rdma(hop):
        return pltpu.make_async_remote_copy(
            src_ref=comm_ref.at[(hop - 1) % 2],
            dst_ref=comm_ref.at[hop % 2],
            send_sem=send_sem, recv_sem=recv_sem,
            device_id=((my + direction) % n,),
            device_id_type=pltpu.DeviceIdType.LOGICAL)

    for hop in range(1, n):
        rdma(hop).start()                   # accumulator rides the ring ...
        part = partial_block(hop)           # ... under the local partial
        rdma(hop).wait()
        comm_ref[hop % 2] = comm_ref[hop % 2] + part

    o_ref[...] = comm_ref[(n - 1) % 2]


def rs_matmul_ring_tpu(x: jnp.ndarray, w: jnp.ndarray, *, axis: str,
                       n: int, direction: int = 1,
                       collective_id: int = 0):
    """One-direction in-kernel matmul RS: (n·b, K) @ (K, N) → (b, N) f32."""
    b_loc = x.shape[0] // n
    kernel = functools.partial(
        _rs_ring_kernel, axis=axis, n=n, direction=direction, b_loc=b_loc)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b_loc, w.shape[1]), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((2, b_loc, w.shape[1]), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=_compiler_params(collective_id),
    )(x, w)


__all__ = [
    "matmul_tile", "consume_matmul", "consume_matmul_acc",
    "ag_matmul_ring_tpu", "rs_matmul_ring_tpu",
]
