"""Public fused collective-matmul ops: ``allgather_matmul_pallas`` and
``matmul_reducescatter_pallas``.

Differentiable (``jax.custom_vjp`` — each op's backward is the *other*
fused op plus a weight-gradient gather), batched (2-D ``(rows, K)`` or 3-D
``(B, rows, K)`` activations), and path-dispatched:

* **remote-DMA path** — the whole ring inside one ``pallas_call``
  (``kernel.ag_matmul_ring_tpu`` / ``rs_matmul_ring_tpu``) when the
  backend supports it (``kernels.common.supports_remote_dma``) and the
  row blocking is TPU-tileable; lane/contraction dims are zero-padded to
  128 (exact — zero columns of a matmul contribute nothing).
* **emulated path** — everywhere else: the hop stays a ``lax.ppermute``
  but every arrival lands in the same double-buffered scratch and is
  consumed by a Pallas kernel reading its slot.  Op-for-op the schedule
  of ``core/overlap.py``, hence bit-identical to it (and CI exercises
  the identical code structure the remote-DMA kernel runs).

Conduit integration: registered as the ``fused`` transport family for
``all_gather`` / ``reduce_scatter`` in ``core/conduit.py``;
``TransportPolicy.tp="fused"`` routes both TP edges of
``models/artblock.py`` here.  Like ``core/overlap.py``, both ops run
inside ``shard_map`` over a 1-D ring axis and return f32.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.art import _ring_perm
from repro.kernels import common
from repro.kernels.cc_matmul import kernel as K

# TPU tiling floor for the remote-DMA path: row blocks must be sublane-
# aligned (f32 tile height), lanes are padded to this multiple.
_ROW_ALIGN = 8
_LANE_ALIGN = 128


def _resolve_flags(interpret: Optional[bool],
                   use_remote_dma: Optional[bool]):
    if interpret is None:
        interpret = common.should_interpret()
    if use_remote_dma is None:
        use_remote_dma = common.supports_remote_dma() and not interpret
    return bool(interpret), bool(use_remote_dma) and not interpret


def _pad_cols(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Emulated schedules (bit-identical mirrors of core/overlap.py)
# ---------------------------------------------------------------------------


def _ag_2d(x, w, *, axis: str, bidirectional: bool, interpret: bool):
    """all_gather(x) @ w with the hop consumed from double-buffered
    scratch; schedule mirror of ``overlap.allgather_matmul``."""
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    b_loc = x.shape[0]
    out = jnp.zeros((n * b_loc, w.shape[1]), jnp.float32)

    if not bidirectional or n == 2:
        scr = jnp.zeros((2,) + x.shape, x.dtype).at[0].set(x)
        y0 = K.consume_matmul(scr, w, slot=0, interpret=interpret)
        out = lax.dynamic_update_slice(out, y0, (my * b_loc, 0))
        if n == 1:
            return out
        perm = _ring_perm(n, 1)
        for hop in range(1, n):
            prev, cur = (hop - 1) % 2, hop % 2
            # hop k's chunk lands in the free slot while slot `prev`'s
            # tile multiplies — the double-buffer discipline of the
            # remote-DMA kernel, ppermute standing in for the DMA
            arrived = lax.ppermute(scr[prev], axis, perm)
            scr = scr.at[cur].set(arrived)
            y = K.consume_matmul(scr, w, slot=cur, interpret=interpret)
            out = lax.dynamic_update_slice(
                out, y, (((my - hop) % n) * b_loc, 0))
        return out

    half = b_loc // 2
    lo, hi = x[:half], x[half:]
    scr_f = jnp.zeros((2,) + lo.shape, x.dtype).at[0].set(lo)
    scr_b = jnp.zeros((2,) + hi.shape, x.dtype).at[0].set(hi)

    def place(out, y, src, second_half):
        row = src * b_loc + (half if second_half else 0)
        return lax.dynamic_update_slice(out, y, (row, 0))

    out = place(out, K.consume_matmul(scr_f, w, slot=0,
                                      interpret=interpret), my, False)
    out = place(out, K.consume_matmul(scr_b, w, slot=0,
                                      interpret=interpret), my, True)
    if n == 1:
        return out
    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)
    for hop in range(1, n):
        prev, cur = (hop - 1) % 2, hop % 2
        arr_f = lax.ppermute(scr_f[prev], axis, fwd)
        arr_b = lax.ppermute(scr_b[prev], axis, bwd)
        scr_f = scr_f.at[cur].set(arr_f)
        scr_b = scr_b.at[cur].set(arr_b)
        out = place(out, K.consume_matmul(scr_f, w, slot=cur,
                                          interpret=interpret),
                    (my - hop) % n, False)
        out = place(out, K.consume_matmul(scr_b, w, slot=cur,
                                          interpret=interpret),
                    (my + hop) % n, True)
    return out


def _rs_2d(x, w, *, axis: str, bidirectional: bool, interpret: bool):
    """reduce_scatter(x @ w) with the in-flight accumulator consumed from
    double-buffered scratch; mirror of ``overlap.matmul_reducescatter``."""
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    b = x.shape[0]
    assert b % n == 0, (b, n)
    b_loc = b // n

    def row_block(owner_offset: int):
        start = ((my + owner_offset) % n) * b_loc
        return lax.dynamic_slice_in_dim(x, start, b_loc, 0)

    if not bidirectional or n == 2:
        acc = K.matmul_tile(row_block(-1), w, interpret=interpret)
        if n == 1:
            return acc
        perm = _ring_perm(n, 1)
        scr = jnp.zeros((2, b_loc, w.shape[1]), jnp.float32)
        for hop in range(1, n):
            cur = hop % 2
            arrived = lax.ppermute(acc, axis, perm)
            scr = scr.at[cur].set(arrived)
            acc = K.consume_matmul_acc(scr, row_block(-(hop + 1)), w,
                                       slot=cur, interpret=interpret)
        return acc

    nloc = w.shape[1]
    half = nloc // 2

    def w_part(second_half):
        return w[:, half:] if second_half else w[:, :half]

    if n == 1:
        return jnp.concatenate(
            [K.matmul_tile(row_block(-1), w_part(False),
                           interpret=interpret),
             K.matmul_tile(row_block(+1), w_part(True),
                           interpret=interpret)], axis=1)

    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)
    acc_f = K.matmul_tile(row_block(-1), w_part(False), interpret=interpret)
    acc_b = K.matmul_tile(row_block(+1), w_part(True), interpret=interpret)
    scr_f = jnp.zeros((2, b_loc, half), jnp.float32)
    scr_b = jnp.zeros((2, b_loc, nloc - half), jnp.float32)
    for hop in range(1, n):
        cur = hop % 2
        arr_f = lax.ppermute(acc_f, axis, fwd)
        arr_b = lax.ppermute(acc_b, axis, bwd)
        scr_f = scr_f.at[cur].set(arr_f)
        scr_b = scr_b.at[cur].set(arr_b)
        acc_f = K.consume_matmul_acc(scr_f, row_block(-(hop + 1)),
                                     w_part(False), slot=cur,
                                     interpret=interpret)
        acc_b = K.consume_matmul_acc(scr_b, row_block(+(hop + 1)),
                                     w_part(True), slot=cur,
                                     interpret=interpret)
    return jnp.concatenate([acc_f, acc_b], axis=1)


# ---------------------------------------------------------------------------
# Remote-DMA schedules (TPU): pad lanes, run the in-kernel ring
# ---------------------------------------------------------------------------


def _ag_2d_tpu(x, w, *, axis: str, bidirectional: bool):
    n = lax.axis_size(axis)
    b_loc, n_out = x.shape[0], w.shape[1]
    x = _pad_cols(x, 1, _LANE_ALIGN)
    w = _pad_cols(_pad_cols(w, 0, _LANE_ALIGN), 1, _LANE_ALIGN)
    if n == 1:
        return K.matmul_tile(x, w, interpret=False)[:, :n_out]
    if not bidirectional or n == 2:
        y = K.ag_matmul_ring_tpu(x, w, axis=axis, n=n, direction=1)
        return y[:, :n_out]
    half = b_loc // 2
    y_lo = K.ag_matmul_ring_tpu(x[:half], w, axis=axis, n=n, direction=1,
                                collective_id=0)
    y_hi = K.ag_matmul_ring_tpu(x[half:], w, axis=axis, n=n, direction=-1,
                                collective_id=1)
    nl = y_lo.shape[1]
    y = jnp.concatenate(
        [y_lo.reshape(n, half, nl), y_hi.reshape(n, b_loc - half, nl)],
        axis=1).reshape(n * b_loc, nl)
    return y[:, :n_out]


def _rs_2d_tpu(x, w, *, axis: str, bidirectional: bool):
    n = lax.axis_size(axis)
    n_out = w.shape[1]
    x = _pad_cols(x, 1, _LANE_ALIGN)
    w = _pad_cols(w, 0, _LANE_ALIGN)
    if n == 1:
        return K.matmul_tile(x, _pad_cols(w, 1, _LANE_ALIGN),
                             interpret=False)[:, :n_out]
    if not bidirectional or n == 2:
        wp = _pad_cols(w, 1, _LANE_ALIGN)
        y = K.rs_matmul_ring_tpu(x, wp, axis=axis, n=n, direction=1)
        return y[:, :n_out]
    half = n_out // 2
    y_lo = K.rs_matmul_ring_tpu(
        x, _pad_cols(w[:, :half], 1, _LANE_ALIGN), axis=axis, n=n,
        direction=1, collective_id=0)[:, :half]
    y_hi = K.rs_matmul_ring_tpu(
        x, _pad_cols(w[:, half:], 1, _LANE_ALIGN), axis=axis, n=n,
        direction=-1, collective_id=1)[:, : n_out - half]
    return jnp.concatenate([y_lo, y_hi], axis=1)


def _rows_tpu_ok(rows: int, bidirectional: bool) -> bool:
    """Row blocking the remote-DMA kernels can tile without row padding
    (which would interleave garbage rows into the gathered layout)."""
    if rows % _ROW_ALIGN:
        return False
    if bidirectional and (rows // 2) % _ROW_ALIGN:
        return False
    return True


# ---------------------------------------------------------------------------
# Dispatch + batching + custom VJP
# ---------------------------------------------------------------------------


def _ag_impl(x, w, *, axis, bidirectional, interpret, use_remote_dma):
    if use_remote_dma and _rows_tpu_ok(x.shape[-2], bidirectional):
        fn2d = functools.partial(_ag_2d_tpu, axis=axis,
                                 bidirectional=bidirectional)
    else:
        fn2d = functools.partial(_ag_2d, axis=axis,
                                 bidirectional=bidirectional,
                                 interpret=interpret)
    if x.ndim == 3:
        return jax.vmap(lambda xb: fn2d(xb, w))(x)
    return fn2d(x, w)


def _rs_impl(x, w, *, axis, bidirectional, interpret, use_remote_dma):
    n_rows = x.shape[-2]
    if use_remote_dma and n_rows % _ROW_ALIGN == 0:
        fn2d = functools.partial(_rs_2d_tpu, axis=axis,
                                 bidirectional=bidirectional)
    else:
        fn2d = functools.partial(_rs_2d, axis=axis,
                                 bidirectional=bidirectional,
                                 interpret=interpret)
    if x.ndim == 3:
        return jax.vmap(lambda xb: fn2d(xb, w))(x)
    return fn2d(x, w)


def _gather_rows(t, axis: str):
    """Plain ring-oblivious gather for weight gradients (bwd only)."""
    return lax.all_gather(t, axis, axis=t.ndim - 2, tiled=True)


@functools.lru_cache(maxsize=None)
def _ag_vjp(axis: str, bidirectional: bool, interpret: bool,
            use_remote_dma: bool):
    kw = dict(axis=axis, bidirectional=bidirectional, interpret=interpret,
              use_remote_dma=use_remote_dma)

    @jax.custom_vjp
    def f(x, w):
        return _ag_impl(x, w, **kw)

    def fwd(x, w):
        return _ag_impl(x, w, **kw), (x, w)

    def bwd(res, g):
        x, w = res
        # y = AG(x) @ w  ⇒  dx = RS(g @ wᵀ) — itself a fused ring —
        # and dw = AG(x)ᵀ @ g (plain gather; wgrads are not ring-shaped)
        dx = _rs_impl(g, w.T, **kw).astype(x.dtype)
        x_full = _gather_rows(x, axis)
        if x.ndim == 3:
            dw = jnp.einsum("bik,bin->kn", x_full, g,
                            preferred_element_type=jnp.float32)
        else:
            dw = jnp.dot(x_full.T, g, preferred_element_type=jnp.float32)
        return dx, dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _rs_vjp(axis: str, bidirectional: bool, interpret: bool,
            use_remote_dma: bool):
    kw = dict(axis=axis, bidirectional=bidirectional, interpret=interpret,
              use_remote_dma=use_remote_dma)

    @jax.custom_vjp
    def f(x, w):
        return _rs_impl(x, w, **kw)

    def fwd(x, w):
        return _rs_impl(x, w, **kw), (x, w)

    def bwd(res, g):
        x, w = res
        # y = RS(x @ w)  ⇒  dY = AG(g), dx = dY @ wᵀ = fused AG-matmul,
        # dw = xᵀ @ dY (plain gather)
        dx = _ag_impl(g, w.T, **kw).astype(x.dtype)
        g_full = _gather_rows(g, axis)
        if x.ndim == 3:
            dw = jnp.einsum("bik,bin->kn", x, g_full,
                            preferred_element_type=jnp.float32)
        else:
            dw = jnp.dot(x.T, g_full, preferred_element_type=jnp.float32)
        return dx, dw.astype(w.dtype)

    f.defvjp(fwd, bwd)
    return f


def allgather_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    bidirectional: bool = True,
    interpret: Optional[bool] = None,
    use_remote_dma: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused ``all_gather(x, axis) @ w`` — the ring consumed in-kernel.

    ``x``: (b, K) or (B, b, K) local rows; ``w``: (K, N_loc) resident
    column shard; returns (n·b, N_loc) / (B, n·b, N_loc) f32 — the same
    contract (and, on the emulated path, the same bits) as
    ``overlap.allgather_matmul``.
    """
    assert x.ndim in (2, 3), x.shape
    interpret, use_remote_dma = _resolve_flags(interpret, use_remote_dma)
    return _ag_vjp(axis, bool(bidirectional), interpret,
                   use_remote_dma)(x, w)


def matmul_reducescatter_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    bidirectional: bool = True,
    interpret: Optional[bool] = None,
    use_remote_dma: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused ``reduce_scatter(x @ w, axis)`` — accumulators ride the ring
    in-kernel.

    ``x``: (n·b, K_loc) or (B, n·b, K_loc); ``w``: (K_loc, N) resident row
    shard; returns (b, N) / (B, b, N) f32 — the contract (and emulated-path
    bits) of ``overlap.matmul_reducescatter``.
    """
    assert x.ndim in (2, 3), x.shape
    interpret, use_remote_dma = _resolve_flags(interpret, use_remote_dma)
    return _rs_vjp(axis, bool(bidirectional), interpret,
                   use_remote_dma)(x, w)


__all__ = ["allgather_matmul_pallas", "matmul_reducescatter_pallas"]
