"""Fused collective matmuls: the ring hop consumed inside the Pallas
kernel (SMI-style), conduit transport family ``fused``."""

from repro.kernels.cc_matmul.kernel import (
    ag_matmul_ring_tpu,
    consume_matmul,
    consume_matmul_acc,
    matmul_tile,
    rs_matmul_ring_tpu,
)
from repro.kernels.cc_matmul.ops import (
    allgather_matmul_pallas,
    matmul_reducescatter_pallas,
)
from repro.kernels.cc_matmul.ref import (
    allgather_matmul_ref,
    matmul_reducescatter_ref,
)

__all__ = [
    "allgather_matmul_pallas",
    "matmul_reducescatter_pallas",
    "allgather_matmul_ref",
    "matmul_reducescatter_ref",
    "ag_matmul_ring_tpu",
    "rs_matmul_ring_tpu",
    "consume_matmul",
    "consume_matmul_acc",
    "matmul_tile",
]
