"""Pure-jnp oracle for the SSD kernel: the exact sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b: jnp.ndarray,      # (B, S, G, N)
    c: jnp.ndarray,      # (B, S, G, N)
    d: jnp.ndarray,      # (H,)
):
    """Step-by-step recurrence (the definition the chunked kernel must match).

    Returns (y: (B, S, H, P), final_state: (B, H, N, P) fp32).
    """
    bsz, s, h, p = x.shape
    _, _, g, n = b.shape
    hpg = h // g
    bh = jnp.repeat(b, hpg, axis=2).astype(jnp.float32)  # (B,S,H,N)
    ch = jnp.repeat(c, hpg, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(af[None, :] * dtt)                       # (B,H)
        state = decay[..., None, None] * state + (
            dtt[..., None, None] * bt[..., :, None] * xt[..., None, :]
        )                                                        # (B,H,N,P)
        yt = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, yt

    state0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    inputs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(bh, 1, 0),
        jnp.moveaxis(ch, 1, 0),
    )
    final_state, ys = jax.lax.scan(step, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1) + d.astype(jnp.float32) [None, None, :, None] * xf
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, N, P) fp32
    xt: jnp.ndarray,     # (B, H, P)
    dtt: jnp.ndarray,    # (B, H)
    a: jnp.ndarray,      # (H,)
    bt: jnp.ndarray,     # (B, G, N)
    ct: jnp.ndarray,     # (B, G, N)
    d: jnp.ndarray,      # (H,)
):
    """Single-token recurrence for serving (O(1) per token — why the SSM
    archs run the long_500k decode shape).  Returns (state, y_t)."""
    bsz, h, n, p = state.shape
    g = bt.shape[1]
    hpg = h // g
    bh = jnp.repeat(bt, hpg, axis=1).astype(jnp.float32)  # (B,H,N)
    ch = jnp.repeat(ct, hpg, axis=1).astype(jnp.float32)
    decay = jnp.exp(a.astype(jnp.float32)[None, :] * dtt)  # (B,H)
    state = decay[..., None, None] * state + (
        dtt[..., None, None] * bh[..., :, None] * xt.astype(jnp.float32)[..., None, :]
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch, state) + d[None, :, None] * xt
    return state, y.astype(xt.dtype)
