"""Pallas SSD (state-space duality) kernel — Mamba-2's chunked scan.

Implements the SSD decomposition (Dao & Gu, arXiv:2405.21060): the sequence
is split into chunks of length L; within a chunk the recurrence is computed
as a (masked, decay-weighted) attention-like matmul (MXU work), and across
chunks only the (N×P) state is carried — giving O(S·L) work with O(N·P)
carried state instead of the O(S²) of attention.  This is what makes the
``long_500k`` shape feasible for mamba2/zamba2.

Recurrence (per batch b, head h, with group g = h // (H//G)):
    state_t = exp(A_h·dt_t)·state_{t-1} + dt_t · B_t ⊗ x_t        (N×P)
    y_t     = C_tᵀ·state_t + D_h·x_t

Chunked form computed by the kernel per chunk (cum = inclusive cumsum of
a_t = A_h·dt_t within the chunk; total = cum[L−1]):
    Y_intra = ((C Bᵀ) ⊙ exp(cum_i − cum_j) ⊙ dt_j ⊙ [i ≥ j]) @ X
    Y_inter = exp(cum) ⊙ (C @ state_prev)
    state   = exp(total)·state_prev + (B ⊙ dt·exp(total − cum))ᵀ @ X

TPU mapping: grid = (B, H, S/L); the chunk axis is innermost, so the fp32
(N×P) state lives in VMEM scratch across the sequential chunk walk — the
carried state never touches HBM (the same locality the paper gets from
keeping data in each FPGA's partition).  All decays are ≤ 1 (A < 0, dt > 0),
so exp() is numerically safe in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, state_in_ref,
    y_ref, state_out_ref, state_ref,
    *, n_chunks: int, chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = state_in_ref[0, 0]

    x = x_ref[0, :, 0, :].astype(jnp.float32)       # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # (L,)
    a_log = a_ref[0].astype(jnp.float32) * dt       # (L,)  A_h * dt_t  (< 0)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)    # (L, N)
    d_skip = d_ref[0].astype(jnp.float32)

    cum = jnp.cumsum(a_log)                         # (L,)
    total = cum[chunk - 1]

    # --- intra-chunk: masked decay-weighted "attention" ---
    seg = cum[:, None] - cum[None, :]               # (L, L) ; i>=j => <= 0
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(ii >= jj, seg, NEG_INF)
    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (L, L) C_i · B_j
    weights = scores * jnp.exp(seg) * dt[None, :]
    y = jax.lax.dot_general(
        weights, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (L, P)

    # --- inter-chunk: contribution of the carried state ---
    state = state_ref[...]                          # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # --- D skip connection ---
    y += d_skip * x

    # --- state update (overlappable with next chunk's intra work) ---
    decay_to_end = jnp.exp(total - cum) * dt        # (L,)
    state_ref[...] = jnp.exp(total) * state + jax.lax.dot_general(
        bmat * decay_to_end[:, None], x,
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...].astype(state_out_ref.dtype)


def ssd_pallas(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)   positive
    a: jnp.ndarray,      # (H,)        negative
    b: jnp.ndarray,      # (B, S, G, N)
    c: jnp.ndarray,      # (B, S, G, N)
    d: jnp.ndarray,      # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
    init_state: jnp.ndarray | None = None,   # (B, H, N, P) fp32
):
    """Returns (y: (B, S, H, P), final_state: (B, H, N, P) fp32).

    ``init_state`` seeds the carried (N×P) state (zeros when ``None``) —
    the chunk-fed entry point (``ops.ssd_chunk_fed``) threads each
    segment's final state into the next segment's scan through it.
    """
    bsz, s, h, p = x.shape
    _, _, g, n = b.shape
    assert h % g == 0, (h, g)
    assert s % chunk == 0, (s, chunk)
    hpg = h // g
    n_chunks = s // chunk
    if init_state is None:
        init_state = jnp.zeros((bsz, h, n, p), jnp.float32)
    assert init_state.shape == (bsz, h, n, p), init_state.shape

    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, d, init_state.astype(jnp.float32))
    return y, state
