from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ops import ssd, ssd_chunk_fed
from repro.kernels.ssd.ref import ssd_decode_step, ssd_ref

__all__ = ["ssd", "ssd_chunk_fed", "ssd_pallas", "ssd_ref", "ssd_decode_step"]
