"""jit'd wrapper for the SSD kernel: padding + CPU interpret fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b: jnp.ndarray,      # (B, S, G, N)
    c: jnp.ndarray,      # (B, S, G, N)
    d: jnp.ndarray,      # (H,)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
):
    """Chunked SSD scan; pads S to a chunk multiple (dt=0 ⇒ identity steps:
    decay exp(0)=1 and zero state injection, so padding is exact).

    Returns (y: (B, S, H, P), final_state: (B, H, N, P) fp32).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_pallas(x, dt, a, b, c, d, chunk=chunk, interpret=interpret)
    return y[:, :s], state
