"""jit'd wrapper for the SSD kernel: padding + CPU interpret fallback,
plus the chunk-fed entry point (segments streamed into the scan)."""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pipeline
from repro.kernels.common import should_interpret
from repro.kernels.ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jnp.ndarray,      # (B, S, H, P)
    dt: jnp.ndarray,     # (B, S, H)
    a: jnp.ndarray,      # (H,)
    b: jnp.ndarray,      # (B, S, G, N)
    c: jnp.ndarray,      # (B, S, G, N)
    d: jnp.ndarray,      # (H,)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
    init_state: jnp.ndarray | None = None,   # (B, H, N, P) fp32
):
    """Chunked SSD scan; pads S to a chunk multiple (dt=0 ⇒ identity steps:
    decay exp(0)=1 and zero state injection, so padding is exact).

    Returns (y: (B, S, H, P), final_state: (B, H, N, P) fp32).
    ``init_state`` seeds the carried state (zeros when ``None``).
    """
    if interpret is None:
        interpret = should_interpret()
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, state = ssd_pallas(x, dt, a, b, c, d, chunk=chunk, interpret=interpret,
                          init_state=init_state)
    return y[:, :s], state


def ssd_chunk_fed(
    fetch: Callable[[int], Tuple[jnp.ndarray, ...]],
    n_segments: int,
    a: jnp.ndarray,      # (H,)
    d: jnp.ndarray,      # (H,)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
    init_state: jnp.ndarray | None = None,
):
    """SSD scan over a sequence delivered segment-by-segment: the fetch of
    segment *k* (e.g. a conduit collective, a host DMA) is issued while
    segment *k−1*'s scan runs — :func:`repro.core.pipeline.streamed` with
    the (N×P) state carried across segments through ``init_state``.

    ``fetch(k) -> (x, dt, b, c)`` delivers segment *k*'s slices (the
    per-segment shapes of :func:`ssd`; segment lengths may differ).  The
    scan of segment *k* consumes segment *k−1*'s arrival, so the wire
    hides under the chunk loop — the same consume-inside-the-pipeline
    discipline as the ``fused`` collective matmuls, applied to the SSD
    chunk walk.

    When every segment length is a multiple of ``chunk`` the result is
    bit-identical to the bulk :func:`ssd` call (identical chunk
    boundaries, identical op order); otherwise the per-segment padding
    moves chunk boundaries and the match is allclose-level.

    Returns (y: (B, S_total, H, P), final_state: (B, H, N, P) fp32).
    """
    if interpret is None:
        interpret = should_interpret()
    if n_segments <= 0:
        raise ValueError("n_segments must be positive")
    carried = [init_state]

    def consume(_k, seg):
        x, dt, b, c = seg
        y, state = ssd(x, dt, a, b, c, d, chunk=chunk, interpret=interpret,
                       init_state=carried[0])
        carried[0] = state
        return y

    ys = pipeline.streamed(n_segments, fetch, consume)
    return jnp.concatenate(ys, axis=1), carried[0]
