"""Pallas TPU kernels for the compute hot-spots (each: kernel + ops + ref).

matmul           — DLA-analogue fused matmul+bias+activation (MXU tiling)
flash_attention  — online-softmax attention, GQA/causal/sliding-window
ssd              — Mamba-2 chunked state-space scan (state carried in VMEM)

All validate against their pure-jnp ref oracles under interpret=True on CPU
(the container has no TPU); ``ops.py`` wrappers auto-select interpret mode.
"""

from repro.kernels import cc_matmul, flash_attention, matmul, ssd

__all__ = ["cc_matmul", "flash_attention", "matmul", "ssd"]
