"""Pure-jnp oracle for the Pallas matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    activation: str = "none",
    out_dtype=None,
) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "relu2":
        r = jnp.maximum(y, 0.0)
        y = r * r
    elif activation == "silu":
        y = y * jax.nn.sigmoid(y)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation != "none":
        raise ValueError(activation)
    return y.astype(out_dtype)
