"""Pallas MXU block matmul — the TPU analogue of the paper's Intel DLA.

The DLA is a 1-D systolic array (16×8 PEs) fed by on-chip buffers, with
"computation types and tensor sizes exposed as arguments" (paper Sec. III-B).
On TPU the systolic array is the 128×128 MXU and the feeder logic is the
BlockSpec pipeline: each grid step stages an (bm×bk) activation tile and a
(bk×bn) weight tile into VMEM, accumulates into an fp32 VMEM scratch tile,
and writes the output tile back to HBM when the K loop completes.

Like the DLA, the kernel exposes its "computation type" as arguments: an
optional bias add and a fused activation (none / relu / squared-relu — the
Nemotron-4 nonlinearity / silu / gelu), so an entire DLA-style
matmul+activation instruction is one kernel launch.

Block sizes default to 128/512 multiples so every matmul dimension is
MXU-aligned (multiples of 128) and the working set
(bm·bk + bk·bn + 2·bm·bn fp32 words ≈ 0.9 MB at 128/512/128) sits well
inside the ~16 MB/core VMEM with room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ACTIVATIONS = ("none", "relu", "relu2", "silu", "gelu")


def _apply_activation(x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "relu2":  # squared ReLU (Nemotron-4)
        r = jnp.maximum(x, 0.0)
        return r * r
    if activation == "silu":
        return x * jax.nn.sigmoid(x)
    if activation == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {activation!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, activation: str,
                   has_bias: bool):
    """Grid: (M/bm, N/bn, K/bk); K innermost so the accumulator tile stays
    resident in VMEM across the contraction (the systolic accumulate)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _finish():
        acc = acc_ref[...]
        if has_bias:
            acc = acc + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_activation(acc, activation).astype(o_ref.dtype)


def matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """``activation(x @ w + bias)`` with fp32 accumulation.

    x: (M, K); w: (K, N); bias: (N,) or None.  M, K, N must be divisible by
    the block sizes (``ops.matmul`` pads arbitrary shapes before calling).
    """
    assert activation in ACTIVATIONS, activation
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, k, n), (block_m, block_k, block_n))
    out_dtype = out_dtype or x.dtype
    nk = k // block_k
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((n,), dtype=x.dtype)
    bias2d = bias.reshape(1, n)

    kernel = functools.partial(
        _matmul_kernel, nk=nk, activation=activation, has_bias=has_bias
    )
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, bias2d)
