"""jit'd public wrapper around the Pallas matmul: padding, dtype policy,
interpret-mode fallback on CPU, and batched (3-D) inputs."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import should_interpret
from repro.kernels.matmul.kernel import matmul_pallas


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# kept as an alias: tests and older call sites import the historical name
_should_interpret = should_interpret


@functools.partial(
    jax.jit,
    static_argnames=(
        "activation", "block_m", "block_n", "block_k", "out_dtype", "interpret"
    ),
)
def matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray | None = None,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """DLA-style fused ``activation(x @ w + bias)``.

    Accepts (M, K) or batched (..., M, K) ``x``; arbitrary (unaligned) shapes
    are zero-padded to block multiples and cropped after — zero rows/cols of
    a matmul are exact, and all supported activations map 0 -> 0, so padding
    does not perturb results.
    """
    if interpret is None:
        interpret = should_interpret()
    out_dtype = out_dtype or x.dtype

    batch_shape = x.shape[:-2]
    m, k = x.shape[-2], x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape((-1, k)) if batch_shape else x
    # fold batch into M (weights shared across batch)
    xp = _pad_to(_pad_to(x2, 0, block_m), 1, block_k)
    wp = _pad_to(_pad_to(w, 0, block_k), 1, block_n)
    bp = _pad_to(bias, 0, block_n) if bias is not None else None
    y = matmul_pallas(
        xp, wp, bp,
        activation=activation,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    y = y[: x2.shape[0], :n]
    return y.reshape(batch_shape + (m, n)) if batch_shape else y
