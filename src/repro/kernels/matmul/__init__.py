from repro.kernels.matmul.kernel import matmul_pallas
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref

__all__ = ["matmul", "matmul_pallas", "matmul_ref"]
