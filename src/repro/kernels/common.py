"""Shared kernel-wrapper policy: when does a Pallas call run in interpret
mode, and when may it use TPU remote-DMA semantics.

Every public kernel wrapper (``matmul``, ``flash_attention``, ``ssd``,
``cc_matmul``) used to carry its own copy of the CPU fallback test; this is
the one home.  Two knobs:

* :func:`should_interpret` — Pallas TPU kernels cannot compile on a CPU
  backend, so CI runs them through the Pallas interpreter.  The
  ``REPRO_PALLAS_INTERPRET`` environment variable overrides the backend
  sniff in either direction (``1``/``true`` forces interpret mode even on
  an accelerator — useful for numerics bisection; ``0``/``false`` forces
  compilation — useful to prove a kernel actually lowers).
* :func:`supports_remote_dma` — whether the in-kernel collective path
  (``pltpu.make_async_remote_copy`` in ``kernels/cc_matmul``) can run.
  Remote DMA exists only on a real TPU backend and has no interpreter
  emulation, so interpret mode always disables it.
"""

from __future__ import annotations

import os

import jax

#: env var forcing interpret mode on ("1"/"true"/"yes") or off ("0"/...).
INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")


def should_interpret() -> bool:
    """True when Pallas calls should run under the interpreter.

    Precedence: the ``REPRO_PALLAS_INTERPRET`` env override, else
    ``jax.default_backend() == "cpu"`` (the only backend with no Mosaic
    lowering).  Unrecognized override values fall back to the sniff.
    """
    raw = os.environ.get(INTERPRET_ENV, "").strip().lower()
    if raw in _TRUTHY:
        return True
    if raw in _FALSY:
        return False
    return jax.default_backend() == "cpu"


def supports_remote_dma() -> bool:
    """True when the in-kernel remote-DMA collective path can run: a TPU
    backend and not forced into interpret mode."""
    return jax.default_backend() == "tpu" and not should_interpret()


__all__ = ["INTERPRET_ENV", "should_interpret", "supports_remote_dma"]
