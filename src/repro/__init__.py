"""FSHMEM-JAX: PGAS communication substrate for TPU pods.

Reproduction + extension of "FSHMEM: Supporting Partitioned Global Address
Space on FPGAs for Large-Scale Hardware Acceleration Infrastructure"
(Arthanto, Ojika, Kim — CS.DC 2022).  See DESIGN.md / EXPERIMENTS.md.
"""

from repro import compat as _compat

_compat.install()

__all__ = ["dist"]
__version__ = "1.1.0"


def __getattr__(name):
    # Lazy re-export: `repro.dist` pulls in the full model/optim stack, which
    # lightweight consumers (e.g. the analytic netmodel) shouldn't pay for —
    # only the compat shims must run at package import.
    if name == "dist":
        import importlib

        return importlib.import_module("repro.dist")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
