"""FSHMEM-JAX: PGAS communication substrate for TPU pods.

Reproduction + extension of "FSHMEM: Supporting Partitioned Global Address
Space on FPGAs for Large-Scale Hardware Acceleration Infrastructure"
(Arthanto, Ojika, Kim — CS.DC 2022).  See DESIGN.md / EXPERIMENTS.md.
"""

__version__ = "1.0.0"
