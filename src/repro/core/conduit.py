"""GASNet-style conduit layer: one collective API, interchangeable transports.

GASNet's portability comes from its *conduit* abstraction — one core API
compiled against many network backends.  This module is that layer for the
repo: every collective op (``all_gather``, ``reduce_scatter``,
``all_reduce``, ``all_to_all``, ``broadcast``, ``barrier``) is served by a
registry of named transports, and everything above (``core/collectives``,
``core/overlap``, ``models/artblock``, ``dist/grad_sync``, ``dist/steps``)
goes through a :class:`Conduit` handle instead of hard-coding a schedule.

Registered transports:

``xla``
    The XLA built-in collectives (``lax.psum`` & friends).  The compiler
    picks the algorithm; per-message latency is low (tree/doubling style)
    but the schedule ignores ring locality.
``ring``
    The paper-faithful unidirectional PUT rings: n−1 neighbor hops, every
    hop an ``fshmem_put``-sized message (DESIGN §4).  Bandwidth-optimal
    per link direction.
``bidir``
    Two counter-rotating half-sized rings.  Links are full-duplex (QSFP+,
    ICI), so splitting the payload across both directions halves the bytes
    each direction carries — the generalization of the bidirectional
    matmul schedules in ``core/overlap.py`` to the bare collectives.
    (For ``all_to_all`` the permutes are direction-symmetric —
    ``(i+s) % n == (i-(n-s)) % n`` — so ``bidir`` differs only in hop
    *distance*, which the cost model prices; the wire schedule enumerates
    shifts as ±s.)

Every ring transport accepts an ART chunk size (``chunk_bytes``): the
per-hop message is split into ⌈hop_bytes / chunk_bytes⌉ independent pieces
so XLA's latency-hiding scheduler can pipeline them — the paper's packet
size knob (Fig. 5) surfaced as a software parameter.  Chunking never
changes numerics: pieces partition the payload elementwise and each piece
runs the identical ring order.

``auto`` is not a transport but a *policy*: :func:`auto_select` queries the
analytic netmodel (``core/netmodel.py``) per (op, bytes, axis size) and
returns the (transport, chunk) pair with the lowest modeled time — the
paper's Fig. 5 message-size × packet-size tradeoff turned into a runtime
decision.  Small messages resolve to ``xla`` (fewest per-message
latencies); large messages resolve to ``bidir`` (full-duplex bandwidth).

Every op can also run **streamed** (:meth:`Conduit.streamed`): the payload
partitioned into chunks, chunk *k*'s collective issued while per-chunk
work digests chunk *k−1* — the generalized ART schedule of
``core/pipeline.py``.  :func:`pipeline_estimate` /
:func:`auto_select_pipeline` are the matching cost model: they price the
whole pipeline against a ``compute_time`` and pick the chunk count that
maximizes *hiding* rather than minimizing standalone wire time.

All collective entry points run *inside* ``shard_map`` over the conduit's
axis, like everything else in ``repro.core``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core import netmodel as nm
from repro.core import pipeline as pl
from repro.core.art import _ring_perm

OPS = (
    "all_gather",
    "reduce_scatter",
    "all_reduce",
    "all_to_all",
    "broadcast",
    "barrier",
)

LINKS: Dict[str, nm.LinkParams] = {
    "qsfp": nm.FSHMEM_QSFP,
    "ici": nm.TPU_ICI,
}

# ---------------------------------------------------------------------------
# Failure surface: a lost peer raises instead of hanging
# ---------------------------------------------------------------------------


class RankFailure(RuntimeError):
    """A peer rank is unreachable: the typed failure every conduit and AM
    entry point raises instead of hanging on a dead link.

    On real hardware this is the NIC timeout / coordination-service
    heartbeat miss; in simulation the fault-injection harness
    (``repro.runtime.faults``) raises it through the installed failure
    hook.  Carries the failing ``rank`` (or ``None`` when unattributed)
    and the ``op`` that tripped it so the recovery path
    (``repro.runtime.elastic.ElasticRuntime``) can exclude the dead
    member and re-form.
    """

    def __init__(self, rank: Optional[int] = None, op: str = "",
                 detail: str = "", ranks: Optional[Sequence[int]] = None):
        """Record the failing ``rank``/``ranks`` and the op involved.

        ``ranks`` carries a *batch* of simultaneous losses (the membership
        detector declares every rank that missed the same deadline in one
        exception so recovery re-forms once); it defaults to ``(rank,)``.
        """
        self.rank, self.op = rank, op
        if ranks is not None:
            self.ranks: Tuple[int, ...] = tuple(int(r) for r in ranks)
        else:
            self.ranks = (rank,) if rank is not None else ()
        msg = f"rank failure on {op or 'collective'}"
        if len(self.ranks) > 1:
            msg += f" (ranks {list(self.ranks)})"
        elif rank is not None:
            msg += f" (rank {rank})"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class StaleEpoch(RankFailure):
    """An operation built against a superseded membership view ran anyway.

    Membership changes are versioned **epochs** (``runtime/membership.py``):
    a conduit or AM wire pinned at epoch ``built`` that executes after the
    membership advanced to ``current`` raises this instead of touching the
    network — in-flight work from a dead view can never corrupt the new
    one.  A subclass of :class:`RankFailure` so every existing recovery
    catch path already handles it; :class:`RetryingConduit` never retries
    it (the view is gone, not wobbling — the caller must rebuild against
    :func:`current_epoch`).
    """

    def __init__(self, built: int, current: int, op: str = ""):
        """Record the epoch the op was ``built`` at vs the ``current`` one."""
        self.built, self.current = int(built), int(current)
        super().__init__(
            None, op,
            f"built at epoch {self.built}, membership now at {self.current}")


#: installed failure probe: ``fn(op, axis)`` raises :class:`RankFailure`
#: when the scripted/observed membership says a peer is gone
_FAILURE_HOOK: Optional[Callable[[str, str], None]] = None


def install_failure_hook(fn: Callable[[str, str], None]) -> None:
    """Install ``fn(op, axis)`` as the conduit/AM failure probe.

    Every :class:`Conduit` collective and every AM wire transfer calls it
    before touching the network (at call/trace time); ``fn`` raises
    :class:`RankFailure` to simulate or surface a lost peer.  One hook at
    a time — installing replaces the previous hook.
    """
    global _FAILURE_HOOK
    _FAILURE_HOOK = fn


def clear_failure_hook() -> None:
    """Remove the installed failure probe (collectives stop checking)."""
    global _FAILURE_HOOK
    _FAILURE_HOOK = None


def check_failure(op: str, axis: str) -> None:
    """Run the installed failure probe for ``(op, axis)``, if any.

    Called by the conduit/AM entry points; a probe signals a dead peer by
    raising :class:`RankFailure`, which propagates to the host-level
    caller (trainer/server) that owns recovery.  No-op when no hook is
    installed — the common case costs one global read.
    """
    if _FAILURE_HOOK is not None:
        _FAILURE_HOOK(op, axis)


#: installed epoch source: ``fn()`` returns the current membership epoch
#: (``runtime/membership.MembershipService`` installs its own counter)
_EPOCH_PROVIDER: Optional[Callable[[], int]] = None


def install_epoch_provider(fn: Callable[[], int]) -> None:
    """Install ``fn() -> int`` as the membership-epoch source.

    Epoch-pinned conduits (:meth:`Conduit.at_epoch`) and AM deliveries
    compare their build-time epoch against ``fn()`` before touching the
    network and raise :class:`StaleEpoch` on mismatch.  One provider at a
    time — installing replaces the previous one.
    """
    global _EPOCH_PROVIDER
    _EPOCH_PROVIDER = fn


def clear_epoch_provider() -> None:
    """Remove the installed epoch source (epoch checks become no-ops)."""
    global _EPOCH_PROVIDER
    _EPOCH_PROVIDER = None


def current_epoch() -> Optional[int]:
    """The installed provider's epoch, or ``None`` when none is installed."""
    return None if _EPOCH_PROVIDER is None else int(_EPOCH_PROVIDER())


def check_epoch(op: str, built: Optional[int]) -> None:
    """Raise :class:`StaleEpoch` if ``built`` lags the provider's epoch.

    No-op when the op is unpinned (``built is None``) or no provider is
    installed — legacy callers pay one global read.  Like
    :func:`check_failure` this runs at call/trace time, which is exactly
    when a cached jitted step would otherwise be reused across a
    membership change.
    """
    if built is None or _EPOCH_PROVIDER is None:
        return
    cur = int(_EPOCH_PROVIDER())
    if cur != int(built):
        raise StaleEpoch(built, cur, op)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[Tuple[str, str], Callable] = {}


def register(op: str, name: str):
    """Decorator: register ``fn`` as transport ``name`` for collective ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown collective op {op!r} (one of {OPS})")

    def deco(fn):
        _REGISTRY[(op, name)] = fn
        return fn

    return deco


def transports(op: str) -> Tuple[str, ...]:
    """Names of every transport registered for ``op`` (sorted, stable)."""
    return tuple(sorted(name for (o, name) in _REGISTRY if o == op))


def resolve(op: str, name: str) -> Callable:
    """The registered transport callable for ``(op, name)``.

    Raises ``KeyError`` (listing what *is* registered) for unknown pairs —
    the error surface ``TransportPolicy.__post_init__`` validates against.
    """
    try:
        return _REGISTRY[(op, name)]
    except KeyError:
        raise KeyError(
            f"no transport {name!r} for {op!r}; registered: {transports(op)}"
        ) from None


# ---------------------------------------------------------------------------
# Shared ring engine + ART chunking helpers (both live in core/pipeline.py —
# the generalized ART scheduler; kept under their historical names here)
# ---------------------------------------------------------------------------

_ring_engine = pl.ring_pipeline

_n_chunks = pl.n_chunks


def _split_cols(x2d: jnp.ndarray, c: int):
    """Static split of axis −1 into ``c`` nearly equal pieces."""
    return pl.split(x2d, c, axis=-1)


# ---------------------------------------------------------------------------
# xla transports — the lax built-ins
# ---------------------------------------------------------------------------


@register("barrier", "xla")
def _barrier_xla(*, axis: str, chunk_bytes=None) -> jnp.ndarray:
    return lax.psum(jnp.ones((), jnp.int32), axis)


@register("broadcast", "xla")
def _broadcast_xla(x, *, root: int, axis: str, chunk_bytes=None):
    my = lax.axis_index(axis)
    return lax.psum(jnp.where(my == root, x, jnp.zeros_like(x)), axis)


@register("all_gather", "xla")
def _all_gather_xla(x, *, axis: str, chunk_bytes=None):
    return lax.all_gather(x, axis, axis=0, tiled=True)


@register("reduce_scatter", "xla")
def _reduce_scatter_xla(x, *, axis: str, chunk_bytes=None):
    return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)


@register("all_reduce", "xla")
def _all_reduce_xla(x, *, axis: str, chunk_bytes=None):
    return lax.psum(x, axis)


@register("all_to_all", "xla")
def _all_to_all_xla(x, *, axis: str, chunk_bytes=None):
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)


# ---------------------------------------------------------------------------
# ring transports — unidirectional PUT rings (DESIGN §4)
# ---------------------------------------------------------------------------


@register("barrier", "ring")
def _barrier_ring(*, axis: str, chunk_bytes=None) -> jnp.ndarray:
    n = lax.axis_size(axis)
    one = jnp.ones((), jnp.int32)
    if n == 1:
        return one
    # a ones-token relayed n−1 hops: each arrival is one more participant
    acc = one

    def body(hop, arrived):
        nonlocal acc
        ((token,),) = (arrived,)
        acc = acc + token
        return (token,), acc

    return _ring_engine((one,), (_ring_perm(n, 1),), axis, n - 1, body)


@register("broadcast", "ring")
def _broadcast_ring(x, *, root: int, axis: str, chunk_bytes=None):
    n = lax.axis_size(axis)
    if n == 1:
        return x

    def piece(flat):
        my = lax.axis_index(axis)
        cur = jnp.where(my == root, flat, jnp.zeros_like(flat))
        have = my == root

        def body(hop, arrived):
            nonlocal cur, have
            ((cur_prev, have_prev),) = arrived
            cur = jnp.where(~have & have_prev, cur_prev, cur)
            have = have | have_prev
            return ((cur, have),), cur

        return _ring_engine(((cur, have),), (_ring_perm(n, 1),), axis,
                            n - 1, body)

    shape = x.shape
    flat = x.reshape(1, -1)
    c = _n_chunks(x.size * x.dtype.itemsize, chunk_bytes, max(1, flat.shape[-1]))
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape(shape)


@register("all_gather", "ring")
def _all_gather_ring(x, *, axis: str, chunk_bytes=None):
    n = lax.axis_size(axis)
    if n == 1:
        return x
    my = lax.axis_index(axis)
    b = x.shape[0]
    shape_rest = x.shape[1:]

    def piece(x2d):  # (b, Fi) -> (n*b, Fi)
        out = jnp.zeros((n * b, x2d.shape[-1]), x2d.dtype)
        out = lax.dynamic_update_slice_in_dim(out, x2d, my * b, 0)

        def body(hop, arrived):
            nonlocal out
            ((cur,),) = (arrived,)
            src = (my - hop) % n
            out = lax.dynamic_update_slice_in_dim(out, cur, src * b, 0)
            return (cur,), out

        return _ring_engine((x2d,), (_ring_perm(n, 1),), axis, n - 1, body)

    hop_bytes = x.size * x.dtype.itemsize
    flat = x.reshape(b, -1)
    c = _n_chunks(hop_bytes, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape((n * b,) + shape_rest)


@register("reduce_scatter", "ring")
def _reduce_scatter_ring(x, *, axis: str, chunk_bytes=None):
    n = lax.axis_size(axis)
    if n == 1:
        return x
    assert x.shape[0] % n == 0, (x.shape, n)
    b = x.shape[0] // n
    my = lax.axis_index(axis)

    def piece(x2d):  # (n*b, Fi) -> (b, Fi)
        def block(owner_offset: int):
            start = ((my + owner_offset) % n) * b
            return lax.dynamic_slice_in_dim(x2d, start, b, 0)

        def body(hop, arrived):
            ((cur,),) = (arrived,)
            cur = cur + block(-(hop + 1))
            return (cur,), cur

        return _ring_engine((block(-1),), (_ring_perm(n, 1),), axis, n - 1,
                            body)

    hop_bytes = (x.size // n) * x.dtype.itemsize
    flat = x.reshape(x.shape[0], -1)
    c = _n_chunks(hop_bytes, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape((b,) + x.shape[1:])


def _flat_all_reduce(x, *, axis: str, rs, ag, chunk_bytes):
    """all-reduce = reduce-scatter + all-gather over the flattened payload
    (the bandwidth-optimal composition; 2·(n−1)/n·|x| wire bytes/rank)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    shape = x.shape
    n_elems = x.size
    flat = x.reshape(-1)
    pad = (-n_elems) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    reduced = rs(flat, axis=axis, chunk_bytes=chunk_bytes)
    gathered = ag(reduced, axis=axis, chunk_bytes=chunk_bytes)
    return gathered[:n_elems].reshape(shape)


@register("all_reduce", "ring")
def _all_reduce_ring(x, *, axis: str, chunk_bytes=None):
    return _flat_all_reduce(x, axis=axis, rs=_reduce_scatter_ring,
                            ag=_all_gather_ring, chunk_bytes=chunk_bytes)


@register("all_to_all", "ring")
def _all_to_all_ring(x, *, axis: str, chunk_bytes=None, _shifts=None):
    """All-to-all as n−1 single-block permutes (MoE dispatch transport).

    ``x``: (n·g, B, ...) with the leading dim a multiple of the axis size —
    rows [q·g, (q+1)·g) are destined for rank q (``g=1`` is the plain
    one-block-per-rank layout; ``g>1`` matches the *tiled* semantics of the
    ``xla`` transport, which is what the bucketed MoE exchange of
    ``models/moe_ep.py`` rides).  Returns the same shape with slot q
    holding what rank q sent here.  Per-permute message size is |x|/n —
    ART-chunked by construction, further split by ``chunk_bytes``.
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    assert x.shape[0] % n == 0, (x.shape, n)
    my = lax.axis_index(axis)
    shifts = _shifts if _shifts is not None else list(range(1, n))

    def piece(x2d):  # (n, Fi) -> (n, Fi)
        out = jnp.zeros_like(x2d)
        out = lax.dynamic_update_index_in_dim(
            out, lax.dynamic_index_in_dim(x2d, my, 0, keepdims=False), my, 0
        )
        for shift in shifts:
            perm = _ring_perm(n, shift)
            dst = (my + shift) % n
            block = jnp.take(x2d, dst, axis=0)
            arrived = lax.ppermute(block, axis, perm)
            src = (my - shift) % n
            out = lax.dynamic_update_index_in_dim(out, arrived, src, 0)
        return out

    hop_bytes = (x.size // n) * x.dtype.itemsize
    flat = x.reshape(n, -1)
    c = _n_chunks(hop_bytes, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------------
# bidir transports — two counter-rotating half-sized rings
# ---------------------------------------------------------------------------


@register("barrier", "bidir")
def _barrier_bidir(*, axis: str, chunk_bytes=None) -> jnp.ndarray:
    """Tokens walk both directions; rank my hears my−h (fwd) and my+h (bwd).
    n//2 forward + (n−1)//2 backward hops count every rank exactly once."""
    n = lax.axis_size(axis)
    one = jnp.ones((), jnp.int32)
    if n == 1:
        return one
    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)
    acc = one
    tf = tb = one
    for h in range(1, n // 2 + 1):
        tf = lax.ppermute(tf, axis, fwd)
        acc = acc + tf
        if h <= (n - 1) // 2:
            tb = lax.ppermute(tb, axis, bwd)
            acc = acc + tb
    return acc


@register("broadcast", "bidir")
def _broadcast_bidir(x, *, root: int, axis: str, chunk_bytes=None):
    """The value floods outward from root in both directions: n//2 hops
    reach the antipode instead of the unidirectional ring's n−1."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)

    def piece(flat):
        my = lax.axis_index(axis)
        cur = jnp.where(my == root, flat, jnp.zeros_like(flat))
        have = my == root
        for _ in range(n // 2):
            cur_f = lax.ppermute(cur, axis, fwd)
            have_f = lax.ppermute(have, axis, fwd)
            cur_b = lax.ppermute(cur, axis, bwd)
            have_b = lax.ppermute(have, axis, bwd)
            cur = jnp.where(~have & have_f, cur_f,
                            jnp.where(~have & have_b, cur_b, cur))
            have = have | have_f | have_b
        return cur

    shape = x.shape
    flat = x.reshape(1, -1)
    c = _n_chunks(x.size * x.dtype.itemsize, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape(shape)


@register("all_gather", "bidir")
def _all_gather_bidir(x, *, axis: str, chunk_bytes=None):
    """Split the local block in half; the low half rides the forward ring,
    the high half the backward ring — each link direction carries half the
    bytes of the unidirectional schedule (links are full-duplex)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    b = x.shape[0]
    if n == 2 or b < 2:
        return _all_gather_ring(x, axis=axis, chunk_bytes=chunk_bytes)
    my = lax.axis_index(axis)
    h = b // 2
    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)

    def piece(x2d):  # (b, Fi) -> (n*b, Fi)
        out = jnp.zeros((n * b, x2d.shape[-1]), x2d.dtype)
        out = lax.dynamic_update_slice_in_dim(out, x2d, my * b, 0)
        lo, hi = x2d[:h], x2d[h:]

        def body(hop, arrived):
            nonlocal out
            (cur_f,), (cur_b,) = arrived
            src_f = (my - hop) % n
            src_b = (my + hop) % n
            out = lax.dynamic_update_slice_in_dim(out, cur_f, src_f * b, 0)
            out = lax.dynamic_update_slice_in_dim(out, cur_b,
                                                  src_b * b + h, 0)
            return ((cur_f,), (cur_b,)), out

        return _ring_engine(((lo,), (hi,)), (fwd, bwd), axis, n - 1, body)

    hop_bytes = (x.size // 2) * x.dtype.itemsize
    flat = x.reshape(b, -1)
    c = _n_chunks(hop_bytes, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape((n * b,) + x.shape[1:])


@register("reduce_scatter", "bidir")
def _reduce_scatter_bidir(x, *, axis: str, chunk_bytes=None):
    """Low halves of every block reduce around the forward ring, high halves
    around the backward ring (the RS invariant mirrored: fwd block b_q
    starts at q+1 moving +1; bwd block b_q starts at q−1 moving −1)."""
    n = lax.axis_size(axis)
    if n == 1:
        return x
    assert x.shape[0] % n == 0, (x.shape, n)
    b = x.shape[0] // n
    if n == 2 or b < 2:
        return _reduce_scatter_ring(x, axis=axis, chunk_bytes=chunk_bytes)
    my = lax.axis_index(axis)
    h = b // 2
    fwd, bwd = _ring_perm(n, 1), _ring_perm(n, -1)

    def piece(x2d):  # (n*b, Fi) -> (b, Fi)
        def block(owner_offset: int, lo: bool):
            start = ((my + owner_offset) % n) * b + (0 if lo else h)
            return lax.dynamic_slice_in_dim(x2d, start, h if lo else b - h, 0)

        def body(hop, arrived):
            (cur_f,), (cur_b,) = arrived
            cur_f = cur_f + block(-(hop + 1), True)
            cur_b = cur_b + block(+(hop + 1), False)
            return ((cur_f,), (cur_b,)), (cur_f, cur_b)

        lo_r, hi_r = _ring_engine(((block(-1, True),), (block(+1, False),)),
                                  (fwd, bwd), axis, n - 1, body)
        return jnp.concatenate([lo_r, hi_r], axis=0)

    hop_bytes = (x.size // n // 2) * x.dtype.itemsize
    flat = x.reshape(x.shape[0], -1)
    c = _n_chunks(hop_bytes, chunk_bytes, flat.shape[-1])
    if c == 1:
        out = piece(flat)
    else:
        out = jnp.concatenate([piece(p) for p in _split_cols(flat, c)], -1)
    return out.reshape((b,) + x.shape[1:])


@register("all_reduce", "bidir")
def _all_reduce_bidir(x, *, axis: str, chunk_bytes=None):
    return _flat_all_reduce(x, axis=axis, rs=_reduce_scatter_bidir,
                            ag=_all_gather_bidir, chunk_bytes=chunk_bytes)


@register("all_to_all", "bidir")
def _all_to_all_bidir(x, *, axis: str, chunk_bytes=None):
    """Shift enumeration ±s (s ≤ ⌈n/2⌉): the permutation sets are identical
    to the unidirectional ring's — ``(i+s) % n == (i-(n-s)) % n`` — so this
    is wire-identical; the payoff is modeled hop distance (see
    :func:`estimate_time`), which auto-selection prices."""
    n = lax.axis_size(axis)
    shifts = []
    for s in range(1, n // 2 + 1):
        shifts.append(s)
        if s <= (n - 1) // 2:
            shifts.append(n - s)          # == shift −s
    return _all_to_all_ring(x, axis=axis, chunk_bytes=chunk_bytes,
                            _shifts=shifts)


# ---------------------------------------------------------------------------
# fused transports — the collective consumed inside the Pallas kernel
# ---------------------------------------------------------------------------


@register("all_gather", "fused")
def _all_gather_fused(x, *, axis: str, chunk_bytes=None, w=None,
                      bidirectional: bool = True, interpret=None):
    """SMI-style in-kernel collective matmul (``kernels/cc_matmul``): the
    ring hop lands in double-buffered VMEM scratch and is multiplied
    without leaving the kernel — no per-hop XLA launch/repack boundary.

    With a resident weight ``w`` (K, N_loc) this *is* the fused
    ``all_gather(x) @ w``; without one there is nothing to fuse into, so
    the plain gather delegates to the ``ring`` wire (the fused family is
    a matmul-edge transport, not a new wire for bare collectives).
    """
    if w is None:
        return _all_gather_ring(x, axis=axis, chunk_bytes=chunk_bytes)
    from repro.kernels.cc_matmul.ops import allgather_matmul_pallas
    return allgather_matmul_pallas(x, w, axis=axis,
                                   bidirectional=bidirectional,
                                   interpret=interpret)


@register("reduce_scatter", "fused")
def _reduce_scatter_fused(x, *, axis: str, chunk_bytes=None, w=None,
                          bidirectional: bool = True, interpret=None):
    """Fused ``reduce_scatter(x @ w)``: partial-sum accumulators ride the
    ring inside the kernel while the next sub-matmul runs on the MXU.
    Without a weight, delegates to the ``ring`` wire (see
    :func:`_all_gather_fused`)."""
    if w is None:
        return _reduce_scatter_ring(x, axis=axis, chunk_bytes=chunk_bytes)
    from repro.kernels.cc_matmul.ops import matmul_reducescatter_pallas
    return matmul_reducescatter_pallas(x, w, axis=axis,
                                       bidirectional=bidirectional,
                                       interpret=interpret)


# ---------------------------------------------------------------------------
# Cost model + auto policy (Fig. 5 as a runtime decision)
# ---------------------------------------------------------------------------

#: candidate ART chunk sizes the auto policy sweeps (bytes)
CHUNK_CANDIDATES = (256, 1024, 4096, 16384, 65536, 262144)


def _default_packet(link: nm.LinkParams) -> int:
    return max(link.packet_overhead_bytes)


def estimate_time(
    op: str,
    transport: str,
    *,
    size_bytes: int,
    axis_size: int,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    chunk_bytes: Optional[int] = None,
) -> float:
    """Modeled wall-clock of one collective, per the netmodel.

    ``size_bytes`` is the op's **global payload**: for ``all_gather`` the
    gathered size (local shard × n), for ``reduce_scatter``/``all_to_all``
    the full per-rank input, for ``all_reduce``/``broadcast`` the tensor
    itself.  Under this convention every ring hop moves ``S/n`` bytes for
    the bandwidth-optimal ops.

    Assumptions (documented, deliberately simple):

    * the mesh axis is a 1-D ring of full-duplex links;
    * ``ring``/``bidir`` messages travel one hop; ``bidir`` halves the
      bytes per link direction (both directions run concurrently);
    * ``xla`` uses a distance-oblivious doubling schedule: ⌈log2 n⌉ rounds
      whose round-k messages travel 2^k hops — distance multiplies the
      link-bytes (a message crossing d links occupies d of them), which is
      why doubling loses to rings at large sizes *on a ring topology*;
    * ``chunk_bytes`` plays the packet-size role of Fig. 5: each message
      is priced by :func:`repro.core.netmodel.put_time` at that packet
      size, so small chunks pay per-packet overhead and large chunks
      amortize it.
    """
    n, S = int(axis_size), int(size_bytes)
    if n <= 1:
        return 0.0
    if transport == "fused" and op in ("all_gather", "reduce_scatter"):
        # the bare-collective spelling of ``fused`` delegates to the ring
        # wire (no matmul to fuse into) — price it as what actually runs;
        # the in-kernel schedule is priced by ``matmul_edge_estimate``
        transport = "ring"
    p = int(chunk_bytes or _default_packet(link))
    rounds = max(1, math.ceil(math.log2(n)))

    def t_put(b: float) -> float:
        return nm.put_time(link, max(1, int(b)), p)

    if op == "barrier":
        S = 4
    if op in ("all_gather", "reduce_scatter", "all_reduce", "barrier"):
        phases = 2 if op == "all_reduce" else 1
        if op == "barrier":
            if transport == "xla":
                return rounds * t_put(S)
            if transport == "ring":
                return (n - 1) * t_put(S)
            if transport == "bidir":
                return -(-n // 2) * t_put(S)
            raise ValueError(
                f"unknown (op, transport) = ({op!r}, {transport!r})")
        if transport == "xla":
            # doubling: round k sends 2^k·S/n bytes across 2^k hops
            one = sum(t_put((S / n) * (4 ** k)) for k in range(rounds))
            return phases * one
        if transport == "ring":
            return phases * (n - 1) * t_put(S / n)
        if transport == "bidir":
            return phases * (n - 1) * t_put(S / (2 * n))
    if op == "broadcast":
        if transport == "xla":
            return sum(t_put(S * (2 ** k)) for k in range(rounds))
        c = max(1, -(-S // p))
        if transport == "ring":
            return (n - 2 + c) * t_put(S / c)   # pipelined store-and-forward
        if transport == "bidir":
            return (n // 2 - 1 + c) * t_put(S / c)
    if op == "all_to_all":
        if transport == "xla":
            return sum(t_put((S / 2) * (2 ** k)) for k in range(rounds))
        if transport == "ring":
            # n−1 direct messages; a shift-s message crosses s links
            return sum(t_put((S / n) * s) for s in range(1, n))
        if transport == "bidir":
            # shifts ±s, distance ≤ ⌈n/2⌉; the two directions run
            # concurrently, so wall-clock is the slower direction's sum
            fwd = sum(t_put((S / n) * s) for s in range(1, n // 2 + 1))
            bwd = sum(t_put((S / n) * s) for s in range(1, (n - 1) // 2 + 1))
            return max(fwd, bwd)
    raise ValueError(f"unknown (op, transport) = ({op!r}, {transport!r})")


def matmul_edge_estimate(
    op: str,
    transport: str,
    *,
    size_bytes: int,
    axis_size: int,
    compute_time: float,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    chunk_bytes: Optional[int] = None,
) -> float:
    """Modeled wall-clock of a *collective-matmul edge*: ``compute_time``
    of matmul riding an ``all_gather``/``reduce_scatter`` of
    ``size_bytes`` (global payload, the :func:`estimate_time` convention).

    Three schedule families, one algebra
    (:func:`repro.core.netmodel.pipeline_time`):

    * ``xla`` — the unfused baseline: compute fully, then the bulk
      collective (or vice versa), fully serialized;
    * ``ring`` / ``bidir`` — the XLA-level streamed schedules of
      ``core/overlap.py``: n sub-matmuls interleaved with n−1 hops, each
      hop paying the launch/repack boundary
      (:func:`repro.core.netmodel.hop_launch_overhead`);
    * ``fused`` — the in-kernel schedule of ``kernels/cc_matmul``: the
      identical pipeline with the per-hop boundary eliminated (paid once,
      :func:`repro.core.netmodel.fused_pipeline_time`) and the hop wire
      issued by the kernel's own DMA — no host command stage.
    """
    n, S = int(axis_size), int(size_bytes)
    if op not in ("all_gather", "reduce_scatter"):
        raise ValueError(f"not a collective-matmul edge op: {op!r}")
    if n <= 1:
        return float(compute_time)
    if transport == "xla":
        return compute_time + estimate_time(
            op, "xla", size_bytes=S, axis_size=n, link=link,
            chunk_bytes=chunk_bytes)
    p = int(chunk_bytes or _default_packet(link))
    hop_bytes = S / n
    per_dir = hop_bytes if transport == "ring" else hop_bytes / 2
    tx = nm.put_time(link, max(1, int(per_dir)), p)
    oh = nm.hop_launch_overhead(link, int(hop_bytes))
    computes = [compute_time / n] * n
    wires = [tx] * (n - 1) + [0.0]       # the last block is resident
    if transport in ("ring", "bidir"):
        return nm.pipeline_time([tc + oh for tc in computes], wires)
    if transport == "fused":
        # in-kernel DMA: no host command per hop, best direction split
        half = nm.put_time(link, max(1, int(hop_bytes / 2)), p)
        tx_f = min(tx, half) - link.latency.t_host_cmd
        tx_f = max(tx_f, per_dir / link.peak_bandwidth)
        wires_f = [tx_f] * (n - 1) + [0.0]
        return nm.fused_pipeline_time(computes, wires_f,
                                      launch_overhead=oh)
    raise ValueError(f"unknown matmul-edge transport {transport!r}")


def auto_select(
    op: str,
    *,
    size_bytes: int,
    axis_size: int,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    chunk_bytes: Optional[int] = None,
    compute_time: Optional[float] = None,
) -> Tuple[str, Optional[int]]:
    """Pick (transport, chunk_bytes) minimizing :func:`estimate_time`.

    This is the conduit's answer to the paper's Fig. 5: per (message size,
    axis size) the best transport differs — small payloads go to ``xla``
    (latency), large ones to the full-duplex ``bidir`` rings (bandwidth).

    ``chunk_bytes``: pin the ART chunk instead of sweeping
    :data:`CHUNK_CANDIDATES` — the transport choice is then conditioned on
    the chunk that will actually run.  Transports the cost model cannot
    price (custom registrations) are skipped, never an error.

    ``compute_time``: when given, the payload is a *collective-matmul
    edge* and every transport is priced by :func:`matmul_edge_estimate`
    instead — which makes the ``fused`` in-kernel family selectable (a
    bare collective has no compute to fuse into, so without
    ``compute_time`` the fused transport is never picked).
    """
    if axis_size <= 1:
        return "xla", None
    candidates = (chunk_bytes,) if chunk_bytes else CHUNK_CANDIDATES
    best: Tuple[float, str, Optional[int]] = (float("inf"), "xla", None)
    for name in transports(op):
        for chunk in candidates:
            try:
                if compute_time is None:
                    t = estimate_time(op, name, size_bytes=size_bytes,
                                      axis_size=axis_size, link=link,
                                      chunk_bytes=chunk)
                else:
                    t = matmul_edge_estimate(
                        op, name, size_bytes=size_bytes,
                        axis_size=axis_size, compute_time=compute_time,
                        link=link, chunk_bytes=chunk)
            except ValueError:
                break                      # unmodeled transport: skip it
            if t < best[0]:
                best = (t, name, chunk)
    return best[1], best[2]


# ---------------------------------------------------------------------------
# Pipeline-aware cost model (overlap as a selection criterion)
# ---------------------------------------------------------------------------

#: candidate chunk counts the pipeline auto policy sweeps
PIPELINE_CHUNKS = (1, 2, 4, 8, 16, 32, 64)


def pipeline_estimate(
    op: str,
    transport: str,
    *,
    size_bytes: int,
    axis_size: int,
    n_chunks: int,
    compute_time: float = 0.0,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    chunk_bytes: Optional[int] = None,
) -> float:
    """Modeled wall-clock of a *streamed* schedule of ``op``.

    The payload is split into ``n_chunks`` independent collectives of
    ``size_bytes / n_chunks`` each, interleaved with ``compute_time`` of
    per-chunk work (``pipeline.streamed`` / ``chunk_pipeline``): chunk
    *k*'s collective flies while chunk *k±1*'s compute runs, per
    :func:`repro.core.netmodel.pipeline_time`.  ``n_chunks=1`` is the bulk
    baseline (``compute_time`` + :func:`estimate_time`, fully serialized).
    """
    c = max(1, int(n_chunks))
    per_wire = estimate_time(
        op, transport, size_bytes=max(1, round(size_bytes / c)),
        axis_size=axis_size, link=link, chunk_bytes=chunk_bytes)
    if c == 1:
        return compute_time + per_wire
    return nm.pipeline_time([compute_time / c] * c, [per_wire] * c)


def crossover_bytes(
    op: str,
    *,
    axis_size: int,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    lo: int = 16,
    hi: int = 1 << 30,
) -> Optional[int]:
    """Smallest payload (bytes, power-of-two grid) where :func:`auto_select`
    leaves ``xla`` for a ring family — the Fig.-5 message-size threshold as
    one number per (op, axis size, link).

    Serving uses it to place decode-time messages: an EP decode exchange
    above this size rides the ring transports, below it ``xla`` (and the
    dense-combine fallback) wins (``benchmarks/serve_bench.py``,
    docs/serving.md).  Returns ``None`` when ``auto`` never leaves ``xla``
    in ``[lo, hi]``.
    """
    if axis_size <= 1:
        return None
    s = lo
    while s <= hi:
        name, _ = auto_select(op, size_bytes=s, axis_size=axis_size,
                              link=link)
        if name != "xla":
            return s
        s *= 2
    return None


def auto_select_pipeline(
    op: str,
    *,
    size_bytes: int,
    axis_size: int,
    compute_time: float = 0.0,
    link: nm.LinkParams = nm.FSHMEM_QSFP,
    chunk_bytes: Optional[int] = None,
    chunk_counts: Sequence[int] = PIPELINE_CHUNKS,
) -> Tuple[str, Optional[int], int]:
    """Pick ``(transport, chunk_bytes, n_chunks)`` minimizing
    :func:`pipeline_estimate`.

    Where :func:`auto_select` minimizes standalone wire time, this policy
    prices the *whole pipeline*: a chunk count that maximizes hiding can
    beat the chunk count with the cheapest isolated collective, because
    per-chunk latency buys overlap with ``compute_time``.  ``n_chunks=1``
    (bulk) is always a candidate, so the choice never regresses below the
    bulk schedule *in the model*.
    """
    if axis_size <= 1:
        return "xla", None, 1
    candidates = (chunk_bytes,) if chunk_bytes else CHUNK_CANDIDATES
    best: Tuple[float, str, Optional[int], int] = (float("inf"), "xla",
                                                   None, 1)
    for name in transports(op):
        for chunk in candidates:
            for c in chunk_counts:
                try:
                    t = pipeline_estimate(
                        op, name, size_bytes=size_bytes, axis_size=axis_size,
                        n_chunks=c, compute_time=compute_time, link=link,
                        chunk_bytes=chunk)
                except ValueError:
                    break                  # unmodeled transport: skip it
                if t < best[0]:
                    best = (t, name, chunk, c)
            else:
                continue
            break
    return best[1], best[2], best[3]


# ---------------------------------------------------------------------------
# The user-facing handle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conduit:
    """A bound (mesh axis, transport policy, chunk size, link model).

    Hashable and immutable, so it can be closed over by jitted/shard_mapped
    code.  ``transport='auto'`` resolves per call from the payload's static
    byte size via :func:`auto_select`.

    ``epoch`` pins the handle to the membership epoch it was built
    against (:meth:`at_epoch`): with an epoch provider installed, every
    op first runs :func:`check_epoch` and raises :class:`StaleEpoch` once
    the membership has moved on.  ``None`` (the default) opts out.
    """

    axis: str
    transport: str = "auto"    # "xla" | "ring" | "bidir" | "fused" | "auto"
    chunk_bytes: Optional[int] = None
    link: str = "qsfp"               # key into LINKS (netmodel params)
    epoch: Optional[int] = None      # membership epoch this handle targets

    def at_epoch(self, epoch: Optional[int]) -> "Conduit":
        """A copy of this handle pinned to membership ``epoch``."""
        return dataclasses.replace(self, epoch=epoch)

    # -- resolution ---------------------------------------------------------

    def _resolve(self, op: str, size_bytes: int) -> Tuple[str, Optional[int]]:
        if self.transport != "auto":
            return self.transport, self.chunk_bytes
        name, chunk = auto_select(
            op, size_bytes=size_bytes,
            axis_size=lax.axis_size(self.axis), link=LINKS[self.link],
            chunk_bytes=self.chunk_bytes)
        return name, chunk

    def _call(self, op: str, x, **kw):
        check_failure(op, self.axis)
        check_epoch(op, self.epoch)
        size = int(x.size) * jnp.dtype(x.dtype).itemsize
        if op == "all_gather":
            # estimate_time's convention is the *global* payload; the
            # all_gather input is only this rank's shard
            size *= lax.axis_size(self.axis)
        name, chunk = self._resolve(op, size)
        return resolve(op, name)(x, axis=self.axis, chunk_bytes=chunk, **kw)

    # -- collectives (call inside shard_map over ``self.axis``) -------------

    def barrier(self) -> jnp.ndarray:
        """Full-axis rendezvous; returns the axis size on every rank."""
        check_failure("barrier", self.axis)
        check_epoch("barrier", self.epoch)
        name, chunk = self._resolve("barrier", 4)
        return resolve("barrier", name)(axis=self.axis, chunk_bytes=chunk)

    def broadcast(self, x, root: int):
        """Rank ``root``'s ``x`` delivered to every rank."""
        return self._call("broadcast", x, root=root)

    def all_gather(self, x):
        """Local ``(B, ...)`` → ``(n·B, ...)``, blocks in axis-index order."""
        return self._call("all_gather", x)

    def reduce_scatter(self, x):
        """``(n·B, ...)`` → ``(B, ...)``: block q summed onto rank q."""
        return self._call("reduce_scatter", x)

    def all_reduce(self, x):
        """Elementwise sum of ``x`` across the axis, on every rank."""
        return self._call("all_reduce", x)

    def all_to_all(self, x):
        """Tiled exchange: leading dim a multiple of n; block q of ``x``
        goes to rank q, returns the blocks the peers addressed here."""
        return self._call("all_to_all", x)

    # -- streamed (per-chunk) schedules --------------------------------------

    def streamed(self, op: str, payloads, *, work=None, **kw):
        """Per-chunk schedule of ``op`` instead of one bulk call.

        ``payloads`` is a sequence of independent chunks (an elementwise
        partition of the bulk payload — e.g. ``pipeline.split``); chunk
        *k*'s collective is issued while ``work(k−1, arrived)`` digests the
        previous arrival (``pipeline.streamed``), so the wire hides behind
        the per-chunk compute — the generalized ART schedule.  Returns the
        list of per-chunk results, in order.

        Every chunk runs the identical transport schedule on a disjoint
        slice, so per-chunk results are bit-identical to slices of the
        bulk call — and concatenating them reassembles the bulk result
        exactly **when the split axis is orthogonal to the op's
        rank-blocking layout** (``all_to_all`` split on a non-leading dim,
        as the MoE dispatch does, or ``all_reduce``/``broadcast`` on any
        axis).  Splitting ``all_gather``/``reduce_scatter`` payloads on
        their *blocked leading dim* instead yields (chunk, rank)-ordered
        blocks that a plain concatenate does not restore — reassemble by
        block, or split another axis.
        """
        return pl.streamed(
            len(payloads),
            lambda k: self._call(op, payloads[k], **kw),
            work,
        )

    # -- fused-matmul flavor (core/overlap.py schedules) --------------------

    def matmul_bidirectional(self, size_bytes: int) -> bool:
        """Whether the fused ring-matmul schedules should counter-rotate.

        The overlap schedules only come in ring flavors (xla has no fused
        equivalent), so ``xla``/``auto`` resolve via the cost model
        restricted to {ring, bidir}."""
        if self.transport == "bidir":
            return True
        if self.transport == "ring":
            return False
        n = lax.axis_size(self.axis)
        link = LINKS[self.link]
        t_ring = estimate_time("all_gather", "ring", size_bytes=size_bytes,
                               axis_size=n, link=link,
                               chunk_bytes=self.chunk_bytes)
        t_bidir = estimate_time("all_gather", "bidir", size_bytes=size_bytes,
                                axis_size=n, link=link,
                                chunk_bytes=self.chunk_bytes)
        return t_bidir <= t_ring

    def matmul_schedule(self, op: str, size_bytes: int,
                        compute_time: Optional[float] = None) -> str:
        """Which collective-matmul schedule family to run at a TP edge:
        ``"ring"``/``"bidir"`` (the XLA-level streamed overlap of
        ``core/overlap.py``) or ``"fused"`` (the in-kernel ring of
        ``kernels/cc_matmul``).

        Explicit ring transports pass through; ``fused`` pins the
        in-kernel family.  ``xla``/``auto`` pick by
        :func:`matmul_edge_estimate` when ``compute_time`` is given —
        without it the fused family cannot be priced, so the choice
        degrades to the plain ring-vs-bidir cost model."""
        check_failure("matmul_schedule", self.axis)
        check_epoch("matmul_schedule", self.epoch)
        if self.transport in ("ring", "bidir", "fused"):
            return self.transport
        if compute_time is None:
            return "bidir" if self.matmul_bidirectional(size_bytes) else "ring"
        n = lax.axis_size(self.axis)
        link = LINKS[self.link]
        best, best_t = "ring", float("inf")
        for name in ("ring", "bidir", "fused"):
            t = matmul_edge_estimate(
                op, name, size_bytes=size_bytes, axis_size=n,
                compute_time=compute_time, link=link,
                chunk_bytes=self.chunk_bytes)
            if t < best_t:
                best, best_t = name, t
        return best

    # -- recovery-path flavor ------------------------------------------------

    def with_retry(self, attempts: int = 3, backoff: float = 0.0,
                   max_elapsed_s: Optional[float] = None
                   ) -> "RetryingConduit":
        """A proxy that retries each collective on :class:`RankFailure`.

        Used by the elastic recovery path (``runtime/elastic.py``): during
        re-formation a peer may be transiently unreachable (drained, not
        dead), so each collective is attempted up to ``attempts`` times
        with deterministic exponential backoff (``backoff``, ``2·backoff``,
        ``4·backoff``, ...; seconds of host sleep between attempts; ``0.0``
        retries immediately).  ``max_elapsed_s`` caps the *total* backoff
        budget per call: an attempt whose preceding sleeps would exceed it
        is not made.  A loss that persists through every attempt (or past
        the budget) re-raises the last :class:`RankFailure` — permanent
        death is the caller's problem.  :class:`StaleEpoch` is never
        retried: a superseded membership view cannot come back.
        """
        return RetryingConduit(self, attempts=attempts, backoff=backoff,
                               max_elapsed_s=max_elapsed_s)


@dataclasses.dataclass(frozen=True)
class RetryingConduit:
    """Retry/backoff wrapper around a :class:`Conduit` (see
    :meth:`Conduit.with_retry`).

    Exposes the same collective surface — including the streamed and
    fused-matmul entry points — and each call funnels through
    :meth:`_attempt`, which swallows transient :class:`RankFailure` and
    re-raises the last one once ``attempts`` (or the ``max_elapsed_s``
    deadline budget) are exhausted.  The backoff schedule is
    deterministic — attempt *k* sleeps ``backoff · 2^k`` and the deadline
    budget is charged by that *planned* schedule, not a wall clock — so a
    retried run makes the same decisions every time.  :class:`StaleEpoch`
    is re-raised immediately: a stale view is permanent, and absorbing it
    would hide exactly the cross-epoch completion the epoch check exists
    to prevent.
    """

    conduit: Conduit
    attempts: int = 3
    backoff: float = 0.0
    max_elapsed_s: Optional[float] = None

    def __post_init__(self):
        """Validate the retry budgets (≥1 attempt, non-negative deadline)."""
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts}")
        if self.max_elapsed_s is not None and self.max_elapsed_s < 0:
            raise ValueError(
                f"max_elapsed_s must be >= 0, got {self.max_elapsed_s}")

    def _attempt(self, fn: Callable, *args, **kw):
        elapsed = 0.0
        last: Optional[RankFailure] = None
        for k in range(self.attempts):
            try:
                return fn(*args, **kw)
            except StaleEpoch:
                raise                        # a dead view never comes back
            except RankFailure as e:
                last = e
                if k + 1 >= self.attempts:
                    break
                delay = self.backoff * (2 ** k)
                if (self.max_elapsed_s is not None
                        and elapsed + delay > self.max_elapsed_s):
                    break                    # deadline budget exhausted
                elapsed += delay
                if delay > 0:
                    time.sleep(delay)
        assert last is not None
        raise last

    def barrier(self):
        """Retrying :meth:`Conduit.barrier`."""
        return self._attempt(self.conduit.barrier)

    def broadcast(self, x, root: int):
        """Retrying :meth:`Conduit.broadcast`."""
        return self._attempt(self.conduit.broadcast, x, root)

    def all_gather(self, x):
        """Retrying :meth:`Conduit.all_gather`."""
        return self._attempt(self.conduit.all_gather, x)

    def reduce_scatter(self, x):
        """Retrying :meth:`Conduit.reduce_scatter`."""
        return self._attempt(self.conduit.reduce_scatter, x)

    def all_reduce(self, x):
        """Retrying :meth:`Conduit.all_reduce`."""
        return self._attempt(self.conduit.all_reduce, x)

    def all_to_all(self, x):
        """Retrying :meth:`Conduit.all_to_all`."""
        return self._attempt(self.conduit.all_to_all, x)

    def streamed(self, op: str, payloads, *, work=None, **kw):
        """Retrying :meth:`Conduit.streamed`: each per-chunk collective
        gets its own attempt/backoff budget, so one transient hop loss
        costs one chunk retry instead of restarting the whole stream."""
        return pl.streamed(
            len(payloads),
            lambda k: self._attempt(self.conduit._call, op, payloads[k],
                                    **kw),
            work,
        )

    def matmul_bidirectional(self, size_bytes: int) -> bool:
        """Retrying :meth:`Conduit.matmul_bidirectional`."""
        return self._attempt(self.conduit.matmul_bidirectional, size_bytes)

    def matmul_schedule(self, op: str, size_bytes: int,
                        compute_time: Optional[float] = None) -> str:
        """Retrying :meth:`Conduit.matmul_schedule`: schedule selection at
        a fused/pipelined TP edge absorbs the same transient faults as the
        plain collectives."""
        return self._attempt(self.conduit.matmul_schedule, op, size_bytes,
                             compute_time)


__all__ = [
    "OPS", "LINKS", "CHUNK_CANDIDATES", "PIPELINE_CHUNKS", "Conduit",
    "RetryingConduit", "RankFailure", "StaleEpoch",
    "install_failure_hook", "clear_failure_hook", "check_failure",
    "install_epoch_provider", "clear_epoch_provider", "current_epoch",
    "check_epoch",
    "register", "transports", "resolve",
    "estimate_time", "matmul_edge_estimate", "auto_select",
    "crossover_bytes", "pipeline_estimate", "auto_select_pipeline",
]
