"""FSHMEM core: the paper's contribution as a composable JAX module.

Layers (bottom-up):
  netmodel    — analytic QSFP+/ICI performance model (Fig. 5 / Table III)
  pgas        — symmetric heap + one-sided put/get over a mesh axis
  am          — GASNet Active Messages: opcode registry + lax.switch dispatch
  pipeline    — the generalized ART scheduler: chunked overlap of any
                collective with any per-chunk compute (DESIGN §3)
  art         — Automatic Result Transfer: the paper's entry points, on
                the shared scheduler
  conduit     — GASNet-style transport registry (xla/ring/bidir + auto
                cost-model selection) behind one collective API, with
                streamed per-chunk schedules
  collectives — extended API (barrier/bcast/AG/RS/AR/a2a), thin wrappers
                binding the conduit's paper-faithful ring transport
  overlap     — beyond-paper: ART applied to tensor-parallel matmuls
"""

from repro.core import (
    am,
    art,
    collectives,
    conduit,
    netmodel,
    overlap,
    pgas,
    pipeline,
)
from repro.core.conduit import Conduit
from repro.core.am import (
    HandlerRegistry,
    am_request,
    am_request_long,
    am_request_medium,
    am_request_short,
    gasnet_get,
    gasnet_put,
    make_args,
)
from repro.core.art import (
    art_matmul_reducescatter,
    art_send,
    bulk_matmul_reducescatter,
    split_conv_allgather,
)
from repro.core.overlap import allgather_matmul, matmul_reducescatter
from repro.core.pgas import GlobalAddressSpace, SymmetricHeap, get, put

__all__ = [
    "am", "art", "collectives", "conduit", "netmodel", "overlap", "pgas",
    "pipeline", "Conduit",
    "HandlerRegistry", "am_request", "am_request_long", "am_request_medium",
    "am_request_short", "gasnet_get", "gasnet_put", "make_args",
    "art_matmul_reducescatter", "art_send", "bulk_matmul_reducescatter",
    "split_conv_allgather", "allgather_matmul", "matmul_reducescatter",
    "GlobalAddressSpace", "SymmetricHeap", "get", "put",
]
