"""Varying-manual-axes helpers for shard_map code (JAX >= 0.7 vma tracking).

Inside ``shard_map``, constants are *unvarying* over the mesh axes while
anything derived from permuted/indexed data is *varying*.  ``lax.scan`` /
``lax.fori_loop`` carries and ``lax.switch`` branches must agree on vma, so
loop initializers and handler outputs built from ``jnp.zeros`` need an
explicit promotion.  ``lax.pcast(..., to='varying')`` errors when the value
is already varying; these helpers make the promotion idempotent.
"""

from __future__ import annotations

import jax
from jax import lax

# Pre-vma jax (< 0.7) has no varying-axes tracking inside shard_map: every
# value is implicitly varying and the promotion is a no-op.
_HAS_VMA = hasattr(jax, "typeof") and hasattr(lax, "pcast")


def vary(x, axis: str | tuple[str, ...]):
    """Promote ``x`` to varying over ``axis`` (no-op if already varying)."""
    if not _HAS_VMA:
        return x
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    vma = getattr(jax.typeof(x), "vma", frozenset())
    missing = tuple(a for a in axes if a not in vma)
    if not missing:
        return x
    return lax.pcast(x, missing, to="varying")


def vary_tree(tree, axis: str | tuple[str, ...] | None):
    """:func:`vary` over every leaf of ``tree`` (None axis: no-op)."""
    if axis is None:
        return tree
    return jax.tree.map(lambda x: vary(x, axis), tree)
