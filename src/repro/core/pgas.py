"""Partitioned Global Address Space on a JAX mesh — the FSHMEM core.

The paper gives every FPGA a *globally addressed* memory partition plus
private local memory, and implements one-sided ``gasnet_put`` / ``gasnet_get``
in hardware so a node can write/read a remote partition without interrupting
the remote process.  On TPU the native equivalent of that one-sided RDMA is
``jax.lax.ppermute`` (collective-permute): the sender's DMA engine deposits
data directly into the receiver's HBM while the receiver keeps computing.

This module provides:

* :class:`SymmetricHeap` — a named bump allocator describing the layout of
  each rank's partition, so applications address remote data by symbol +
  offset exactly like SHMEM's symmetric heap.
* :func:`put` / :func:`get` — one-sided remote write/read between ranks of a
  mesh axis, usable inside any ``shard_map``-ed function.  ``get`` is
  deliberately built as *request + reply* (two messages) to preserve the
  paper's cost structure (GET latency > PUT latency; GET bandwidth below PUT
  for small transfers).
* :class:`GlobalAddressSpace` — the user-facing handle bundling a mesh axis
  with a heap layout and providing jit-ready collective closures.

Addressing model
----------------
All functions here run *inside* ``shard_map``: ``heap`` is the caller's local
partition, a 1-D array of ``heap.size`` elements.  A global address is
``(rank, offset)``.  Point-to-point routing is expressed with a static
``perm`` list of ``(src_rank, dst_rank)`` pairs — the SPMD analogue of each
node knowing its peer — while offsets and payloads are traced values carried
in the message itself (the AM header of the paper).

Atomicity note: the paper's GASNet core arbitrates handler atomicity in
hardware.  Inside an XLA program there is no concurrent mutation — SPMD
dataflow gives every ``put`` a deterministic position in the schedule — so
handler atomicity is structural rather than arbitrated (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

Perm = Sequence[Tuple[int, int]]


# ---------------------------------------------------------------------------
# Symmetric heap layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Symbol:
    """One named allocation in the symmetric heap (offset identical on
    every rank — the SHMEM property remote addressing relies on)."""

    name: str
    offset: int
    size: int


class SymmetricHeap:
    """Named bump allocator over each rank's partition (SHMEM symmetric heap).

    Every rank has the same layout, so ``heap_addr("x")`` is a valid remote
    offset on any peer — the defining property of a symmetric heap.
    """

    def __init__(self, size: int, dtype=jnp.float32):
        self.size = int(size)
        self.dtype = dtype
        self._symbols: Dict[str, Symbol] = {}
        self._top = 0

    def alloc(self, name: str, size: int) -> Symbol:
        """Bump-allocate ``size`` words for ``name`` (same offset on every
        rank); raises on double allocation or heap overflow."""
        if name in self._symbols:
            raise ValueError(f"symbol {name!r} already allocated")
        if self._top + size > self.size:
            raise MemoryError(
                f"symmetric heap overflow: {self._top}+{size} > {self.size}"
            )
        sym = Symbol(name, self._top, int(size))
        self._symbols[name] = sym
        self._top += int(size)
        return sym

    def addr(self, name: str) -> int:
        """The symbol's offset — valid as a *remote* address on any peer."""
        return self._symbols[name].offset

    def symbol(self, name: str) -> Symbol:
        """The full :class:`Symbol` record for ``name``."""
        return self._symbols[name]

    def zeros_local(self) -> jnp.ndarray:
        """A zeroed local partition with the heap's size and dtype."""
        return jnp.zeros((self.size,), self.dtype)


@dataclasses.dataclass
class BlockSegment:
    """Block-granular view of a symmetric-heap symbol.

    The paged KV pool treats one heap symbol as an array of fixed-size
    blocks, globally numbered ``0 .. n_blocks-1`` and striped across ranks
    owner-major: rank ``r`` owns blocks ``[r*blocks_per_rank,
    (r+1)*blocks_per_rank)``.  :meth:`addr` is the shared-to-physical
    address translation of PGAS address-mapping hardware — a global block
    id resolves to ``(owner rank, local word offset)`` with two integer
    ops, so it composes with traced values inside a jitted PUT.
    """

    symbol: Symbol
    block_words: int
    blocks_per_rank: int
    n_ranks: int

    @property
    def n_blocks(self) -> int:
        """Total blocks across all ranks."""
        return self.blocks_per_rank * self.n_ranks

    def owner(self, bid):
        """Rank owning global block ``bid`` (int or traced array)."""
        return bid // self.blocks_per_rank

    def local_index(self, bid):
        """Owner-local block index of global block ``bid``."""
        return bid % self.blocks_per_rank

    def local_offset(self, bid):
        """Word offset of ``bid`` inside the owner's partition."""
        return self.symbol.offset + self.local_index(bid) * self.block_words

    def addr(self, bid):
        """Translate a global block id to ``(owner_rank, local_offset)``."""
        return self.owner(bid), self.local_offset(bid)


@dataclasses.dataclass
class HeartbeatSegment:
    """Membership wire state in the symmetric heap: one lease counter and
    one join flag per rank.

    Layout (word offsets from ``symbol.offset``, identical on every rank —
    the symmetric-heap property is exactly what lets rank ``r`` PUT its
    lease into slot ``r`` of *every* peer's segment with one short AM):

    * ``[0, n_ranks)`` — lease counters: slot ``r`` holds the freshest
      lease counter heard from rank ``r``.
    * ``[n_ranks, 2·n_ranks)`` — join flags: slot ``r`` is set when rank
      ``r`` has announced it wants to (re)join the membership.

    The host-side detector (``runtime/membership.MembershipService``)
    remains the deterministic source of truth — this segment is the wire
    image it would read on hardware, validated against the host mirror in
    ``tests/test_membership.py``.
    """

    symbol: Symbol
    n_ranks: int

    @property
    def words(self) -> int:
        """Total heap words the segment occupies (leases + join flags)."""
        return 2 * self.n_ranks

    def lease_offset(self, rank) -> int:
        """Heap word offset of rank ``rank``'s lease slot."""
        return self.symbol.offset + rank

    def join_offset(self, rank) -> int:
        """Heap word offset of rank ``rank``'s join flag."""
        return self.symbol.offset + self.n_ranks + rank


# ---------------------------------------------------------------------------
# One-sided primitives (call inside shard_map)
# ---------------------------------------------------------------------------


def _recv_mask(axis: str, perm: Perm) -> jnp.ndarray:
    """True on ranks that are a destination in ``perm``.

    ``perm`` is a static Python list, so the mask is a compile-time table
    indexed by ``lax.axis_index`` — no wire traffic.  (It used to ppermute
    a ones-array, costing every ``put``/``get`` an extra message.)
    """
    n = lax.axis_size(axis)
    is_dst = [False] * n
    for _, d in perm:
        is_dst[d] = True
    return jnp.asarray(is_dst)[lax.axis_index(axis)]


def put(
    heap: jnp.ndarray,
    payload: jnp.ndarray,
    offset: jnp.ndarray | int,
    *,
    axis: str,
    perm: Perm,
) -> jnp.ndarray:
    """One-sided remote write: each ``src`` in ``perm`` deposits ``payload``
    at ``offset`` words into ``dst``'s partition.  Returns the updated local
    partition (unchanged on ranks that are not a destination).

    This is the paper's ``gasnet_put``: a single *long* active message whose
    header carries the destination offset and whose body is the payload.
    """
    payload = payload.reshape(-1).astype(heap.dtype)
    hdr = jnp.asarray(offset, jnp.int32)
    perm = list(perm)
    body = lax.ppermute(payload, axis, perm)
    hdr_r = lax.ppermute(hdr, axis, perm)
    mask = _recv_mask(axis, perm)
    written = lax.dynamic_update_slice(heap, body, (hdr_r,))
    return jnp.where(mask, written, heap)


def get(
    heap: jnp.ndarray,
    offset: jnp.ndarray | int,
    size: int,
    *,
    axis: str,
    perm: Perm,
) -> jnp.ndarray:
    """One-sided remote read: each ``(requester, source)`` pair in ``perm``
    reads ``size`` words at ``source``'s ``offset``.  Returns the fetched
    chunk on requester ranks (zeros elsewhere).

    Faithful two-message structure (short request + long PUT reply): the
    request carries only the header (offset); the source slices its partition
    and replies with the payload — the reply handler of the paper's GET flow.
    """
    req_perm = [(r, s) for (r, s) in perm]   # requester -> source (short msg)
    rep_perm = [(s, r) for (r, s) in perm]   # source -> requester (long msg)
    hdr = jnp.asarray(offset, jnp.int32)
    hdr_at_src = lax.ppermute(hdr, axis, req_perm)
    chunk = lax.dynamic_slice(heap, (hdr_at_src,), (size,))
    reply = lax.ppermute(chunk, axis, rep_perm)
    mask = _recv_mask(axis, rep_perm)
    return jnp.where(mask, reply, jnp.zeros_like(reply))


def put_ring(
    heap: jnp.ndarray,
    payload: jnp.ndarray,
    offset: jnp.ndarray | int,
    *,
    axis: str,
    shift: int = 1,
) -> jnp.ndarray:
    """``put`` along a ring: every rank sends to ``(rank + shift) % n``."""
    n = lax.axis_size(axis)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return put(heap, payload, offset, axis=axis, perm=perm)


def _as_spec_tuple(specs) -> tuple:
    """Normalize a spec argument to a tuple of specs.  PartitionSpec is a
    tuple subclass on some jax versions, so a bare P(...) must be wrapped
    before tuple() can ever see it (it would iterate into its entries)."""
    if isinstance(specs, P):
        return (specs,)
    if isinstance(specs, (list, tuple)):
        return tuple(specs)
    return (specs,)


# ---------------------------------------------------------------------------
# User-facing handle
# ---------------------------------------------------------------------------


class GlobalAddressSpace:
    """Bundles a mesh axis with a symmetric-heap layout.

    ``run(fn)`` wraps ``fn(local_heap, *local_args)`` in ``shard_map`` over
    the PGAS axis so applications write rank-local code with one-sided
    communication, then call it on globally sharded arrays — the programming
    model of the paper's Fig. 2.
    """

    def __init__(self, mesh: jax.sharding.Mesh, axis: str, heap: SymmetricHeap):
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh {mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self.heap = heap

    @property
    def n_ranks(self) -> int:
        """Number of partitions (the PGAS axis extent)."""
        return self.mesh.shape[self.axis]

    def zeros_global(self) -> jax.Array:
        """Allocate the global heap: one partition per rank along the axis."""
        shape = (self.n_ranks * self.heap.size,)
        sharding = jax.sharding.NamedSharding(self.mesh, P(self.axis))
        return jax.device_put(jnp.zeros(shape, self.heap.dtype), sharding)

    def run(
        self,
        fn: Callable,
        *,
        extra_in_specs: Sequence[P] = (),
        extra_out_specs: P | Sequence[P] | None = None,
    ) -> Callable:
        """shard_map ``fn(heap_local, *extras) -> (heap_local, *outs)``."""
        in_specs = (P(self.axis),) + _as_spec_tuple(extra_in_specs)
        if extra_out_specs is None:
            out_specs: object = P(self.axis)
        else:
            out_specs = (P(self.axis),) + _as_spec_tuple(extra_out_specs)
        return jax.jit(
            jax.shard_map(
                fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs
            )
        )

    # Convenience: symbol-level remote write/read closures ------------------

    def write_symbol(self, name: str, *, perm: Perm) -> Callable:
        """A jitted ``f(global_heap, payload)`` PUTting into symbol
        ``name`` on the peers named by ``perm``."""
        sym = self.heap.symbol(name)

        def _w(heap, payload):
            return put(heap, payload, sym.offset, axis=self.axis, perm=perm)

        return self.run(_w, extra_in_specs=(P(self.axis),))

    def block_segment(self, name: str, block_words: int) -> BlockSegment:
        """Block-granular view of symbol ``name``: the symbol on each rank
        is split into ``size // block_words`` fixed-size blocks, globally
        numbered owner-major across the axis."""
        sym = self.heap.symbol(name)
        if sym.size % block_words:
            raise ValueError(
                f"symbol {name!r} size {sym.size} not a multiple of "
                f"block_words {block_words}"
            )
        return BlockSegment(
            symbol=sym,
            block_words=int(block_words),
            blocks_per_rank=sym.size // int(block_words),
            n_ranks=self.n_ranks,
        )

    def heartbeat_segment(self, name: str = "hb_leases") -> HeartbeatSegment:
        """Allocate (or reuse) the membership heartbeat segment.

        ``2 · n_ranks`` words: per-rank lease counters plus per-rank join
        flags (:class:`HeartbeatSegment`).  Idempotent — a second call
        returns a view of the already-allocated symbol, so the membership
        service and the wire builder can both ask for it.
        """
        try:
            sym = self.heap.symbol(name)
        except KeyError:
            sym = self.heap.alloc(name, 2 * self.n_ranks)
        if sym.size != 2 * self.n_ranks:
            raise ValueError(
                f"symbol {name!r} has {sym.size} words, heartbeat needs "
                f"{2 * self.n_ranks}")
        return HeartbeatSegment(symbol=sym, n_ranks=self.n_ranks)

    def write_block(self, name: str, block_words: int, *, perm: Perm) -> Callable:
        """A jitted ``f(global_heap, payload, bid)`` PUTting one block into
        the segment of symbol ``name`` on the peers named by ``perm``.

        ``bid`` is a traced global block id; the segment translates it to a
        local offset on the destination, so one closure serves every block
        the static ``perm`` destination owns.  The caller must route each
        ``bid`` to its owner — ``segment.owner(bid)`` must equal the ``dst``
        of the pair delivering it (the one-sided contract: the sender, not
        the receiver, resolves the global address).
        """
        seg = self.block_segment(name, block_words)

        def _w(heap, payload, bid):
            off = seg.local_offset(jnp.asarray(bid, jnp.int32))
            return put(heap, payload, off, axis=self.axis, perm=perm)

        return self.run(_w, extra_in_specs=(P(self.axis), P()))

    def read_symbol(self, name: str, *, perm: Perm) -> Callable:
        """A jitted ``f(global_heap) -> (heap, chunk)`` GETting symbol
        ``name`` from the peers named by ``perm`` (request + reply)."""
        sym = self.heap.symbol(name)

        def _r(heap, _dummy=None):
            chunk = get(
                heap, sym.offset, sym.size, axis=self.axis, perm=perm
            )
            return heap, chunk

        return self.run(_r, extra_out_specs=P(self.axis))
