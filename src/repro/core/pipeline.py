"""The generalized ART scheduler: any collective, any per-chunk compute.

The paper's ART (Sec. III-B) streams a producer's results chunk-by-chunk so
the wire time hides under the remaining compute.  ``core/art.py`` expressed
that for one pattern (matmul partials into a ring reduce-scatter); this
module is the pattern itself, factored out so *any* conduit collective can
interleave with *any* per-chunk compute.

The structural property every scheduler here preserves — and the only thing
XLA's latency-hiding scheduler needs — is that **the collective of chunk
*k* is data-independent of the compute of chunk *k+1***.  XLA then emits
``collective-permute-start``/``-done`` (or ``all-to-all-start``/``-done``)
pairs and moves the ``done`` past the next chunk's compute: the AM
sequencer's overlap, played by the compiler.

Three loop shapes, one discipline:

* :func:`chunk_pipeline` — the *producer* pipeline (ART proper): chunk *k*
  is computed while chunk *k−1*'s transfer is in flight, and a ``consume``
  hook folds whatever the transfer delivered.  ``loop=True`` rolls the body
  into ``lax.fori_loop`` (uniform chunks, O(1) trace size — what
  ``core/art.py`` builds on); the default unrolled form permits uneven
  chunk shapes.  :func:`chunk_pipeline_carried` is the same loop for
  producers whose computes chain through a carry (chunked prefill: chunk
  *k* attends to the K/V chunks ``< k`` wrote) while the payload path
  stays pipelined.
* :func:`streamed` — the *consumer* pipeline: chunk *k*'s collective is
  issued, then chunk *k−1*'s result is consumed while *k* is in flight.
  ``Conduit.streamed`` binds this to the transport registry; the streamed
  MoE dispatch (``models/moe_ep.py``) and the bucketed gradient sync
  (``dist/grad_sync.py``) are both instances.
* :func:`ring_pipeline` — the hop-carried ring loop every ring/bidir
  collective of ``core/conduit.py`` (and the fused-matmul schedules of
  ``core/overlap.py``) is an instance of: the permute of hop *k* never
  depends on the body's work for hop *k*.

Chunking never changes numerics: :func:`chunk_slices` partitions a payload
elementwise, every piece runs the identical schedule, and re-concatenation
restores the bulk result bit-for-bit (the PR-2 discipline, asserted per
entry point by ``tests/test_pipeline.py``).
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Chunk partitioning (elementwise, order-preserving — numerics-neutral)
# ---------------------------------------------------------------------------


def chunk_slices(total: int, n: int) -> List[Tuple[int, int]]:
    """``n`` nearly equal, order-preserving ``(lo, hi)`` cuts of ``total``.

    Boundaries are ``round(i·total/n)``; empty cuts (when ``n > total``)
    are dropped, so the returned list partitions ``range(total)`` exactly.
    """
    cuts = [round(i * total / n) for i in range(n + 1)]
    return [(lo, hi) for lo, hi in zip(cuts, cuts[1:]) if hi > lo]


def n_chunks(total_bytes: int, chunk_bytes: Optional[int], limit: int) -> int:
    """⌈total_bytes / chunk_bytes⌉ clamped to ``[1, limit]`` (the splittable
    extent); ``None``/oversized ``chunk_bytes`` means one chunk (bulk)."""
    if not chunk_bytes or total_bytes <= chunk_bytes:
        return 1
    return max(1, min(limit, -(-total_bytes // chunk_bytes)))


def split(x: jnp.ndarray, n: int, axis: int = 0) -> List[jnp.ndarray]:
    """Static split of ``x`` along ``axis`` into ≤ ``n`` nearly equal pieces
    (uneven extents allowed — the last pieces are one element shorter)."""
    sl = [slice(None)] * x.ndim
    out = []
    for lo, hi in chunk_slices(x.shape[axis], n):
        sl[axis] = slice(lo, hi)
        out.append(x[tuple(sl)])
    return out


# ---------------------------------------------------------------------------
# The producer pipeline (ART proper)
# ---------------------------------------------------------------------------


def chunk_pipeline(
    n: int,
    compute: Callable[[Any], Any],
    transfer: Callable[[Any, Any], Any],
    consume: Callable[[Any, Any, Any], Any],
    *,
    init: Any = None,
    loop: bool = False,
) -> Any:
    """Run ``n`` chunks of ``compute`` with each finished chunk's
    ``transfer`` overlapping the next chunk's compute.

    Per chunk *k*: ``payload_k = compute(k)`` is shipped with
    ``transfer(k, payload_k)`` and folded by
    ``state = consume(state, k, arrived_k)``.  The loop is ordered so the
    transfer of chunk *k−1* is issued *before* compute of chunk *k* and
    neither depends on the other — the ART overlap window.

    ``init`` seeds the state; a callable ``init`` receives chunk 0's
    payload (so accumulators can be shaped from it).  ``loop=True`` uses
    ``lax.fori_loop`` (chunk indices arrive traced; compute/consume must be
    shape-uniform across chunks); the default unrolls, permitting uneven
    chunks.  Both orders are identical op-for-op, so the choice never
    changes numerics.
    """
    first = compute(jnp.int32(0) if loop else 0)
    state = init(first) if callable(init) else init
    if n <= 1:
        return consume(state, 0, transfer(0, first))

    if loop:
        def body(k, carry):
            state, prev = carry
            # issue the transfer of the *previous* chunk ...
            arrived = transfer(k - 1, prev)
            # ... while computing the next one (no data dependence between
            # these two lines — the ART overlap window)
            nxt = compute(k)
            return consume(state, k - 1, arrived), nxt

        state, last = lax.fori_loop(1, n, body, (state, first))
        return consume(state, n - 1, transfer(n - 1, last))

    prev = first
    for k in range(1, n):
        arrived = transfer(k - 1, prev)     # chunk k−1 in flight ...
        nxt = compute(k)                    # ... while chunk k computes
        state = consume(state, k - 1, arrived)
        prev = nxt
    return consume(state, n - 1, transfer(n - 1, prev))


def chunk_pipeline_carried(
    n: int,
    compute: Callable[[int, Any], Tuple[Any, Any]],
    transfer: Callable[[int, Any], Any],
    consume: Callable[[Any, int, Any], Any],
    *,
    carry: Any,
    init: Any = None,
) -> Tuple[Any, Any]:
    """:func:`chunk_pipeline` with a sequential carry through the computes.

    ``compute(k, carry) -> (payload_k, carry')`` — for producers whose
    chunks are *data-dependent in sequence* (chunked prefill: chunk *k*'s
    attention reads the K/V scratch chunks ``< k`` wrote) but whose
    **payload path stays pipelined**: the transfer/consume of chunk *k−1*
    is issued before compute of chunk *k* and depends only on ``payload``,
    never on ``carry`` — the ART overlap window holds for the wire even
    though the computes chain.  Unrolled only (the carry chain rules out
    ``fori_loop`` without shape-uniform chunks; uneven chunks welcome).

    Returns ``(state, carry)`` after all ``n`` chunks.
    """
    first, carry = compute(0, carry)
    state = init(first) if callable(init) else init
    if n <= 1:
        return consume(state, 0, transfer(0, first)), carry

    prev = first
    for k in range(1, n):
        arrived = transfer(k - 1, prev)     # chunk k−1's payload in flight
        nxt, carry = compute(k, carry)      # ... while chunk k computes
        state = consume(state, k - 1, arrived)
        prev = nxt
    return consume(state, n - 1, transfer(n - 1, prev)), carry


# ---------------------------------------------------------------------------
# The consumer pipeline (streamed collectives)
# ---------------------------------------------------------------------------


def streamed(
    n: int,
    issue: Callable[[int], Any],
    consume: Optional[Callable[[int, Any], Any]] = None,
) -> List[Any]:
    """Issue ``n`` chunked collectives with each arrival's ``consume``
    overlapping the next chunk's flight.

    ``issue(k)`` starts chunk *k*'s collective; ``consume(k, arrived)``
    (identity when ``None``) digests what chunk *k* delivered while chunk
    *k+1* is in flight — the mirror image of :func:`chunk_pipeline`, for
    when the wire *feeds* the compute (streamed MoE dispatch: expert FFN on
    bucket *k−1* while bucket *k*'s all_to_all flies).  Returns the ``n``
    consumed results in chunk order.
    """
    if n <= 0:
        return []
    if consume is None:
        def consume(_k, arrived):
            return arrived
    prev = issue(0)
    outs: List[Any] = []
    for k in range(1, n):
        cur = issue(k)                      # chunk k in flight ...
        outs.append(consume(k - 1, prev))   # ... while chunk k−1 is consumed
        prev = cur
    outs.append(consume(n - 1, prev))
    return outs


# ---------------------------------------------------------------------------
# The hop-carried ring loop (every ring/bidir collective is an instance)
# ---------------------------------------------------------------------------


def ring_pipeline(wire, perms: Sequence, axis: str, hops: int, body) -> Any:
    """The one ring loop every ring/bidir collective is an instance of.

    ``wire``: tuple of pytrees riding the ring (one entry per direction);
    ``perms``: matching tuple of static permutations;
    ``body(hop, arrived) -> (wire', state)`` consumes what the hop
    delivered.  Returns the last ``state``.  The permute of hop *k* never
    depends on ``body``'s work for hop *k* — the ART overlap window
    (DESIGN §3).
    """
    state = None
    for hop in range(1, hops + 1):
        arrived = tuple(
            jax.tree.map(lambda t, p=p: lax.ppermute(t, axis, p), w)
            for w, p in zip(wire, perms)
        )
        wire, state = body(hop, arrived)
    return state


__all__ = [
    "chunk_slices", "n_chunks", "split",
    "chunk_pipeline", "chunk_pipeline_carried", "streamed", "ring_pipeline",
]
