"""GASNet "extended API" collectives — thin wrappers over the conduit layer.

GASNet layers barriers/collectives on top of the core AM primitives; we do
the same, except the schedules themselves now live in one place: the
conduit registry (``repro.core.conduit``).  Every function here binds the
paper-faithful ``ring`` transport (n−1 one-sided ``fshmem_put`` hops, each
an ART-sized message — DESIGN §4); callers who want the XLA built-ins, the
full-duplex ``bidir`` rings, or cost-model-driven selection construct a
:class:`repro.core.conduit.Conduit` directly.

``repro.dist.grad_sync.cross_pod_all_reduce`` routes the cross-pod
data-parallel gradient reduction through these conduits (optionally with
8-bit error-feedback compression as a conduit wrapper), making the PGAS
layer a first-class transport for training.

All functions run inside ``shard_map`` over ``axis``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import conduit as _conduit


def _ring(axis: str, chunk_bytes: int | None = None) -> _conduit.Conduit:
    return _conduit.Conduit(axis=axis, transport="ring",
                            chunk_bytes=chunk_bytes)


def barrier(axis: str) -> jnp.ndarray:
    """GASNet barrier: every rank reports in; returns the participant count.

    A ones-token relayed around the PUT ring (n−1 hops): every rank counts
    the same n, but the result is *not* statically provably replicated the
    way the old ``psum(1)`` was — consume it with per-rank out_specs, or
    use ``Conduit(axis, "xla").barrier()`` for the psum form.
    """
    return _ring(axis).barrier()


def broadcast(x: jnp.ndarray, root: int, *, axis: str) -> jnp.ndarray:
    """One-sided broadcast: the value propagates from root around the ring,
    one PUT per hop (n−1 hops).  Non-root inputs are ignored, as in
    shmem_broadcast."""
    return _ring(axis).broadcast(x, root)


def ring_all_gather(x: jnp.ndarray, *, axis: str,
                    chunk_bytes: int | None = None) -> jnp.ndarray:
    """All-gather via n−1 ring PUTs: each rank forwards the block it just
    received (bandwidth-optimal, (n−1)/n · |global| bytes per rank).

    ``x``: (B, ...) local block; returns (n·B, ...) tiled on axis 0.
    """
    return _ring(axis, chunk_bytes).all_gather(x)


def ring_reduce_scatter(x: jnp.ndarray, *, axis: str,
                        chunk_bytes: int | None = None) -> jnp.ndarray:
    """Reduce-scatter via the ring invariant of ``art_matmul_reducescatter``:
    block b_q starts at rank q+1, gathers every rank's contribution along
    n−1 hops, and lands fully reduced at its owner.

    ``x``: (n·B, ...) per-rank vector of partial sums; returns (B, ...) —
    this rank's fully-reduced block.
    """
    return _ring(axis, chunk_bytes).reduce_scatter(x)


def ring_all_reduce(x: jnp.ndarray, *, axis: str,
                    chunk_bytes: int | None = None) -> jnp.ndarray:
    """Bandwidth-optimal all-reduce = ring reduce-scatter + ring all-gather
    (2·(n−1)/n · |x| bytes on the wire per rank, the textbook optimum —
    and every hop is an `fshmem_put`-sized message, i.e. ART-chunked by
    construction)."""
    return _ring(axis, chunk_bytes).all_reduce(x)


def all_to_all_chunked(x: jnp.ndarray, *, axis: str,
                       chunk_bytes: int | None = None) -> jnp.ndarray:
    """All-to-all via n−1 single-block ring hops (MoE dispatch transport).

    ``x``: (n, B, ...) — slot q is destined for rank q.  Returns (n, B, ...)
    where slot q holds the block rank q sent here.  Each hop moves exactly
    one block per rank, so the per-hop message size is |x|/n — i.e. the
    all-to-all is already ART-chunked by construction.
    """
    return _ring(axis, chunk_bytes).all_to_all(x)
