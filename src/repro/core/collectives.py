"""GASNet "extended API" collectives built from one-sided PUT chunks.

GASNet layers barriers/collectives on top of the core AM primitives; we do
the same: every collective here is composed of ring ``ppermute`` steps (the
``fshmem_put`` transport), so each can trade per-message overhead against
pipeline overlap exactly like the paper's packet-size sweep in Fig. 5.

These are the *paper-faithful* software collectives.
``repro.dist.grad_sync.cross_pod_all_reduce`` routes the cross-pod
data-parallel gradient reduction through :func:`ring_all_reduce` and
:func:`ring_all_gather` (optionally with 8-bit error-feedback compression
from ``optim/compress.py``) instead of the XLA built-in ``psum``, making
the PGAS layer a first-class transport for training — and giving us a
handle to chunk/overlap/compress the cross-pod hop.

All functions run inside ``shard_map`` over ``axis``.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.art import _ring_perm


def barrier(axis: str) -> jnp.ndarray:
    """GASNet barrier: every rank reports in; returns the participant count.

    (An all-reduce of 1 — the cheapest full-synchronization primitive.)
    """
    return lax.psum(jnp.ones((), jnp.int32), axis)


def broadcast(x: jnp.ndarray, root: int, *, axis: str) -> jnp.ndarray:
    """One-sided broadcast: the value propagates from root around the ring,
    one PUT per hop (n−1 hops).  Non-root inputs are ignored, as in
    shmem_broadcast."""
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    cur = jnp.where(my == root, x, jnp.zeros_like(x))
    have = my == root
    perm = _ring_perm(n, 1)
    for _ in range(n - 1):
        arrived = lax.ppermute(cur, axis, perm)
        have_prev = lax.ppermute(have, axis, perm)
        cur = jnp.where(~have & have_prev, arrived, cur)
        have = have | have_prev
    return cur


def ring_all_gather(x: jnp.ndarray, *, axis: str) -> jnp.ndarray:
    """All-gather via n−1 ring PUTs: each rank forwards the block it just
    received (bandwidth-optimal, (n−1)/n · |global| bytes per rank).

    ``x``: (B, ...) local block; returns (n·B, ...) tiled on axis 0.
    """
    n = lax.axis_size(axis)
    perm = _ring_perm(n, 1)
    my = lax.axis_index(axis)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = lax.dynamic_update_index_in_dim(out, x, my, 0)
    cur = x
    for hop in range(1, n):
        cur = lax.ppermute(cur, axis, perm)
        src = (my - hop) % n
        out = lax.dynamic_update_index_in_dim(out, cur, src, 0)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def ring_reduce_scatter(x: jnp.ndarray, *, axis: str) -> jnp.ndarray:
    """Reduce-scatter via the ring invariant of ``art_matmul_reducescatter``:
    block b_q starts at rank q+1, gathers every rank's contribution along
    n−1 hops, and lands fully reduced at its owner.

    ``x``: (n·B, ...) per-rank vector of partial sums; returns (B, ...) —
    this rank's fully-reduced block.
    """
    n = lax.axis_size(axis)
    assert x.shape[0] % n == 0, (x.shape, n)
    b = x.shape[0] // n
    perm = _ring_perm(n, 1)
    my = lax.axis_index(axis)

    def block(owner_offset: int):
        start = ((my + owner_offset) % n) * b
        return lax.dynamic_slice_in_dim(x, start, b, 0)

    cur = block(-1)
    for hop in range(1, n):
        arrived = lax.ppermute(cur, axis, perm)
        cur = arrived + block(-(hop + 1))
    return cur


def ring_all_reduce(x: jnp.ndarray, *, axis: str) -> jnp.ndarray:
    """Bandwidth-optimal all-reduce = ring reduce-scatter + ring all-gather
    (2·(n−1)/n · |x| bytes on the wire per rank, the textbook optimum —
    and every hop is an `fshmem_put`-sized message, i.e. ART-chunked by
    construction)."""
    n = lax.axis_size(axis)
    orig_shape = x.shape
    n_elems = 1
    for s in orig_shape:
        n_elems *= s
    flat = x.reshape(-1)
    pad = (-n_elems) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    reduced_block = ring_reduce_scatter(flat, axis=axis)
    gathered = ring_all_gather(reduced_block, axis=axis)
    return gathered[:n_elems].reshape(orig_shape)


def all_to_all_chunked(x: jnp.ndarray, *, axis: str) -> jnp.ndarray:
    """All-to-all via n−1 single-block ring hops (MoE dispatch transport).

    ``x``: (n, B, ...) — slot q is destined for rank q.  Returns (n, B, ...)
    where slot q holds the block rank q sent here.  Each hop moves exactly
    one block per rank, so the per-hop message size is |x|/n — i.e. the
    all-to-all is already ART-chunked by construction.
    """
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    out = jnp.zeros_like(x)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(x, my, 0, keepdims=False), my, 0
    )
    for shift in range(1, n):
        perm = _ring_perm(n, shift)
        dst = (my + shift) % n
        block = jnp.take(x, dst, axis=0)
        arrived = lax.ppermute(block, axis, perm)
        src = (my - shift) % n
        out = lax.dynamic_update_index_in_dim(out, arrived, src, 0)
    return out
