"""ART — Automatic Result Transfer (paper Sec. III-B), TPU-native.

The paper's DLA produces results continuously; instead of one bulk PUT after
the computation (host-driven, latency fully exposed), ART issues a PUT for
every N valid results *during* the computation, hiding the wire time under
remaining compute and removing host intervention.

On TPU the identical mechanism is a software-pipelined loop in which
iteration *k* computes chunk *k* while the collective-permute of chunk
*k−1* is in flight.  XLA emits ``collective-permute-start`` /
``collective-permute-done`` pairs and its latency-hiding scheduler moves the
``done`` past the next chunk's compute — the AM sequencer's overlap, played
by the compiler.  We express every loop so that the permute of chunk *k*
never depends on compute *k+1* (and vice versa), which is the structural
property the scheduler needs.

The loop shape itself lives in ``core/pipeline.py``
(:func:`repro.core.pipeline.chunk_pipeline` — the *generalized* ART
scheduler, reused by the streamed conduit collectives, the MoE dispatch
pipeline and the bucketed gradient sync); this module keeps the
paper-faithful entry points and binds them to the shared scheduler.

Three entry points:

* :func:`art_send` — generic producer→consumer chunk pipeline: compute a
  chunk, put it to the peer, accumulate at the receiver.
* :func:`art_matmul_reducescatter` — the paper's Fig. 6(a) parallel matmul,
  generalized from 2 FPGAs to an n-rank ring: every rank holds a column
  block of M and a row block of N; partial sums are exchanged chunk-by-chunk
  while the next row-chunk is computed.  (With n=2 this is exactly the
  paper's pseudo-code: compute with N_{0,0},N_{1,1}; exchange; compute with
  N_{0,1},N_{1,0}; accumulate.)
* :func:`split_conv_allgather` — Fig. 6(b): output channels split across
  ranks, synchronize + concatenate at the end (the paper notes this end-sync
  is why convolution never quite reaches 2×).

All run inside ``shard_map`` over the PGAS axis.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
from jax import lax

from repro.core.pipeline import chunk_pipeline
from repro.core.vma import vary


def _ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# Generic ART pipeline
# ---------------------------------------------------------------------------


def art_send(
    compute_chunk: Callable[[jnp.ndarray], jnp.ndarray],
    n_chunks: int,
    *,
    axis: str,
    shift: int = 1,
    accumulate: bool = True,
):
    """Build an ART producer/consumer: each rank computes ``n_chunks`` chunks
    with ``compute_chunk(k)`` and PUTs each finished chunk to
    ``rank+shift``; the receiver accumulates (or stacks) them.

    Returns a function ``() -> received`` to call inside shard_map.  The loop
    (``pipeline.chunk_pipeline(loop=True)``) keeps the permute of chunk
    *k−1* independent of compute of chunk *k* so XLA can overlap them (see
    module docstring).
    """

    def run():
        n = lax.axis_size(axis)
        perm = _ring_perm(n, shift)

        def compute(k):
            return vary(compute_chunk(k), axis)

        def transfer(k, prev):
            return lax.ppermute(prev, axis, perm)

        if accumulate:
            def init(c0):
                return vary(jnp.zeros_like(c0), axis)

            def consume(acc, k, arrived):
                return acc + arrived
        else:
            def init(c0):
                return vary(jnp.zeros((n_chunks,) + c0.shape, c0.dtype), axis)

            def consume(acc, k, arrived):
                return lax.dynamic_update_index_in_dim(acc, arrived, k, 0)

        return chunk_pipeline(n_chunks, compute, transfer, consume,
                              init=init, loop=True)

    return run


# ---------------------------------------------------------------------------
# Paper case study (a): parallel matmul with ART partial-sum exchange
# ---------------------------------------------------------------------------


def art_matmul_reducescatter(
    m_cols: jnp.ndarray,
    n_rows: jnp.ndarray,
    *,
    axis: str,
    n_chunks: int,
) -> jnp.ndarray:
    """Fig. 6(a), n-rank generalization.

    Inputs (per rank p of n):
      ``m_cols``: (R, K/n)   — column block p of M
      ``n_rows``: (K/n, C)   — row block p of N

    Every rank computes the full-width partial product
    ``M[:, p] @ N[p, :]`` row-chunk by row-chunk; while the ring
    reduce-scatter of chunk *k−1* is in flight it computes chunk *k*
    (the ART overlap).  After the ring, each rank holds its complete column
    block of ``C = M @ N``: a *reduce-scatter fused into the matmul*.

    Ring reduce-scatter invariant (blocks indexed by owner rank): block
    ``b_q`` starts at rank ``q+1`` and moves +1 around the ring, gathering
    each rank's partial contribution; after ``n−1`` hops it arrives, fully
    accumulated, at its owner ``q``.

    Returns (R, C/n): rank p's column block of C, fp32 accumulated.
    """
    n = lax.axis_size(axis)
    rows, _ = m_cols.shape
    cols = n_rows.shape[1]
    assert rows % n_chunks == 0, (rows, n_chunks)
    assert cols % n == 0, (cols, n)
    rchunk = rows // n_chunks
    ccols = cols // n
    perm = _ring_perm(n, 1)
    my = lax.axis_index(axis)

    def col_block(full_chunk, owner_offset: int):
        # columns owned by rank (my + owner_offset) mod n
        start = ((my + owner_offset) % n) * ccols
        return lax.dynamic_slice(full_chunk, (0, start), (rchunk, ccols))

    def compute_chunk(k):
        a = lax.dynamic_slice(m_cols, (k * rchunk, 0), (rchunk, m_cols.shape[1]))
        return jnp.dot(a, n_rows, preferred_element_type=jnp.float32)

    def ring_reduce_scatter(partial_chunk):
        # send own partial of predecessor's block; after n−1 hops we hold b_my.
        block = col_block(partial_chunk, -1)
        for hop in range(1, n):
            arrived = lax.ppermute(block, axis, perm)
            block = arrived + col_block(partial_chunk, -(hop + 1))
        return block

    # chunk k's heavy sub-matmul is independent of the ring carrying chunk
    # k−1's partials, so XLA overlaps them: ART, on the shared scheduler.
    return chunk_pipeline(
        n_chunks,
        compute=lambda k: vary(compute_chunk(k), axis),
        transfer=lambda k, partial: ring_reduce_scatter(partial),
        consume=lambda acc, k, done: lax.dynamic_update_slice(
            acc, done, (k * rchunk, 0)),
        init=vary(jnp.zeros((rows, ccols), jnp.float32), axis),
        loop=True,
    )


def bulk_matmul_reducescatter(
    m_cols: jnp.ndarray, n_rows: jnp.ndarray, *, axis: str
) -> jnp.ndarray:
    """Paper-faithful *baseline* (no ART): compute the whole partial product,
    then one bulk synchronous exchange at the end ("a large-sized message at
    the end of the computation")."""
    partial_c = jnp.dot(m_cols, n_rows, preferred_element_type=jnp.float32)
    return lax.psum_scatter(partial_c, axis, scatter_dimension=1, tiled=True)


# ---------------------------------------------------------------------------
# Paper case study (b): kernel-split convolution, end sync
# ---------------------------------------------------------------------------


def split_conv_allgather(
    images: jnp.ndarray,
    kernels_local: jnp.ndarray,
    *,
    axis: str,
) -> jnp.ndarray:
    """Fig. 6(b): weight kernels split across ranks; each rank convolves its
    share of output channels, then results are synchronized and concatenated
    so every rank holds the complete output (the paper's end-of-compute sync).

    images:        (B, H, W, Cin)          replicated
    kernels_local: (kh, kw, Cin, Cout/n)   rank's kernel group
    returns:       (B, H', W', Cout)       complete on every rank
    """
    out_local = lax.conv_general_dilated(
        images,
        kernels_local,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return lax.all_gather(out_local, axis, axis=3, tiled=True)
