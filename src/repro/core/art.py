"""ART — Automatic Result Transfer (paper Sec. III-B), TPU-native.

The paper's DLA produces results continuously; instead of one bulk PUT after
the computation (host-driven, latency fully exposed), ART issues a PUT for
every N valid results *during* the computation, hiding the wire time under
remaining compute and removing host intervention.

On TPU the identical mechanism is a software-pipelined loop in which
iteration *k* computes chunk *k* while the collective-permute of chunk
*k−1* is in flight.  XLA emits ``collective-permute-start`` /
``collective-permute-done`` pairs and its latency-hiding scheduler moves the
``done`` past the next chunk's compute — the AM sequencer's overlap, played
by the compiler.  We express every loop so that the permute of chunk *k*
never depends on compute *k+1* (and vice versa), which is the structural
property the scheduler needs.

Three entry points:

* :func:`art_send` — generic producer→consumer chunk pipeline: compute a
  chunk, put it to the peer, accumulate at the receiver.
* :func:`art_matmul_reducescatter` — the paper's Fig. 6(a) parallel matmul,
  generalized from 2 FPGAs to an n-rank ring: every rank holds a column
  block of M and a row block of N; partial sums are exchanged chunk-by-chunk
  while the next row-chunk is computed.  (With n=2 this is exactly the
  paper's pseudo-code: compute with N_{0,0},N_{1,1}; exchange; compute with
  N_{0,1},N_{1,0}; accumulate.)
* :func:`split_conv_allgather` — Fig. 6(b): output channels split across
  ranks, synchronize + concatenate at the end (the paper notes this end-sync
  is why convolution never quite reaches 2×).

All run inside ``shard_map`` over the PGAS axis.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
from jax import lax

from repro.core.vma import vary


def _ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# Generic ART pipeline
# ---------------------------------------------------------------------------


def art_send(
    compute_chunk: Callable[[jnp.ndarray], jnp.ndarray],
    n_chunks: int,
    *,
    axis: str,
    shift: int = 1,
    accumulate: bool = True,
):
    """Build an ART producer/consumer: each rank computes ``n_chunks`` chunks
    with ``compute_chunk(k)`` and PUTs each finished chunk to
    ``rank+shift``; the receiver accumulates (or stacks) them.

    Returns a function ``() -> received`` to call inside shard_map.  The loop
    body keeps the permute of chunk *k−1* independent of compute of chunk
    *k* so XLA can overlap them (see module docstring).
    """

    def run():
        n = lax.axis_size(axis)
        perm = _ring_perm(n, shift)
        c0 = compute_chunk(jnp.int32(0))

        def body(k, carry):
            acc, prev = carry
            # Issue the transfer of the *previous* chunk ...
            arrived = lax.ppermute(prev, axis, perm)
            # ... while computing the next one (no data dependence between
            # these two lines — the ART overlap window).
            nxt = compute_chunk(k)
            if accumulate:
                acc = acc + arrived
            else:
                acc = lax.dynamic_update_index_in_dim(acc, arrived, k - 1, 0)
            return acc, nxt

        if accumulate:
            acc0 = jnp.zeros_like(c0)
        else:
            acc0 = jnp.zeros((n_chunks,) + c0.shape, c0.dtype)
        acc0 = vary(acc0, axis)
        acc, last = lax.fori_loop(1, n_chunks, body, (acc0, vary(c0, axis)))
        arrived = lax.ppermute(last, axis, perm)
        if accumulate:
            return acc + arrived
        return lax.dynamic_update_index_in_dim(acc, arrived, n_chunks - 1, 0)

    return run


# ---------------------------------------------------------------------------
# Paper case study (a): parallel matmul with ART partial-sum exchange
# ---------------------------------------------------------------------------


def art_matmul_reducescatter(
    m_cols: jnp.ndarray,
    n_rows: jnp.ndarray,
    *,
    axis: str,
    n_chunks: int,
) -> jnp.ndarray:
    """Fig. 6(a), n-rank generalization.

    Inputs (per rank p of n):
      ``m_cols``: (R, K/n)   — column block p of M
      ``n_rows``: (K/n, C)   — row block p of N

    Every rank computes the full-width partial product
    ``M[:, p] @ N[p, :]`` row-chunk by row-chunk; while the ring
    reduce-scatter of chunk *k−1* is in flight it computes chunk *k*
    (the ART overlap).  After the ring, each rank holds its complete column
    block of ``C = M @ N``: a *reduce-scatter fused into the matmul*.

    Ring reduce-scatter invariant (blocks indexed by owner rank): block
    ``b_q`` starts at rank ``q+1`` and moves +1 around the ring, gathering
    each rank's partial contribution; after ``n−1`` hops it arrives, fully
    accumulated, at its owner ``q``.

    Returns (R, C/n): rank p's column block of C, fp32 accumulated.
    """
    n = lax.axis_size(axis)
    rows, _ = m_cols.shape
    cols = n_rows.shape[1]
    assert rows % n_chunks == 0, (rows, n_chunks)
    assert cols % n == 0, (cols, n)
    rchunk = rows // n_chunks
    ccols = cols // n
    perm = _ring_perm(n, 1)
    my = lax.axis_index(axis)

    def col_block(full_chunk, owner_offset: int):
        # columns owned by rank (my + owner_offset) mod n
        start = ((my + owner_offset) % n) * ccols
        return lax.dynamic_slice(full_chunk, (0, start), (rchunk, ccols))

    def compute_chunk(k):
        a = lax.dynamic_slice(m_cols, (k * rchunk, 0), (rchunk, m_cols.shape[1]))
        return jnp.dot(a, n_rows, preferred_element_type=jnp.float32)

    def ring_reduce_scatter(partial_chunk):
        # send own partial of predecessor's block; after n−1 hops we hold b_my.
        block = col_block(partial_chunk, -1)
        for hop in range(1, n):
            arrived = lax.ppermute(block, axis, perm)
            block = arrived + col_block(partial_chunk, -(hop + 1))
        return block

    def body(k, carry):
        acc, partial_prev = carry
        # Compute chunk k (heavy matmul) — independent of the ring below, so
        # XLA overlaps it with the in-flight transfer of chunk k−1: ART.
        partial_cur = compute_chunk(k)
        done = ring_reduce_scatter(partial_prev)
        acc = lax.dynamic_update_slice(acc, done, ((k - 1) * rchunk, 0))
        return acc, partial_cur

    acc0 = vary(jnp.zeros((rows, ccols), jnp.float32), axis)
    acc, partial_last = lax.fori_loop(
        1, n_chunks, body, (acc0, vary(compute_chunk(0), axis))
    )
    done = ring_reduce_scatter(partial_last)
    return lax.dynamic_update_slice(acc, done, ((n_chunks - 1) * rchunk, 0))


def bulk_matmul_reducescatter(
    m_cols: jnp.ndarray, n_rows: jnp.ndarray, *, axis: str
) -> jnp.ndarray:
    """Paper-faithful *baseline* (no ART): compute the whole partial product,
    then one bulk synchronous exchange at the end ("a large-sized message at
    the end of the computation")."""
    partial_c = jnp.dot(m_cols, n_rows, preferred_element_type=jnp.float32)
    return lax.psum_scatter(partial_c, axis, scatter_dimension=1, tiled=True)


# ---------------------------------------------------------------------------
# Paper case study (b): kernel-split convolution, end sync
# ---------------------------------------------------------------------------


def split_conv_allgather(
    images: jnp.ndarray,
    kernels_local: jnp.ndarray,
    *,
    axis: str,
) -> jnp.ndarray:
    """Fig. 6(b): weight kernels split across ranks; each rank convolves its
    share of output channels, then results are synchronized and concatenated
    so every rank holds the complete output (the paper's end-of-compute sync).

    images:        (B, H, W, Cin)          replicated
    kernels_local: (kh, kw, Cin, Cout/n)   rank's kernel group
    returns:       (B, H', W', Cout)       complete on every rank
    """
    out_local = lax.conv_general_dilated(
        images,
        kernels_local,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return lax.all_gather(out_local, axis, axis=3, tiled=True)
