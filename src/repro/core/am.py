"""GASNet Active Messages in JAX — the FSHMEM GASNet-core dispatch layer.

GASNet's core API is the Active Message: every message head names a *handler*
that runs on arrival, and the body carries the handler's arguments plus an
optional data payload.  The paper implements this in hardware by replacing
the handler *function pointer* with a handler *opcode* checked by the AM
receive handler (Sec. III-A).  We do exactly the same thing in JAX:

* a :class:`HandlerRegistry` assigns each registered handler a dense opcode;
* delivery is a ``ppermute`` of ``(opcode, args, payload)``;
* dispatch is ``jax.lax.switch(opcode, handlers, ...)`` on the receiving
  shard — the traced analogue of the hardware opcode check.

Message classes follow the spec (Table I):

=========  ================================================================
Short      header + args only, no payload (config updates, GET requests)
Medium     payload delivered to the handler as *local scratch* (not heap)
Long       payload deposited at a heap address **before** the handler runs
=========  ================================================================

``gasnet_put`` / ``gasnet_get`` are built on these exactly as in the paper:
PUT = long AMRequest invoking the PUT handler; GET = short AMRequest whose
handler issues a long PUT *reply*.  Replies may not themselves reply
(GASNet rule), which is why the registry keeps separate request and reply
tables.

All functions run inside ``shard_map`` over the PGAS axis.  Sizes of args
and payloads are static per call site — the software analogue of
``gasnet_AMMaxMedium()``-style hardware limits.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core.pgas import Perm, _recv_mask

MAX_ARGS = 8  # i32 argument slots in an AM header (gasnet: 16 max; 8 suffices)

# Handler signatures
#   request handler: (heap, args i32[MAX_ARGS], payload f[payload_size])
#       -> (heap, reply_opcode i32, reply_args i32[MAX_ARGS], reply_payload)
#   reply handler:   (heap, args, payload) -> heap
RequestHandler = Callable[
    [jnp.ndarray, jnp.ndarray, jnp.ndarray],
    Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray],
]
ReplyHandler = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


from repro.core.vma import vary_tree as _vary_tree


def make_args(*vals) -> jnp.ndarray:
    """Pack up to MAX_ARGS integers into an AM header argument block."""
    a = jnp.zeros((MAX_ARGS,), jnp.int32)
    for i, v in enumerate(vals):
        a = a.at[i].set(jnp.asarray(v, jnp.int32))
    return a


@dataclasses.dataclass
class _Entry:
    name: str
    opcode: int
    fn: Callable


class HandlerRegistry:
    """Opcode table for AM request and reply handlers.

    Registration order defines opcodes — the same contract as the paper's
    hardware opcode map.  Two built-ins mirror the GASNet core:

    * ``PUT`` (request): write payload at ``args[0]``; no reply.
    * ``PUT_REPLY`` (reply): write payload at ``args[0]`` (GET's second half).
    * ``NOP_REPLY`` (reply): opcode 0, does nothing — "no reply requested".
    """

    def __init__(self) -> None:
        self._requests: List[_Entry] = []
        self._replies: List[_Entry] = []
        # opcode 0 = nop reply so every request can return "no reply".
        self.register_reply("NOP_REPLY", lambda heap, args, payload: heap)
        self.register_reply("PUT_REPLY", _put_reply_handler)
        self.register_request("PUT", _put_request_handler)
        self.register_request("GET", _get_request_handler)

    # -- registration -------------------------------------------------------

    def register_request(self, name: str, fn: RequestHandler) -> int:
        """Register a request handler; returns its integer opcode."""
        opcode = len(self._requests)
        self._requests.append(_Entry(name, opcode, fn))
        return opcode

    def register_reply(self, name: str, fn: ReplyHandler) -> int:
        """Register a reply handler; returns its integer opcode."""
        opcode = len(self._replies)
        self._replies.append(_Entry(name, opcode, fn))
        return opcode

    def request_opcode(self, name: str) -> int:
        """Opcode of the request handler registered as ``name``."""
        for e in self._requests:
            if e.name == name:
                return e.opcode
        raise KeyError(name)

    def reply_opcode(self, name: str) -> int:
        """Opcode of the reply handler registered as ``name``."""
        for e in self._replies:
            if e.name == name:
                return e.opcode
        raise KeyError(name)

    # -- dispatch (the hardware "AM receive handler") -------------------------

    def dispatch_request(self, opcode, heap, args, payload, *, axis: str | None = None):
        """Invoke the request handler for a (traced) ``opcode`` —
        ``lax.switch`` over the handler table, the software analogue of
        the paper's AM sequencer."""
        branches = [
            (lambda h, a, p, fn=e.fn: _vary_tree(fn(h, a, p), axis))
            for e in self._requests
        ]
        return lax.switch(opcode, branches, heap, args, payload)

    def dispatch_reply(self, opcode, heap, args, payload, *, axis: str | None = None):
        """Invoke the reply handler for a (traced) ``opcode``."""
        branches = [
            (lambda h, a, p, fn=e.fn: _vary_tree(fn(h, a, p), axis))
            for e in self._replies
        ]
        return lax.switch(opcode, branches, heap, args, payload)


# -- built-in handlers (the paper's PUT / GET flows) -------------------------


def _put_request_handler(heap, args, payload):
    dst = args[0]
    heap = lax.dynamic_update_slice(heap, payload.astype(heap.dtype), (dst,))
    reply_payload = jnp.zeros_like(payload)
    return heap, jnp.int32(0), jnp.zeros((MAX_ARGS,), jnp.int32), reply_payload


def _get_request_handler(heap, args, payload):
    # args[0] = source offset on this rank; args[1] = dst offset at requester.
    src, dst = args[0], args[1]
    chunk = lax.dynamic_slice(heap, (src,), payload.shape)
    return heap, jnp.int32(1), make_args(dst), chunk.astype(payload.dtype)


def _put_reply_handler(heap, args, payload):
    dst = args[0]
    return lax.dynamic_update_slice(heap, payload.astype(heap.dtype), (dst,))


# ---------------------------------------------------------------------------
# Wire transfer + round trip
# ---------------------------------------------------------------------------


def _deliver(msg, axis: str, perm: Perm, *, epoch=None):
    """ppermute a pytree of message fields (one wire transfer).

    Consults the conduit failure probe first (``conduit.check_failure``):
    a dead peer surfaces as a typed ``RankFailure`` at injection time
    instead of a hung wire — the AM layer shares the conduit's failure
    surface because on hardware both ride the same NIC.  When the caller
    pins a membership ``epoch``, the conduit epoch check runs too
    (``conduit.check_epoch``): a delivery built against a superseded view
    raises ``StaleEpoch`` instead of landing in the new one.
    """
    import jax

    from repro.core.conduit import check_epoch, check_failure
    check_failure("am_deliver", axis)
    check_epoch("am_deliver", epoch)
    return jax.tree.map(lambda x: lax.ppermute(x, axis, list(perm)), msg)


def am_request(
    registry: HandlerRegistry,
    heap: jnp.ndarray,
    opcode,
    args: jnp.ndarray,
    payload: jnp.ndarray,
    *,
    axis: str,
    perm: Perm,
    epoch=None,
) -> jnp.ndarray:
    """Send an AM request from each ``src`` to ``dst`` in ``perm``, run the
    request handler at the destination, deliver its reply back, and run the
    reply handler at the origin.  Returns the updated local heap.

    Non-participating ranks dispatch opcode 0 with zero payloads, which the
    mask then discards — the SPMD cost of the one-sided model (same trick a
    hardware NIC uses: every port always clocks, idle ports carry null
    frames).  ``epoch`` (optional) pins both wire transfers to a
    membership epoch (see ``_deliver``).
    """
    perm = list(perm)
    rev = [(d, s) for (s, d) in perm]
    opcode = jnp.asarray(opcode, jnp.int32)

    # --- request wire transfer (header + body) ---
    op_r, args_r, body_r = _deliver((opcode, args, payload), axis, perm,
                                    epoch=epoch)
    recv = _recv_mask(axis, perm)
    op_safe = jnp.where(recv, op_r, 0)

    new_heap, rep_op, rep_args, rep_payload = registry.dispatch_request(
        op_safe, heap, args_r, body_r, axis=axis
    )
    heap = jnp.where(recv, new_heap, heap)
    rep_op = jnp.where(recv, rep_op, 0)

    # --- reply wire transfer (destination -> origin) ---
    rop_b, rargs_b, rbody_b = _deliver((rep_op, rep_args, rep_payload), axis,
                                       rev, epoch=epoch)
    recv_rep = _recv_mask(axis, rev)
    rop_safe = jnp.where(recv_rep, rop_b, 0)
    replied = registry.dispatch_reply(rop_safe, heap, rargs_b, rbody_b, axis=axis)
    return jnp.where(recv_rep, replied, heap)


# -- message-class wrappers (Table I) ----------------------------------------


def am_request_short(registry, heap, opcode, args, *, axis, perm, epoch=None):
    """Short AM: header + args, zero-length payload."""
    payload = jnp.zeros((1,), heap.dtype)  # 1-word null frame (shape-static)
    return am_request(registry, heap, opcode, args, payload, axis=axis,
                      perm=perm, epoch=epoch)


def am_request_medium(
    registry, heap, opcode, args, payload, *, axis, perm, epoch=None
):
    """Medium AM: payload handed to the handler as scratch (not heap-addressed).

    Returns ``(heap, scratch)`` where scratch is the delivered payload on
    receiving ranks — the "local memory address" of the spec.
    """
    perm = list(perm)
    op_r, args_r, body_r = _deliver(
        (jnp.asarray(opcode, jnp.int32), args, payload), axis, perm,
        epoch=epoch)
    recv = _recv_mask(axis, perm)
    op_safe = jnp.where(recv, op_r, 0)
    new_heap, _, _, _ = registry.dispatch_request(op_safe, heap, args_r, body_r, axis=axis)
    heap = jnp.where(recv, new_heap, heap)
    scratch = jnp.where(recv, body_r, jnp.zeros_like(body_r))
    return heap, scratch


def am_request_long(registry, heap, opcode, args, payload, dst_offset, *,
                    axis, perm, epoch=None):
    """Long AM: payload is deposited at ``dst_offset`` in the destination's
    heap **before** the handler runs (the spec's ordering guarantee)."""
    from repro.core.conduit import check_epoch
    check_epoch("am_deliver", epoch)
    perm = list(perm)
    body_r = lax.ppermute(payload, axis, perm)
    off_r = lax.ppermute(jnp.asarray(dst_offset, jnp.int32), axis, perm)
    recv = _recv_mask(axis, perm)
    deposited = lax.dynamic_update_slice(heap, body_r.astype(heap.dtype), (off_r,))
    heap = jnp.where(recv, deposited, heap)
    # Handler then runs with the deposit address in args[0].
    op_r, args_r = _deliver((jnp.asarray(opcode, jnp.int32), args), axis, perm)
    op_safe = jnp.where(recv, op_r, 0)
    new_heap, _, _, _ = registry.dispatch_request(
        op_safe, heap, args_r.at[0].set(off_r), jnp.zeros((1,), heap.dtype), axis=axis
    )
    return jnp.where(recv, new_heap, heap)


# -- extended API on top of AM (the paper's gasnet_put / gasnet_get) ---------


def gasnet_put(registry, heap, payload, dst_offset, *, axis, perm, epoch=None):
    """PUT = long AM request invoking the PUT handler (paper Sec. III-A)."""
    args = make_args(dst_offset)
    return am_request(
        registry, heap, registry.request_opcode("PUT"), args, payload,
        axis=axis, perm=perm, epoch=epoch,
    )


def gasnet_get(registry, heap, src_offset, dst_offset, size, *, axis, perm,
               epoch=None):
    """GET = short AM request; its handler issues a long PUT reply.

    ``perm`` lists ``(requester, source)`` pairs.  The requested chunk lands
    at ``dst_offset`` in the requester's heap.
    """
    from repro.core.conduit import check_failure
    check_failure("gasnet_get", axis)
    req = [(r, s) for (r, s) in perm]
    args = make_args(src_offset, dst_offset)
    payload = jnp.zeros((size,), heap.dtype)  # shape carrier for the reply
    return am_request(
        registry, heap, registry.request_opcode("GET"), args, payload,
        axis=axis, perm=req, epoch=epoch,
    )
