"""Beyond-paper: ART-style overlap applied to tensor-parallel matmuls.

The paper applies ART to one producer→consumer edge (DLA → peer FPGA).  A
transformer under tensor parallelism has the same pattern at *every* layer:

* an **all-gather edge** before a column-sharded matmul
  (``x_shard -> AG -> x_full @ W_col``), and
* a **reduce-scatter edge** after a row-sharded matmul
  (``x @ W_row -> partial -> RS``).

Both admit the identical chunking trick: split the contraction into ring
steps and let each step's ``ppermute`` fly while the next step's sub-matmul
runs.  These are the "collective matmul" schedules of Wang et al. (ASPLOS'23)
— which is precisely ART transplanted from FPGA to TPU, and is *our*
beyond-paper optimization lever for the perf hillclimb.

Two schedule families:

* unidirectional ring: n−1 hops, message size |X|/n per hop;
* bidirectional ring: two counter-rotating half-sized rings, halving the
  per-hop bytes on each link direction (ICI links are full-duplex), i.e.
  ~2× faster collective term on the same hardware.

Both functions accept either a bare ``axis`` (+ ``bidirectional`` flag) or
a :class:`repro.core.conduit.Conduit` handle, whose transport selects the
schedule family (``ring`` → unidirectional, ``bidir`` → counter-rotating,
``auto`` → cost-model choice per payload size; ``xla`` has no fused
equivalent and resolves like ``auto``).

All functions run inside ``shard_map``; the weight stays resident
(sharded), only activations move — the same locality argument the paper
makes for keeping data in each FPGA's partition.  Both schedule families
are instances of the shared hop-carried loop
(``repro.core.pipeline.ring_pipeline`` — the generalized ART scheduler,
DESIGN §3).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.core.art import _ring_perm
from repro.core.conduit import Conduit
from repro.core.pipeline import ring_pipeline


def _schedule(conduit: Optional[Conduit], axis: Optional[str],
              bidirectional: bool, size_bytes: int) -> tuple[str, bool]:
    """Resolve (axis, bidirectional) from either calling convention.

    ``size_bytes`` is the *global* payload the fused collective edge moves
    (the convention of ``conduit.estimate_time``) — what the conduit's
    cost model prices when its transport is ``auto``/``xla``."""
    if conduit is None:
        assert axis is not None, "pass either conduit= or axis="
        return axis, bidirectional
    return conduit.axis, conduit.matmul_bidirectional(size_bytes)


def allgather_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: Optional[str] = None,
    bidirectional: bool = True,
    conduit: Optional[Conduit] = None,
) -> jnp.ndarray:
    """Compute ``all_gather(x, axis) @ w`` without materializing the gather
    (Megatron column-parallel layer with the AG fused into the ring).

    Global computation: ``Y[B, N] = X[B, K] @ W[K, N]`` with ``W``
    column-sharded over the axis (``w = W[:, cols_local]``, shape
    (K, N/n)) and ``X`` row-sharded (``x = X[rows_local, :]``, shape
    (B/n, K)) — under tensor parallelism the rows are the
    sequence/batch dim, so the all-gather runs over that dim.

    Ring step *s* multiplies the row block that just arrived against the
    resident weight while the next block's ``ppermute`` is in flight, so
    the gather is hidden under the sub-matmuls (ART).  Returns
    ``(B, N/n)``: every global row, this rank's output columns — i.e.
    ``all_gather(x) @ w`` with the AG never materialized.
    """
    if conduit is not None:
        axis = conduit.axis
    # global AG payload: every rank's (B/n, K) block, i.e. local × n
    axis, bidirectional = _schedule(
        conduit, axis, bidirectional,
        x.size * x.dtype.itemsize * lax.axis_size(axis))
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    b_loc = x.shape[0]
    out = jnp.zeros((n * b_loc, w.shape[1]), jnp.float32)

    if not bidirectional or n == 2:
        perm = _ring_perm(n, 1)
        # hop 0: the local block, no permute
        y0 = jnp.dot(x, w, preferred_element_type=jnp.float32)
        out = lax.dynamic_update_slice(out, y0, (my * b_loc, 0))
        if n == 1:
            return out

        def body(hop, arrived):
            # the matmul of the block in hand overlaps the permute of the
            # next (ring_pipeline re-permutes the forwarded wire)
            nonlocal out
            (cur,) = arrived
            src = (my - hop) % n
            y = jnp.dot(cur, w, preferred_element_type=jnp.float32)
            out = lax.dynamic_update_slice(out, y, (src * b_loc, 0))
            return (cur,), out

        return ring_pipeline((x,), (perm,), axis, n - 1, body)

    # bidirectional: split the local block in two, send halves around
    # counter-rotating rings; each link direction carries half the bytes.
    fwd = _ring_perm(n, 1)
    bwd = _ring_perm(n, -1)
    half = b_loc // 2
    lo, hi = x[:half], x[half:]

    def place(out, y, src, second_half):
        row = src * b_loc + (half if second_half else 0)
        return lax.dynamic_update_slice(out, y, (row, 0))

    out = place(out, jnp.dot(lo, w, preferred_element_type=jnp.float32),
                my, False)
    out = place(out, jnp.dot(hi, w, preferred_element_type=jnp.float32),
                my, True)

    if n == 1:
        return out

    def body(hop, arrived):
        nonlocal out
        (cur_f,), (cur_b,) = arrived
        y_f = jnp.dot(cur_f, w, preferred_element_type=jnp.float32)
        y_b = jnp.dot(cur_b, w, preferred_element_type=jnp.float32)
        out = place(out, y_f, (my - hop) % n, False)
        out = place(out, y_b, (my + hop) % n, True)
        return ((cur_f,), (cur_b,)), out

    return ring_pipeline(((lo,), (hi,)), (fwd, bwd), axis, n - 1, body)


def matmul_reducescatter(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: Optional[str] = None,
    bidirectional: bool = True,
    conduit: Optional[Conduit] = None,
) -> jnp.ndarray:
    """Compute ``reduce_scatter(x @ w, axis)`` with the RS fused into the
    matmul ring (Megatron row-parallel layer; the paper's Fig. 6(a) pattern).

    x: (B, K_loc) — activations, contraction dim sharded;
    w: (K_loc, N) — row-sharded weight;
    returns: (B/n, N) — this rank's block of rows of Y, fully reduced.

    Ring step s computes the sub-matmul producing the block that must travel
    farthest next, adds the in-flight accumulator, and forwards it; the
    permute of the accumulator overlaps the next sub-matmul.
    """
    if conduit is not None:
        axis = conduit.axis
    # global RS payload: the full (B, N) fp32 partial product
    axis, bidirectional = _schedule(
        conduit, axis, bidirectional, x.shape[0] * w.shape[1] * 4)
    n = lax.axis_size(axis)
    my = lax.axis_index(axis)
    b = x.shape[0]
    assert b % n == 0, (b, n)
    b_loc = b // n

    def row_block(owner_offset: int):
        start = ((my + owner_offset) % n) * b_loc
        return lax.dynamic_slice_in_dim(x, start, b_loc, 0)

    if not bidirectional or n == 2:
        perm = _ring_perm(n, 1)
        acc = jnp.dot(row_block(-1), w, preferred_element_type=jnp.float32)
        if n == 1:
            return acc

        def body(hop, arrived):
            # next sub-matmul overlaps the permute of the accumulator
            (arr,) = arrived
            acc = arr + jnp.dot(
                row_block(-(hop + 1)), w, preferred_element_type=jnp.float32
            )
            return (acc,), acc

        return ring_pipeline((acc,), (perm,), axis, n - 1, body)

    fwd = _ring_perm(n, 1)
    bwd = _ring_perm(n, -1)
    nloc = w.shape[1]
    half = nloc // 2

    def mm(owner_offset: int, second_half: bool):
        blk = row_block(owner_offset)
        wpart = w[:, half:] if second_half else w[:, :half]
        return jnp.dot(blk, wpart, preferred_element_type=jnp.float32)

    if n == 1:
        return jnp.concatenate([mm(-1, False), mm(+1, True)], axis=1)

    def body(hop, arrived):
        (arr_f,), (arr_b,) = arrived
        acc_f = arr_f + mm(-(hop + 1), False)
        acc_b = arr_b + mm(hop + 1, True)
        return ((acc_f,), (acc_b,)), (acc_f, acc_b)

    acc_f, acc_b = ring_pipeline(((mm(-1, False),), (mm(+1, True),)),
                                 (fwd, bwd), axis, n - 1, body)
    return jnp.concatenate([acc_f, acc_b], axis=1)
