"""Analytic network performance model for FSHMEM-style PGAS transports.

The paper measures PUT/GET bandwidth and latency of the GASNet core over a
QSFP+ link between two Intel D5005 FPGAs (Fig. 5, Table III).  This container
has no QSFP+ (and no ICI), so *performance* numbers come from this model,
while *functional* semantics are exercised for real on CPU meshes
(see ``repro.core.pgas`` / ``repro.core.am``).

The model has three ingredients, each of which corresponds to a physical
mechanism described in the paper:

1. **Per-packet cost.**  A transfer of ``S`` bytes is segmented into packets
   of ``packet_size`` bytes.  Every packet pays the wire time of its payload
   plus a per-packet overhead (header + AM sequencer turnaround).  The paper's
   own measurements define the calibration table ``packet_overhead_bytes``
   (its four packet sizes are measured points; other sizes are interpolated
   in log-space).

2. **Per-message latency decomposition.**  Table III's four latency numbers
   decompose consistently into five stages (values in ``LatencyParams``):

   =====================  =====================================================
   ``t_host_cmd``         host/PCIe command issue -> scheduler -> AM sequencer
   ``t_dma``              read-DMA fetch startup for a payload (long msg only)
   ``t_header``           header serialization + wire + remote opcode check
   ``t_handler``          AM receive-handler turnaround (GET -> PUT reply)
   ``t_sched``            reply path through scheduler/FIFO (no host)
   =====================  =====================================================

   short PUT = t_host_cmd + t_header                           = 0.21 us
   long  PUT = t_host_cmd + t_dma + t_header                   = 0.35 us
   short GET = short PUT + t_handler + (t_sched + t_header)    = 0.45 us
   long  GET = short PUT + t_handler + (t_sched+t_dma+t_header)= 0.59 us

3. **Two-message GET.**  ``gasnet_get`` is a short request plus a long PUT
   reply, so it pays one extra fixed cost that is *independent of transfer
   size* — which is exactly why the paper sees GET bandwidth 20 % below PUT
   at 2 KB but only 8 % below at 8 KB.

The model reproduces, and the tests assert, every quantitative claim of
Fig. 5 / Table III:

* peak bandwidth 3813 MB/s at packet size >= 512 B (> 95 % of the 4 GB/s max)
* half of peak reached around ~2 KB transfers
* 95 % of peak ("saturation") around ~32 KB
* GET bandwidth ~20 % below PUT at 2 KB and ~8 % at 8 KB
* the four Table III latencies exactly.

A second parameter set (:data:`TPU_ICI`) instantiates the same mechanism with
TPU v5e inter-chip-interconnect constants; it is what the ART overlap
projections and the roofline collective term use.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencyParams:
    """Fixed per-message latency stages (seconds)."""

    t_host_cmd: float  # command issue -> scheduler -> sequencer
    t_dma: float       # payload read-DMA startup (long messages only)
    t_header: float    # header serialization + wire + remote check
    t_handler: float   # AM receive-handler turnaround
    t_sched: float     # reply-path scheduler/FIFO (no host involvement)

    @property
    def put_short(self) -> float:
        """Table III short-PUT latency (no payload DMA stage)."""
        return self.t_host_cmd + self.t_header

    @property
    def put_long(self) -> float:
        """Table III long-PUT latency (adds the read-DMA startup)."""
        return self.t_host_cmd + self.t_dma + self.t_header

    @property
    def get_short(self) -> float:
        """Table III short-GET latency (request + handler + short reply)."""
        return self.put_short + self.t_handler + self.t_sched + self.t_header

    @property
    def get_long(self) -> float:
        """Table III long-GET latency (request + handler + long reply)."""
        return (
            self.put_short
            + self.t_handler
            + self.t_sched
            + self.t_dma
            + self.t_header
        )


@dataclasses.dataclass(frozen=True)
class LinkParams:
    """A point-to-point link with packetized framing."""

    name: str
    line_rate: float                      # bytes/s raw
    line_efficiency: float                # encoding/framing ceiling (64b/66b etc.)
    packet_overhead_bytes: Dict[int, float]  # calibration: packet size -> overhead
    latency: LatencyParams

    @property
    def peak_bandwidth(self) -> float:
        """Ceiling imposed by line encoding, independent of packet size."""
        return self.line_rate * self.line_efficiency

    def overhead_bytes(self, packet_size: int) -> float:
        """Per-packet overhead; measured points exact, log-interp between."""
        table = self.packet_overhead_bytes
        if packet_size in table:
            return table[packet_size]
        keys = sorted(table)
        if packet_size <= keys[0]:
            return table[keys[0]]
        if packet_size >= keys[-1]:
            return table[keys[-1]]
        for lo, hi in zip(keys, keys[1:]):
            if lo < packet_size < hi:
                f = (math.log(packet_size) - math.log(lo)) / (
                    math.log(hi) - math.log(lo)
                )
                return table[lo] * (1 - f) + table[hi] * f
        raise AssertionError  # unreachable

    # -- per-packet / steady-state -----------------------------------------

    def packet_time(self, packet_size: int) -> float:
        """Wire time of one packet: payload + per-packet overhead bytes."""
        return (packet_size + self.overhead_bytes(packet_size)) / self.line_rate

    def steady_bandwidth(self, packet_size: int) -> float:
        """Bandwidth with per-message setup fully amortized (S -> inf)."""
        return min(self.peak_bandwidth, packet_size / self.packet_time(packet_size))


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# The paper's QSFP+ link: 250 MHz x 128-bit datapath = 4 GB/s raw.
# 3813 MB/s peak == 95.3 % of raw -> line_efficiency 0.9533.
# Overhead table calibrated from Fig. 5 peak bandwidths:
#   X(P) = P * (line_rate / measured_peak(P) - 1)
#   P=128 -> 2621 MB/s -> 67.4 B      P=256 -> 3419 MB/s -> 43.5 B
#   P>=512 saturate the 0.9533 ceiling; residual overhead <= ceiling slack.
FSHMEM_QSFP = LinkParams(
    name="fshmem-qsfp+",
    line_rate=4.0e9,
    line_efficiency=3813.0 / 4000.0,
    packet_overhead_bytes={128: 67.4, 256: 43.5, 512: 25.1, 1024: 25.1},
    latency=LatencyParams(
        t_host_cmd=0.12e-6,
        t_dma=0.14e-6,
        t_header=0.09e-6,
        t_handler=0.03e-6,
        t_sched=0.12e-6,
    ),
)

# TPU v5e ICI, one link direction.  ~50 GB/s/link (task constants).  ICI is
# circuit-switched with tiny per-hop latency; "packets" here are the chunk
# granularity of a software-pipelined collective (ART chunk size).  The
# per-message latency stages model the collective-permute issue overhead.
TPU_ICI = LinkParams(
    name="tpu-v5e-ici",
    line_rate=50.0e9,
    line_efficiency=0.95,
    packet_overhead_bytes={512: 64.0, 4096: 64.0, 65536: 64.0},
    latency=LatencyParams(
        t_host_cmd=0.0,      # no host on the critical path inside an XLA program
        t_dma=0.5e-6,        # DMA engine program + launch
        t_header=1.0e-6,     # per-hop ICI latency
        t_handler=0.2e-6,
        t_sched=0.3e-6,
    ),
)


# ---------------------------------------------------------------------------
# Transfer-time / bandwidth model
# ---------------------------------------------------------------------------


def n_packets(size_bytes: int, packet_size: int) -> int:
    """⌈size/packet⌉, at least one packet."""
    return max(1, -(-size_bytes // packet_size))


def put_time(link: LinkParams, size_bytes: int, packet_size: int) -> float:
    """Command-to-completion time of gasnet_put of ``size_bytes``."""
    if size_bytes == 0:
        return link.latency.put_short
    wire = n_packets(size_bytes, packet_size) * link.packet_time(packet_size)
    wire = max(wire, size_bytes / link.peak_bandwidth)  # encoding ceiling
    return link.latency.put_long + wire


def get_time(link: LinkParams, size_bytes: int, packet_size: int) -> float:
    """gasnet_get = short request + handler + long PUT reply."""
    if size_bytes == 0:
        return link.latency.get_short
    request = link.latency.put_short + link.latency.t_handler
    reply_setup = link.latency.t_sched + link.latency.t_dma + link.latency.t_header
    wire = n_packets(size_bytes, packet_size) * link.packet_time(packet_size)
    wire = max(wire, size_bytes / link.peak_bandwidth)
    return request + reply_setup + wire


def put_bandwidth(link: LinkParams, size_bytes: int, packet_size: int) -> float:
    """Effective PUT bandwidth at this transfer/packet size (Fig. 5 y-axis)."""
    return size_bytes / put_time(link, size_bytes, packet_size)


def get_bandwidth(link: LinkParams, size_bytes: int, packet_size: int) -> float:
    """Effective GET bandwidth — below PUT at small sizes (two messages)."""
    return size_bytes / get_time(link, size_bytes, packet_size)


# ---------------------------------------------------------------------------
# ART overlap model (paper Sec. III-B; used by the case-study benchmark)
# ---------------------------------------------------------------------------


def bulk_time(t_compute: float, t_comm: float, t_msg: float) -> float:
    """Baseline: compute fully, then one bulk PUT of the whole result."""
    return t_compute + t_msg + t_comm


def pipeline_time(compute_times, wire_times) -> float:
    """Wall-clock of a chunked overlap pipeline (the generalized ART model).

    ``compute_times[k]`` / ``wire_times[k]`` are chunk *k*'s compute and
    transfer (wire + per-message setup) times.  Chunk *k*'s transfer starts
    as soon as its compute has finished *and* the link is free — transfers
    serialize on the link while compute of later chunks proceeds
    underneath.  The exposed communication is whatever does not fit under
    the remaining compute, plus the final chunk's transfer, which can never
    be hidden.

    This is the cost model of ``repro.core.pipeline.chunk_pipeline``; by
    time-reversal symmetry it also prices the consumer-side pipeline
    (``pipeline.streamed``: chunk *k* arrives while chunk *k−1* is
    consumed) with the same arguments swapped, which for the uniform chunks
    ``conduit.pipeline_estimate`` sweeps is the identical number.
    """
    assert len(compute_times) == len(wire_times), (
        len(compute_times), len(wire_times))
    link_free = 0.0
    computed = 0.0
    for tc, tx in zip(compute_times, wire_times):
        computed += tc
        start = max(computed, link_free)
        link_free = start + tx
    return link_free


#: HBM stream rate used to price the per-hop repack an XLA-level collective
#: matmul pays (the arriving chunk round-trips HBM between the permute and
#: the next sub-matmul).  Shared with the roofline term of
#: ``benchmarks/overlap_pipeline.py``.
HBM_BYTES_PER_S = 100e9

#: nominal dense-matmul rate for sizing the compute term of a fused
#: collective-matmul edge (TPU v5e bf16 peak; benchmarks import it).
MXU_BF16_FLOPS = 197e12


def hop_launch_overhead(link: LinkParams, hop_bytes: int = 0,
                        hbm_bytes_per_s: float = HBM_BYTES_PER_S) -> float:
    """Per-hop boundary cost an *XLA-level* ring matmul pays and the
    in-kernel fused schedule does not.

    Between two hops of ``core/overlap.py`` the program crosses an XLA
    boundary: the next sub-matmul is a fresh launch (``t_host_cmd`` —
    zero inside a single TPU program, real on the FPGA/host path) whose
    DMA engines must be re-programmed (``t_dma``), and the chunk that
    just landed is repacked through HBM before the MXU can read it
    (``hop_bytes`` at the HBM stream rate).  The fused kernel keeps the
    chunk in VMEM and the MXU hot, so it pays none of this per hop —
    :func:`fused_pipeline_time` charges it once for the whole kernel.
    """
    boundary = link.latency.t_host_cmd + link.latency.t_dma
    return boundary + max(0, int(hop_bytes)) / hbm_bytes_per_s


def fused_pipeline_time(compute_times, wire_times, *,
                        launch_overhead: float = 0.0) -> float:
    """Wall-clock of an *in-kernel* fused ring pipeline.

    Same greedy link-serialized overlap algebra as :func:`pipeline_time`,
    but the per-hop launch/repack boundary is eliminated: the whole ring
    is one kernel, so ``launch_overhead`` (one
    :func:`hop_launch_overhead`) is paid **once** up front instead of
    per chunk.  The XLA-level streamed equivalent of the same schedule
    is ``pipeline_time([tc + oh for tc in computes], wires)`` — that
    difference is the fused transport's whole claim, and what the
    ``fused`` suite of ``BENCH_overlap.json`` records.
    """
    return launch_overhead + pipeline_time(compute_times, wire_times)


def art_time(
    t_compute: float, t_comm: float, t_msg: float, n_chunks: int
) -> float:
    """ART: the result is sent in ``n_chunks`` PUTs issued as soon as each
    chunk of results is valid, overlapping wire time with remaining compute
    (the uniform-chunk special case of :func:`pipeline_time`).
    """
    if n_chunks <= 1:
        return bulk_time(t_compute, t_comm, t_msg)
    tc = t_compute / n_chunks
    tx = t_comm / n_chunks + t_msg
    return pipeline_time([tc] * n_chunks, [tx] * n_chunks)


def art_speedup(
    t_compute: float, t_comm: float, t_msg: float, n_chunks: int
) -> float:
    """Bulk-synchronous time over ART time (the paper's Fig. 7 metric)."""
    return bulk_time(t_compute, t_comm, t_msg) / art_time(
        t_compute, t_comm, t_msg, n_chunks
    )


def serve_prefill_time(
    link: LinkParams,
    t_compute: float,
    cache_bytes: float,
    n_chunks: int,
    packet_size: int,
) -> float:
    """TTFT model of a (chunked) prefill — the serving half of ART.

    The prompt's forward produces the decode cache; writing it into the
    (remote / sequence-sharded) cache region is the paper's one-sided bulk
    ``gasnet_put``.  ``n_chunks = 1`` is bulk prefill: compute fully, then
    one PUT of ``cache_bytes`` — the first token cannot be sampled before
    both finish.  ``n_chunks > 1`` is the chunked streamed prefill of
    ``models/prefill.prefill_chunked``: chunk *k*'s cache PUT rides under
    chunk *k+1*'s forward (uniform-chunk :func:`pipeline_time`), so TTFT
    approaches ``t_compute`` + one chunk's PUT.
    """
    c = max(1, int(n_chunks))
    tx = put_time(link, max(1, -(-int(cache_bytes) // c)), packet_size)
    if c == 1:
        return t_compute + tx
    return pipeline_time([t_compute / c] * c, [tx] * c)


def carried_prefill_time(
    link: LinkParams,
    t_compute: float,
    row_bytes: float,
    carry_bytes: float,
    n_chunks: int,
    packet_size: int,
    once_bytes: float = 0.0,
) -> float:
    """TTFT model of a *carried* streamed prefill — the chunk-carry
    contract's generalization of :func:`serve_prefill_time`.

    ``row_bytes``: the per-position cache rows the prompt writes in total
    (K/V ring rows, MLA latents — split evenly over chunks);
    ``carry_bytes``: the per-chunk hand-off that rides every chunk's PUT
    (the constant-size SSD state pair — for ring carries the rows *are*
    the carry and this is 0); ``once_bytes``: one-time payload on chunk
    0's wire (the encdec cross-K/V the encoder materializes once).

    ``n_chunks = 1`` is bulk: compute fully, then one PUT of everything.
    Chunked, chunk *k*'s PUT rides under later chunks' compute
    (:func:`pipeline_time`).  The compute split is built by accumulation
    so it sums to *exactly* ``t_compute`` in floats (Sterbenz: the
    remainder ``t_compute − acc`` is exact for ``acc ∈ [t/2, t]``) —
    a pure-state arch (``row_bytes == 0``) whose per-chunk PUT fits under
    one chunk's compute therefore models *exactly* 1.0× vs bulk, which is
    the honest claim: a constant-size carry has no growing transfer to
    hide, streaming buys admission interleaving, not TTFT.
    """
    c = max(1, int(n_chunks))
    total = int(row_bytes) + int(carry_bytes) + int(once_bytes)
    if c == 1:
        return t_compute + put_time(link, total, packet_size)
    per_rows = -(-int(row_bytes) // c) if row_bytes else 0
    wires = [
        put_time(link,
                 per_rows + int(carry_bytes) + (int(once_bytes) if k == 0
                                                else 0),
                 packet_size)
        for k in range(c)
    ]
    base = t_compute / c
    acc = 0.0
    computes = []
    for _ in range(c - 1):
        computes.append(base)
        acc += base
    computes.append(t_compute - acc)
    return pipeline_time(computes, wires)


def block_push_time(
    link: LinkParams,
    block_bytes: float,
    n_blocks: int,
    packet_size: int,
) -> float:
    """Wire cost of PUTting ``n_blocks`` fixed-size KV blocks one-sided.

    The paged-pool admission path: every finished block is its own long
    PUT into the owner rank's pool segment (``core/pgas.BlockSegment``
    resolves the address), so the total pays per-message setup once per
    block — the block-size U-curve the serving docs quote (small blocks
    waste latency, huge blocks waste prefix-sharing granularity).
    """
    return max(1, int(n_blocks)) * put_time(
        link, max(1, int(block_bytes)), packet_size)


def block_push_efficiency(
    link: LinkParams, block_bytes: float, packet_size: int
) -> float:
    """Fraction of a block PUT spent moving payload (vs per-message setup)
    — the netmodel's block-size guidance knob."""
    wire = max(1, int(block_bytes)) / link.peak_bandwidth
    return wire / put_time(link, max(1, int(block_bytes)), packet_size)


def prefix_hit_ttft(
    link: LinkParams,
    t_compute: float,
    cache_bytes: float,
    n_chunks: int,
    packet_size: int,
    hit_frac: float,
    n_shared_blocks: int,
) -> float:
    """TTFT of an admission whose leading ``hit_frac`` of the prompt is
    resident in the prefix cache.

    The shared prefix is neither recomputed nor re-sent: admission maps the
    ``n_shared_blocks`` resident block ids into the slot's table — one
    *short* PUT each (header-only, the paper's 0.21 µs class) — then runs
    the chunked prefill of the remaining suffix
    (:func:`serve_prefill_time` over the surviving compute and cache
    bytes).  ``hit_frac = 0`` degenerates to the full admission.
    """
    assert 0.0 <= hit_frac < 1.0, hit_frac
    suffix = serve_prefill_time(
        link, t_compute * (1.0 - hit_frac),
        cache_bytes * (1.0 - hit_frac), n_chunks, packet_size)
    return n_shared_blocks * link.latency.put_short + suffix


def prefix_hit_speedup(
    link: LinkParams,
    t_compute: float,
    cache_bytes: float,
    n_chunks: int,
    packet_size: int,
    hit_frac: float,
    n_shared_blocks: int,
) -> float:
    """Cold-admission TTFT over prefix-hit TTFT (the BENCH_serve claim)."""
    cold = serve_prefill_time(link, t_compute, cache_bytes, n_chunks,
                              packet_size)
    return cold / prefix_hit_ttft(link, t_compute, cache_bytes, n_chunks,
                                  packet_size, hit_frac, n_shared_blocks)


def best_chunk_count(
    t_compute: float,
    t_comm: float,
    t_msg: float,
    max_chunks: int = 4096,
) -> int:
    """Chunk count minimizing ART time: more chunks hide more wire time but
    pay more per-message latency — the same U-curve as Fig. 5's packet sizes."""
    best_n, best_t = 1, bulk_time(t_compute, t_comm, t_msg)
    n = 1
    while n <= max_chunks:
        t = art_time(t_compute, t_comm, t_msg, n)
        if t < best_t:
            best_n, best_t = n, t
        n *= 2
    return best_n


# ---------------------------------------------------------------------------
# Curve helpers (used by benchmarks/bandwidth.py to reproduce Fig. 5)
# ---------------------------------------------------------------------------


def half_saturation_size(link: LinkParams, packet_size: int) -> int:
    """Smallest power-of-two transfer reaching half the steady bandwidth."""
    target = 0.5 * link.steady_bandwidth(packet_size)
    s = 4
    while put_bandwidth(link, s, packet_size) < target:
        s *= 2
        if s > 1 << 30:
            raise RuntimeError("no saturation")
    return s


def saturation_size(link: LinkParams, packet_size: int, frac: float = 0.95) -> int:
    """Smallest power-of-two transfer reaching ``frac`` of steady bandwidth."""
    target = frac * link.steady_bandwidth(packet_size)
    s = 4
    while put_bandwidth(link, s, packet_size) < target:
        s *= 2
        if s > 1 << 30:
            raise RuntimeError("no saturation")
    return s


# ---------------------------------------------------------------------------
# Elastic recovery costs (runtime/elastic.py + runtime/faults.py)
# ---------------------------------------------------------------------------

#: control rounds of a membership change: failure detect/agree, segment
#: re-register, conduit re-form barrier — each a ring of short AMs
REFORM_ROUNDS = 3

#: control-message payload of one membership round (a short AM: header,
#: member id, epoch, segment descriptor)
REFORM_MSG_BYTES = 64


def reform_time(link: LinkParams, n_ranks: int, packet_size: int) -> float:
    """Control-plane latency of re-forming the runtime after a rank loss.

    :data:`REFORM_ROUNDS` rounds (detect/agree, segment re-register,
    conduit re-form barrier), each a ring of :data:`REFORM_MSG_BYTES`
    short AMs across the ``n_ranks`` survivors — latency-bound, so the
    per-message overhead term dominates and the link *class* (QSFP vs
    ICI) sets the constant.  This is what ``ElasticRuntime.on_failure``
    spends *before* any state moves.
    """
    short = put_time(link, REFORM_MSG_BYTES, packet_size)
    return REFORM_ROUNDS * max(1, int(n_ranks) - 1) * short


def reprefill_time(
    link: LinkParams,
    t_compute_per_tok: float,
    tokens: int,
    kv_bytes_per_tok: float,
    n_chunks: int,
    packet_size: int,
) -> float:
    """Cost of re-establishing the KV state a dead rank took with it.

    The drained requests replay ``tokens`` positions through the chunked
    prefill path (:func:`serve_prefill_time` — compute rides over the
    block PUTs); prefix-cache hits on surviving ranks shrink ``tokens``
    before this is called (the caller passes only the *lost tail*).
    """
    toks = max(0, int(tokens))
    if toks == 0:
        return 0.0
    return serve_prefill_time(link, t_compute_per_tok * toks,
                              kv_bytes_per_tok * toks, n_chunks,
                              packet_size)


def serve_recovery_time(
    link: LinkParams,
    *,
    n_ranks: int,
    t_compute_per_tok: float,
    reprefill_tokens: int,
    kv_bytes_per_tok: float,
    n_chunks: int,
    packet_size: int,
) -> float:
    """End-to-end serving recovery wall: re-form + re-prefill.

    The drain itself is host-side bookkeeping (block releases, queue
    surgery) — negligible against the wire terms; what a decode-rank loss
    costs is the membership re-formation plus replaying the lost KV
    (``stats()['reprefilled_tokens']`` is the measured analogue).
    """
    return (reform_time(link, n_ranks, packet_size)
            + reprefill_time(link, t_compute_per_tok, reprefill_tokens,
                             kv_bytes_per_tok, n_chunks, packet_size))


def train_recovery_time(
    link: LinkParams,
    *,
    n_ranks: int,
    ckpt_bytes: float,
    ckpt_interval_steps: int,
    step_time: float,
    packet_size: int,
) -> float:
    """Expected training recovery wall after a rank loss.

    Three terms: membership re-formation (:func:`reform_time`); streaming
    the checkpoint back resharded onto the survivors (one bulk
    ``ckpt_bytes`` transfer — restore-after-remesh moves every shard);
    and replaying the steps since the last checkpoint — on average half
    the interval (failures land uniformly within it).  This is the
    ``interval × link class`` trade ``benchmarks/elastic_bench.py``
    sweeps: short intervals pay checkpoint writes steadily, long ones pay
    replay on failure.
    """
    restore = put_time(link, max(1, int(ckpt_bytes)), packet_size)
    replay = 0.5 * max(0, int(ckpt_interval_steps)) * step_time
    return reform_time(link, n_ranks, packet_size) + restore + replay


# ---------------------------------------------------------------------------
# Live membership costs (runtime/membership.py)
# ---------------------------------------------------------------------------

#: one heartbeat message: AM header + (rank, lease, epoch) words
HEARTBEAT_MSG_BYTES = 16


def detection_latency(lease_period_s: float, k_misses: int) -> float:
    """Worst-case heartbeat detection wall (seconds).

    A victim dying just *after* a publish stays fresh through that
    deadline, then accrues ``k_misses`` consecutive missed deadlines —
    ``k_misses`` periods plus up to one period of phase slack:
    strictly bounded by ``lease_period_s × (k_misses + 1)``, the bound
    ``tools/bench_gate.py`` holds on every detection row.
    """
    return float(lease_period_s) * (int(k_misses) + 1)


def heartbeat_misses(lease_period_s: float, delay_s: float) -> int:
    """Consecutive deadlines a delivery-jitter onset of ``delay_s`` costs.

    Steady jitter shifts the whole arrival lattice and misses nothing
    (arrivals stay one per period); the damage is at *onset*, where the
    gap between the last prompt arrival and the first delayed one spans
    ``ceil(delay_s / lease_period_s)`` deadlines.  Matches the
    step-quantized detector exactly.
    """
    if lease_period_s <= 0:
        raise ValueError(f"lease_period_s must be > 0, got {lease_period_s}")
    if delay_s <= 0:
        return 0
    return int(math.ceil(delay_s / lease_period_s - 1e-9))


def false_positive(lease_period_s: float, k_misses: int,
                   delay_s: float) -> bool:
    """Whether jitter ``delay_s`` alone trips a K-miss declaration.

    True iff :func:`heartbeat_misses` reaches ``k_misses`` — so any
    jitter below ``(k_misses − 1) × lease_period_s`` can never kill a
    live rank.  This is the lease-period/K design tradeoff: shorter
    periods detect faster but tolerate less jitter.
    """
    return heartbeat_misses(lease_period_s, delay_s) >= int(k_misses)


def false_positive_rate(lease_period_s: float, k_misses: int,
                        delays_s) -> float:
    """Fraction of a jitter sweep that would false-positive.

    ``delays_s`` is the scripted ``delay_am`` sweep; the bench gate holds
    this at exactly 0 for the shipped detector operating points.
    """
    ds = list(delays_s)
    if not ds:
        return 0.0
    hits = sum(1 for d in ds
               if false_positive(lease_period_s, k_misses, d))
    return hits / len(ds)


def lease_overhead(link: LinkParams, n_ranks: int, lease_period_s: float,
                   packet_size: int) -> float:
    """Fraction of wall time the heartbeat wire consumes per rank.

    Each period every rank PUTs its lease to the ``n_ranks − 1`` peers
    (:data:`HEARTBEAT_MSG_BYTES` short AMs).  Latency-bound like
    :func:`reform_time`; the returned fraction is what the lease-period
    knob trades against :func:`detection_latency`.
    """
    if lease_period_s <= 0:
        raise ValueError(f"lease_period_s must be > 0, got {lease_period_s}")
    per_period = (max(1, int(n_ranks) - 1)
                  * put_time(link, HEARTBEAT_MSG_BYTES, packet_size))
    return per_period / lease_period_s


def join_admit_time(link: LinkParams, *, n_ranks: int,
                    lease_period_s: float, packet_size: int) -> float:
    """Wall from a JOIN announcement to membership admission.

    Announce (one ring of :data:`HEARTBEAT_MSG_BYTES` short AMs to the
    current members), wait out up to one lease period for the epoch
    boundary (joins are only admitted at deadlines, riding the same view
    change as any batched deaths), then re-form conduits over the grown
    membership.
    """
    announce = (max(1, int(n_ranks) - 1)
                * put_time(link, HEARTBEAT_MSG_BYTES, packet_size))
    return (announce + float(lease_period_s)
            + reform_time(link, int(n_ranks) + 1, packet_size))


def scaleout_mttr(link: LinkParams, *, n_ranks: int, state_bytes: float,
                  lease_period_s: float, packet_size: int) -> float:
    """Join-recovery MTTR: admission plus resharding state back out.

    After admission the joiner must receive its data-parallel shard of
    the training state — ``state_bytes / (n_ranks + 1)`` streamed over
    the link (the scale-out analogue of the restore term in
    :func:`train_recovery_time`; no replay term, because survivors never
    lost their state).
    """
    shard = max(1, int(state_bytes // (int(n_ranks) + 1)))
    return (join_admit_time(link, n_ranks=n_ranks,
                            lease_period_s=lease_period_s,
                            packet_size=packet_size)
            + put_time(link, shard, packet_size))
