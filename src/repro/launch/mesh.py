"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax call, and smoke tests must keep seeing 1 device.

Axis semantics (DESIGN §6):
  "pod"   — crosses data-center network (DCN); only the DP gradient
            all-reduce runs here, once per step (optionally 8-bit
            compressed, optim/compress.py)
  "data"  — DP/FSDP within a pod (ICI)
  "model" — tensor/sequence/expert parallelism within a pod (ICI)
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 2, expert: int = 1):
    """Small CPU mesh for tests/examples (requires the host-device flag).

    ``expert`` > 1 appends an ``expert`` axis (EP dispatch —
    ``models/moe_ep.py``); dense archs treat it as one more data axis.
    """
    n = data * model * expert
    avail = len(jax.devices())
    assert avail >= n, (
        f"need {n} devices, have {avail}; set "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    auto = jax.sharding.AxisType.Auto
    if expert > 1:
        return jax.make_mesh((data, model, expert),
                             ("data", "model", "expert"),
                             axis_types=(auto, auto, auto))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(auto, auto))
