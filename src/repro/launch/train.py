"""Training launcher: ``python -m repro.launch.train --arch smollm-360m``.

On this CPU container it drives *reduced* configs end-to-end (the full
configs are exercised by the dry-run); on a real pod the same launcher
binds the production mesh and full config.  All fault-tolerance features
(checkpoint/restart, preemption, straggler watchdog) are live either way.
"""

from __future__ import annotations

import argparse
import os


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--global-batch", type=int, default=16)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--data-axis", type=int, default=2)
    p.add_argument("--model-axis", type=int, default=2)
    p.add_argument("--expert-axis", type=int, default=1,
                   help="expert mesh axis extent (>1 enables EP dispatch "
                        "for MoE archs when --moe-transport is non-xla)")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--grad-bucket-kb", type=int, default=0,
                   help="accumulate microbatch grads in size-targeted "
                        "buckets of this many KiB (0: pytree accumulation; "
                        "bit-identical update — DESIGN §3)")
    p.add_argument("--moe-transport", default="xla",
                   help="TransportPolicy.moe: xla|ring|bidir|auto "
                        "(non-xla needs an expert mesh axis)")
    p.add_argument("--moe-stream-chunks", type=int, default=0,
                   help="stream the EP dispatch in this many ART chunks "
                        "(0: bulk exchange)")
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-interval", type=int, default=50)
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--full", dest="reduced", action="store_false")
    args = p.parse_args()

    n_dev = args.data_axis * args.model_axis * args.expert_axis
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    from repro.configs import get_config
    from repro.data import DataConfig, SyntheticLM
    from repro.dist.steps import StepConfig, TransportPolicy
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data_axis, args.model_axis,
                          args.expert_axis)
    scfg = StepConfig(
        microbatches=args.microbatches, peak_lr=args.lr,
        warmup_steps=max(args.steps // 20, 5), total_steps=args.steps,
        seq_chunk=min(2048, args.seq_len),
        grad_bucket_bytes=(args.grad_bucket_kb << 10) or None,
        transport=TransportPolicy(
            moe=args.moe_transport,
            moe_stream_chunks=args.moe_stream_chunks or None),
    )
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len + 1,
        global_batch=args.global_batch))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_interval=args.ckpt_interval)
    trainer = Trainer(cfg, scfg, tcfg, data, mesh=mesh)
    trainer.install_signal_handler()
    params, opt, step = trainer.train()
    print(f"[train] finished at step {step}; "
          f"final loss {trainer.history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
