import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements — jax locks the device
count on first init, and the production meshes need 512 host devices.

Per cell:
    with mesh:
        lowered  = jit(step, in_shardings=…, out_shardings=…).lower(*specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

and a JSON report (memory table + roofline terms + collective census) is
written under --out for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax

from repro.analysis.roofline import (
    TPU_V5E, model_flops_for, roofline_from_compiled)
from repro.configs import ARCH_NAMES, SHAPES, cell_applicable, get_config, shape_cell
from repro.dist.steps import (
    build_prefill_step, build_serve_step, build_train_step)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    decode_token_specs, effective_seq, prefill_input_specs, step_config,
    train_input_specs)


def _mesh_desc(mesh) -> str:
    return "x".join(f"{mesh.shape[a]}{a}" for a in mesh.axis_names)


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               variant_overrides: Optional[dict] = None,
               step_overrides: Optional[dict] = None):
    """Returns (lowered, compiled, context dict) for one cell."""
    cfg = get_config(arch)
    cell = shape_cell(shape)
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return None, None, {"skip": reason}
    if variant_overrides:
        cfg = dataclasses.replace(cfg, **variant_overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    scfg = step_config(cfg, cell)
    if step_overrides:
        scfg = dataclasses.replace(scfg, **step_overrides)
    seq = effective_seq(cfg, cell)

    with mesh:
        if cell.kind == "train":
            specs = train_input_specs(cfg, cell)
            bundle = build_train_step(cfg, mesh, scfg, specs)
            args = (bundle.aux["params_shape"], bundle.aux["opt_shape"],
                    specs, jax.ShapeDtypeStruct((), jax.numpy.int32.dtype))
            lowered = bundle.fn.lower(*args)
        elif cell.kind == "prefill":
            in_specs = prefill_input_specs(cfg, cell)
            fe = None
            if len(in_specs) == 2:
                fe = (cfg.frontend_tokens, cfg.frontend_dim)
            bundle = build_prefill_step(cfg, mesh, scfg, cell.global_batch,
                                        in_specs[0].shape[1],
                                        with_frontend=fe)
            lowered = bundle.fn.lower(bundle.aux["params_shape"], *in_specs)
        else:  # decode
            bundle = build_serve_step(cfg, mesh, scfg, cell.global_batch, seq)
            lowered = bundle.fn.lower(bundle.aux["params_shape"],
                                      bundle.aux["cache_shape"],
                                      decode_token_specs(cell))
        compiled = lowered.compile()
    return lowered, compiled, {
        "mesh": mesh, "cfg": cfg, "cell": cell, "scfg": scfg}


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             verbose: bool = True,
             variant: str = "baseline",
             variant_overrides: Optional[dict] = None,
             step_overrides: Optional[dict] = None) -> dict:
    tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"
    if variant != "baseline":
        tag += f"__{variant}"
    t0 = time.time()
    try:
        lowered, compiled, ctx = lower_cell(
            arch, shape, multi_pod=multi_pod,
            variant_overrides=variant_overrides,
            step_overrides=step_overrides)
        if compiled is None:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "pod2" if multi_pod else "pod1",
                   "variant": variant,
                   "status": "skip", "reason": ctx["skip"]}
        else:
            mesh, cfg, cell = ctx["mesh"], ctx["cfg"], ctx["cell"]
            chips = mesh.devices.size
            if verbose:
                print(compiled.memory_analysis())
                print(compiled.cost_analysis())
            seq_eff = effective_seq(cfg, cell)
            n_tok = (cell.global_batch if cell.kind == "decode"
                     else cell.global_batch * seq_eff)
            rep = roofline_from_compiled(
                compiled, arch=arch, shape=shape,
                mesh_desc=_mesh_desc(mesh), chips=chips,
                model_flops=model_flops_for(cfg, cell, n_tokens=n_tok))
            rec = rep.to_dict()
            rec.update(status="ok", variant=variant,
                       compile_s=round(time.time() - t0, 1),
                       hbm_limit=TPU_V5E.hbm_bytes)
    except Exception as e:
        rec = {"arch": arch, "shape": shape,
               "mesh": "pod2" if multi_pod else "pod1",
               "variant": variant, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = (f" dominant={rec.get('dominant')} compile={rec.get('compile_s')}s"
             if status == "ok" else
             f" {rec.get('reason', rec.get('error', ''))[:120]}")
    print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    return rec


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES)
    p.add_argument("--shape", choices=[s.name for s in SHAPES])
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args()

    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_bad = 0
    for arch, shape in cells:
        for mp in pods:
            rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                           verbose=not args.quiet)
            if rec["status"] == "error":
                n_bad += 1
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
