"""Per-(arch × shape) input specs + step configs for the dry-run.

``input_specs(cfg, cell)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, zero allocation.  ``[vlm]`` and
``[audio]`` archs get precomputed patch/frame embeddings per the task spec
(the frontend is a stub).

Adaptations (recorded in EXPERIMENTS §Dry-run notes):
  * whisper-tiny sequence dims clamp to its decoder capacity (4096 learned
    positions; official 448) — a 32k decoder context does not exist for
    this architecture.
  * vlm text length = seq_len − frontend_tokens so the total backbone
    sequence equals the cell's seq_len exactly.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.steps import StepConfig


WHISPER_MAX_SEQ = 4096   # learned decoder position table


def effective_seq(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cfg.family == "encdec":
        return min(cell.seq_len, WHISPER_MAX_SEQ)
    return cell.seq_len


def text_len(cfg: ModelConfig, cell: ShapeCell) -> int:
    s = effective_seq(cfg, cell)
    if cfg.family == "vlm" and cell.kind in ("train", "prefill"):
        return s - cfg.frontend_tokens
    return s


def frontend_spec(cfg: ModelConfig, batch: int):
    if not cfg.frontend:
        return None
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict:
    s = text_len(cfg, cell)
    b = cell.global_batch
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    fe = frontend_spec(cfg, b)
    if fe is not None:
        specs["frontend_embeds"] = fe
    return specs


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> Tuple:
    s = text_len(cfg, cell)
    b = cell.global_batch
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    fe = frontend_spec(cfg, b)
    return (toks,) if fe is None else (toks, fe)


def decode_token_specs(cell: ShapeCell):
    return jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)


# ---------------------------------------------------------------------------
# per-arch step presets (numerics + microbatching chosen to fit 16 GB HBM;
# the resulting per-device bytes are *reported* by the dry-run)
# ---------------------------------------------------------------------------


def step_config(cfg: ModelConfig, cell: ShapeCell) -> StepConfig:
    n = cfg.n_params()
    big = n >= 100e9          # grok-1, nemotron, llama4-scout
    if cell.kind == "train":
        if big:
            micro = 16
        elif n >= 2e9:
            micro = 4
        else:
            micro = 2
        # keep per-microbatch row count >= 1
        micro = min(micro, cell.global_batch)
        return StepConfig(
            microbatches=micro,
            seq_chunk=min(2048, cell.seq_len),
            moment_dtype="bfloat16" if big else "float32",
            master_fp32=not big,
            sequence_parallel=True,
        )
    return StepConfig(sequence_parallel=False)
