"""Serving launcher: ``python -m repro.launch.serve --arch smollm-360m``.

Continuous batching with chunked streamed prefill over a CPU mesh with
reduced configs; the production path is identical modulo mesh + config
size (dry-run covers the full-scale lowering).  ``--prefill-chunk 0``
falls back to bulk per-slot admission (the head-of-line-blocking
baseline the chunked scheduler exists to kill); ``--expert-axis`` +
``--moe-transport`` route MoE decode through the expert-parallel conduit
dispatch (``docs/serving.md``).
"""

from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--data-axis", type=int, default=2)
    p.add_argument("--model-axis", type=int, default=2)
    p.add_argument("--expert-axis", type=int, default=1,
                   help="EP decode: expert mesh-axis extent (MoE archs)")
    p.add_argument("--moe-transport", default="xla",
                   help="TransportPolicy.moe for EP decode "
                        "(xla|ring|bidir|auto)")
    p.add_argument("--prefill-chunk", type=int, default=8,
                   help="tokens per admitted prefill chunk (0: bulk "
                        "per-slot admission)")
    p.add_argument("--arrive-every", type=int, default=0,
                   help="synthetic arrivals: submit one request every N "
                        "scheduler steps (0: all upfront)")
    p.add_argument("--paged", action="store_true",
                   help="paged KV block pool + prefix cache (token-"
                        "identical to the contiguous cache)")
    p.add_argument("--block-size", type=int, default=16,
                   help="KV positions per pool block (--paged only); must "
                        "divide the ring extent and, for prefix caching, "
                        "be a multiple of --prefill-chunk")
    p.add_argument("--dump-tokens", default=None, metavar="PATH",
                   help="write {rid: out_tokens} JSON (CI diffs paged vs "
                        "contiguous runs)")
    p.add_argument("--fail-at-step", type=int, default=None, metavar="N",
                   help="fault injection: kill a decode rank at scheduler "
                        "step N (requires --paged; the server drains and "
                        "re-admits — tokens stay identical to an unfailed "
                        "run)")
    p.add_argument("--fail-rank", type=int, default=1, metavar="R",
                   help="which decode rank dies at --fail-at-step "
                        "(pool-partition index over the data axis)")
    p.add_argument("--chaos-seed", type=int, default=None, metavar="N",
                   help="live-detector churn: a fixed-seed plan (two "
                        "decode ranks lose their lease in one window, an "
                        "AM-delay burst jitters heartbeats, one victim "
                        "later rejoins) delivered through the membership "
                        "detector — NOT scripted raises (requires "
                        "--paged; tokens stay identical to an unfailed "
                        "run)")
    args = p.parse_args()

    n_dev = args.data_axis * args.model_axis * args.expert_axis
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    import jax
    from repro.configs import get_config
    from repro.dist.sharding import param_pspecs, to_shardings
    from repro.dist.steps import StepConfig, TransportPolicy
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import init_params
    from repro.runtime.server import Server, ServerConfig, drive_arrivals

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh(args.data_axis, args.model_axis, args.expert_axis)
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, params_shape))
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(0))

    scfg = StepConfig(transport=TransportPolicy(moe=args.moe_transport))
    plan = None
    membership = None
    if args.fail_at_step is not None:
        assert args.paged, "--fail-at-step needs --paged (the pool " \
            "partition is what a decode rank owns)"
        from repro.runtime.faults import FaultPlan
        plan = FaultPlan.from_cli(args.fail_at_step, args.fail_rank)
    if args.chaos_seed is not None:
        assert args.paged, "--chaos-seed needs --paged (the pool " \
            "partition is what a decode rank owns)"
        assert plan is None, "--chaos-seed and --fail-at-step are " \
            "mutually exclusive chaos drivers"
        from repro.runtime.faults import FaultPlan
        from repro.runtime.membership import LeaseConfig, MembershipService
        crng = np.random.default_rng(args.chaos_seed)
        n_pool = 4                       # logical decode-pool ranks
        kill_at = int(crng.integers(4, 9))
        victims = sorted(crng.choice(np.arange(1, n_pool), size=2,
                                     replace=False).tolist())
        lease = LeaseConfig(lease_period=1, k_misses=3, step_time_s=1e-3)
        # the delay burst (2 lease periods of jitter) stays under K=3
        # misses — the detector must NOT declare anyone for it
        plan = (FaultPlan(deliver="lease")
                .delay_am(2 * lease.step_time_s, at_step=2)
                .kill_rank(victims[0], at_step=kill_at)
                .kill_rank(victims[1], at_step=kill_at))
        membership = MembershipService(n_pool, lease, fault_plan=plan)
        membership.schedule_join(victims[0], at_step=kill_at + 10)
    srv = Server(cfg, params, mesh, scfg=scfg, srv=ServerConfig(
        max_batch=args.max_batch, max_seq=256, max_new_tokens=args.max_new,
        prefill_chunk=args.prefill_chunk or None,
        paged=args.paged, block_size=args.block_size), fault_plan=plan,
        membership=membership)
    rng = np.random.default_rng(0)
    plen = args.prompt_len
    if cfg.family == "encdec":
        plen = min(plen, cfg.decoder_max_seq)
    prompts = [rng.integers(0, cfg.vocab_size, size=plen)
               for _ in range(args.requests)]
    if cfg.frontend:
        # multimodal archs: synthetic per-request frontend embeds (the
        # vision/audio tower output the server carries through admission)
        prompts = [
            (pr, rng.standard_normal(
                (cfg.frontend_tokens, cfg.frontend_dim), dtype=np.float32))
            for pr in prompts]

    if args.arrive_every:
        steps = drive_arrivals(srv, prompts, args.arrive_every)
    else:
        for pr in prompts:
            srv.submit(*pr) if isinstance(pr, tuple) else srv.submit(pr)
        steps = srv.run()
    if membership is not None:
        # idle-tick until the scheduled rejoin lands (requests may all
        # finish first; the detector keeps running on the step clock)
        extra = 0
        while not any(ev.joined for ev in membership.events) and extra < 200:
            srv.step()
            extra += 1
        steps += extra

    stats = srv.stats()
    mode = str(stats["admission_mode"])
    if args.paged:
        mode += f"+paged(blk{args.block_size})"
    print(f"[serve:{mode}] {stats['requests']} requests, "
          f"{stats['tokens']} tokens in {steps} steps; "
          f"{stats['throughput_tok_s']:.1f} tok/s, "
          f"mean latency {stats['mean_latency_s']*1e3:.1f} ms, "
          f"ttft {stats['mean_ttft_s']*1e3:.1f} ms, "
          f"itl {stats['mean_itl_s']*1e3:.2f} ms")
    if args.paged:
        print(f"[serve:{mode}] prefix hits {stats['prefix_hits']:.0f} / "
              f"misses {stats['prefix_misses']:.0f}, "
              f"pool evictions {stats['pool_evictions']:.0f}, "
              f"free blocks {stats['pool_free_blocks']:.0f}")
    if membership is not None:
        srv.pool.check_conservation()
        deaths = [ev for ev in membership.events if ev.died]
        joins = [ev for ev in membership.events if ev.joined]
        assert len(deaths) == 1 and deaths[0].died == tuple(victims), \
            (deaths, victims)           # double loss = exactly one bump
        assert len(joins) == 1, joins
        print(f"[serve:{mode}] chaos seed {args.chaos_seed}: leases of "
              f"ranks {victims} suppressed at step {kill_at}, detector "
              f"declared both at step {deaths[0].step} (one epoch bump), "
              f"rank {victims[0]} rejoined at step {joins[0].step}; "
              f"epoch {membership.epoch}, "
              f"{stats['recoveries']:.0f} slots drained/re-admitted, "
              f"{stats['reprefilled_tokens']:.0f} tokens re-prefilled, "
              f"{stats['quarantined_blocks']:.0f} blocks quarantined "
              f"(conservation holds)")
    elif plan is not None:
        srv.pool.check_conservation()
        print(f"[serve:{mode}] fault injected at step {args.fail_at_step} "
              f"(rank {args.fail_rank}): {stats['recoveries']:.0f} slots "
              f"drained/re-admitted, "
              f"{stats['reprefilled_tokens']:.0f} tokens re-prefilled, "
              f"{stats['lost_blocks']:.0f} blocks lost "
              f"(conservation holds)")
    if args.dump_tokens:
        import json
        with open(args.dump_tokens, "w") as f:
            json.dump({str(r.rid): r.out_tokens for r in srv.done}, f,
                      sort_keys=True)


if __name__ == "__main__":
    main()
