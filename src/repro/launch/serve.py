"""Serving launcher: ``python -m repro.launch.serve --arch smollm-360m``.

Continuous-batching decode over a CPU mesh with reduced configs; the
production path is identical modulo mesh + config size (dry-run covers the
full-scale lowering).
"""

from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--data-axis", type=int, default=2)
    p.add_argument("--model-axis", type=int, default=2)
    args = p.parse_args()

    n_dev = args.data_axis * args.model_axis
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={n_dev}")

    import jax
    from repro.configs import get_config
    from repro.dist.sharding import param_pspecs, to_shardings
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import init_params
    from repro.runtime.server import Server, ServerConfig

    cfg = get_config(args.arch).reduced()
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    psh = to_shardings(mesh, param_pspecs(cfg, mesh, params_shape))
    params = jax.jit(lambda k: init_params(cfg, k), out_shardings=psh)(
        jax.random.PRNGKey(0))

    srv = Server(cfg, params, mesh, srv=ServerConfig(
        max_batch=args.max_batch, max_seq=256, max_new_tokens=args.max_new))
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        srv.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len))
    steps = srv.run()
    stats = srv.stats()
    print(f"[serve] {stats['requests']} requests, {stats['tokens']} tokens "
          f"in {steps} steps; {stats['throughput_tok_s']:.1f} tok/s, "
          f"mean latency {stats['mean_latency_s']*1e3:.1f} ms, "
          f"ttft {stats['mean_ttft_s']*1e3:.1f} ms")


if __name__ == "__main__":
    main()
