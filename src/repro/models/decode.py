"""Serving path: per-family caches + single-token decode step.

``decode_*`` shapes in the dry-run lower exactly this ``decode_step`` (one
new token against a populated cache), never ``train_step``.

Cache design notes (these drive the decode-shape roofline memory term):

* Positions are **per slot**: ``cache["pos"]`` is ``(B,)`` and
  ``slot_pos`` is ``(B, S_buf)``, so every batch row of the cache advances
  independently — the continuous-batching server admits a freshly
  prefilled request into one row while the other rows keep decoding at
  their own positions (``runtime/server.py``).
* GQA: ring-buffer K/V — ``S_buf = min(max_seq, window)``; for h2o-danube's
  4096-token sliding window the long_500k cache is 4096 slots, not 500k
  (the reason the arch runs that shape at all).  A per-row ``slot_pos``
  array maps buffer slots to absolute positions; masking validates
  ``pos - window < slot_pos <= pos``.
* MLA (minicpm3): caches the 256-d latent + 32-d shared rope key instead of
  per-head K/V, and uses the *absorbed* formulation (W_uk folded into the
  query, W_uv into the output) so per-token work is O(S_buf · r).
* SSD: O(1) state — (H, N, P) fp32 per layer + a (conv−1)-deep conv ring.
* hybrid: SSM states for all 81 layers + one K/V cache per *application*
  of the shared attention block (weights are shared; caches are not).
* encdec: decoder self-attention ring + precomputed cross K/V per layer.

**Paged KV block pool** (PR 6): :func:`init_paged_cache` replaces the
per-row contiguous ring with fixed-size blocks drawn from one shared pool
(``kp``/``vp``: (L, N_blocks, Hkv, blk, hd)) plus a per-slot block table
(``block_ids`` (B, S_buf/blk)).  ``block_size`` must divide
``kv_buf_len`` so ring slot ``j`` lives in block ``j // blk`` at offset
``j % blk`` — the block-table gather then reconstructs *exactly* the
contiguous layout, the attention math is byte-for-byte the contiguous
recipe, and only the new row is scattered back — which is what makes
paged decode bit-identical to the contiguous path (asserted by
tests/test_serving.py across block sizes, ring wraparound, and
shared-prefix aliasing).  Blocks ``[0, batch)`` are per-row *parking*
blocks: rows whose slot is idle keep writing into their own parking
block, so a retired row can never clobber a block the allocator
(``runtime/server.BlockPool``) has handed to someone else.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.model import _lm_logits

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _cd(cfg):
    return jnp.dtype(cfg.compute_dtype)


#: decoder self-attention ring cap for encdec archs (whisper-style)
ENCDEC_DECODER_CAP = 4096


def kv_buf_len(cfg: ModelConfig, max_seq: int) -> int:
    """Ring-buffer extent of the K/V cache for ``max_seq`` positions.

    The one owner of the sizing rule — ``init_cache``, both prefill paths
    (``models/prefill.py``), and the step builders all call it: the SWA
    window caps the buffer (h2o-danube keeps 4096 slots at 500k context),
    and encdec decoders cap at :data:`ENCDEC_DECODER_CAP`.
    """
    if cfg.family == "encdec":
        return min(max_seq, ENCDEC_DECODER_CAP)
    return min(max_seq, cfg.window) if cfg.window else max_seq


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_out: Optional[jnp.ndarray] = None,
               params: Optional[Params] = None) -> Cache:
    dt = jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    sb = kv_buf_len(cfg, max_seq)
    cache: Cache = {"pos": jnp.zeros((batch,), jnp.int32)}

    if cfg.family in ("dense", "vlm", "moe") and cfg.attn_type != "mla":
        cache["k"] = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, sb, hd), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, sb, hd), dt)
        cache["slot_pos"] = jnp.full((batch, sb), -1, jnp.int32)
    elif cfg.attn_type == "mla":
        cache["ckv"] = jnp.zeros((cfg.n_layers, batch, sb, cfg.kv_lora_rank), dt)
        cache["krope"] = jnp.zeros((cfg.n_layers, batch, sb, cfg.qk_rope_dim), dt)
        cache["slot_pos"] = jnp.full((batch, sb), -1, jnp.int32)
    elif cfg.family == "ssm":
        cache.update(_ssm_cache(cfg, cfg.n_layers, batch, dt))
    elif cfg.family == "hybrid":
        cache.update(_ssm_cache(cfg, cfg.n_layers, batch, dt))
        n_apps = cfg.n_layers // cfg.hybrid_period
        cache["attn_k"] = jnp.zeros((n_apps, batch, cfg.n_kv_heads, sb, hd), dt)
        cache["attn_v"] = jnp.zeros((n_apps, batch, cfg.n_kv_heads, sb, hd), dt)
        cache["slot_pos"] = jnp.full((batch, sb), -1, jnp.int32)
    elif cfg.family == "encdec":
        sdec = kv_buf_len(cfg, max_seq)
        cache["k"] = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, sdec, hd), dt)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, sdec, hd), dt)
        cache["slot_pos"] = jnp.full((batch, sdec), -1, jnp.int32)
        if enc_out is not None:
            assert params is not None
            def xkv(lp):
                k, v, _ = L.cross_kv(cfg, lp["xattn"], enc_out)
                return k.astype(dt), v.astype(dt)
            ks, vs = jax.vmap(xkv)(params["dec_layers"])
            cache["cross_k"], cache["cross_v"] = ks, vs
        else:
            cache["cross_k"] = jnp.zeros(
                (cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_seq, hd), dt)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def _ssm_cache(cfg: ModelConfig, n_layers: int, batch: int, dt) -> Cache:
    d_in = cfg.ssm_heads * cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm_state": jnp.zeros(
            (n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32),
        "conv_state": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_ch), dt),
    }


def supports_paged(cfg: ModelConfig) -> bool:
    """Whether the arch can decode against the paged KV block pool.

    Requires the GQA ring-buffer cache (dense/vlm/moe non-MLA) — the
    families whose ``k``/``v`` leaves the block table indirects.  MLA
    latents, SSM state and the encdec cross-cache stay contiguous.  The
    rule itself lives in the jax-free capability table
    (``configs.base.serving_features``) so docs and tools can query it.
    """
    from repro.configs.base import serving_features

    return serving_features(cfg)["paged"]


def paged_slot_blocks(cfg: ModelConfig, max_seq: int, block_size: int) -> int:
    """Blocks per slot: ``kv_buf_len / block_size``.

    ``block_size`` must divide the ring extent — that is the invariant
    that keeps ring slot ``j`` at block ``j // blk`` offset ``j % blk``,
    i.e. the gathered view *is* the contiguous layout (bit-identity).
    """
    sb = kv_buf_len(cfg, max_seq)
    if sb % block_size:
        raise ValueError(
            f"block_size {block_size} must divide kv_buf_len {sb}")
    return sb // block_size


def init_paged_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     block_size: int, n_blocks: int) -> Cache:
    """A paged decode cache: shared block pool + per-slot block tables.

    Layout (vs the contiguous ``init_cache``): ``k``/``v``
    (L, B, Hkv, S_buf, hd) become ``kp``/``vp`` (L, n_blocks, Hkv,
    block_size, hd), and ``block_ids`` (B, S_buf/block_size) maps each
    slot's logical block to a pool block.  Blocks ``[0, batch)`` are the
    per-row parking blocks; every row's table starts parked on its own
    (``block_ids[b, :] = b``), so idle rows write garbage only into
    their private parking block.  ``pos``/``slot_pos`` bookkeeping is
    unchanged from the contiguous contract.
    """
    assert supports_paged(cfg), cfg.name
    dt = jnp.dtype(cfg.param_dtype)
    hd = cfg.resolved_head_dim
    sb = kv_buf_len(cfg, max_seq)
    npb = paged_slot_blocks(cfg, max_seq, block_size)
    if n_blocks < batch:
        raise ValueError(
            f"n_blocks {n_blocks} < batch {batch}: every row needs a "
            f"parking block")
    shape = (cfg.n_layers, n_blocks, cfg.n_kv_heads, block_size, hd)
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "slot_pos": jnp.full((batch, sb), -1, jnp.int32),
        "kp": jnp.zeros(shape, dt),
        "vp": jnp.zeros(shape, dt),
        "block_ids": jnp.broadcast_to(
            jnp.arange(batch, dtype=jnp.int32)[:, None], (batch, npb)),
    }


def gather_blocks(pool: jnp.ndarray, block_ids: jnp.ndarray) -> jnp.ndarray:
    """Block-table gather: pool (N, Hkv, blk, hd) + table (B, npb) →
    the contiguous-layout view (B, Hkv, npb·blk, hd).  A pure gather —
    the bits are exactly the contiguous cache's, so everything computed
    from the view is bit-identical to the contiguous path."""
    g = jnp.take(pool, block_ids, axis=0)          # (B, npb, Hkv, blk, hd)
    b, npb, hkv, blk, hd = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, npb * blk, hd)


def scatter_block_rows(pool: jnp.ndarray, block_ids: jnp.ndarray,
                       new: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Write each row's new K/V vector (B, Hkv, hd) into its pool block.

    Ring slot ``slot[b]`` lives in block ``block_ids[b, slot // blk]`` at
    offset ``slot % blk`` — the one-row scatter that replaces the
    contiguous path's ``_row_update``.  The allocator guarantees distinct
    rows never share a *tail* block (shared prefix blocks are read-only
    by the admission rule), so the scatter has no write aliasing."""
    blk = pool.shape[2]
    bid = jnp.take_along_axis(block_ids, (slot // blk)[:, None], axis=1)[:, 0]
    return pool.at[bid, :, slot % blk, :].set(new.astype(pool.dtype))


def cache_bytes(cfg: ModelConfig, batch: int, max_seq: int) -> int:
    """Analytic cache footprint (roofline memory term for decode shapes)."""
    c = init_cache(cfg, 1, 8)  # layout probe, tiny
    del c
    leaves = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(leaves))


# ---------------------------------------------------------------------------
# per-layer decode primitives
# ---------------------------------------------------------------------------


def _valid_slots(slot_pos, pos, window):
    """Per-row key validity: ``slot_pos`` (B, S_buf) against ``pos`` (B,)."""
    valid = slot_pos >= 0
    valid &= slot_pos <= pos[:, None]
    if window is not None:
        valid &= slot_pos > (pos - window)[:, None]
    return valid


def _row_update(buf, new, slot):
    """Write ``new`` (B, ..., 1, d) into ``buf`` (B, ..., S_buf, d) at the
    per-row ring slot ``slot`` (B,) — the vmapped dynamic-update the shared
    scalar position used to do in one call."""
    def one(b, n, s):
        start = (0,) * (b.ndim - 2) + (s, 0)
        return lax.dynamic_update_slice(b, n, start)

    return jax.vmap(one)(buf, new.astype(buf.dtype), slot)


def _masked_softmax_attend(scores, vcache, slot_pos, pos, window):
    """scores: (B, Hkv, G, S_buf) fp32; vcache: (B, Hkv, S_buf, hd);
    ``slot_pos`` (B, S_buf) / ``pos`` (B,) are per batch row."""
    valid = _valid_slots(slot_pos, pos, window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    m = scores.max(-1, keepdims=True)
    p = jnp.where(scores <= -1e29, 0.0, jnp.exp(scores - m))
    denom = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    p = p / denom
    return jnp.einsum("bkgs,bksd->bkgd", p, vcache.astype(jnp.float32))


def attention_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     kc: jnp.ndarray, vc: jnp.ndarray,
                     slot_pos_new: jnp.ndarray, pos: jnp.ndarray,
                     rope: bool = True, window: Optional[int] = None):
    """x: (B, D) single token; ``pos`` (B,) per-row.  Returns
    (out (B, D), kc, vc)."""
    b, _ = x.shape
    hd = cfg.resolved_head_dim
    hkv, hq = cfg.n_kv_heads, cfg.n_heads
    g = hq // hkv
    sb = kc.shape[2]
    cd = _cd(cfg)
    xc = x.astype(cd)

    q = (xc @ p["wq"].astype(cd)).reshape(b, hq, hd)
    k = (xc @ p["wk"].astype(cd)).reshape(b, hkv, hd)
    v = (xc @ p["wv"].astype(cd)).reshape(b, hkv, hd)
    if rope:
        posv = pos[:, None, None]
        q = L.apply_rope(q[:, :, None, :], posv, cfg.rope_theta)[:, :, 0]
        k = L.apply_rope(k[:, :, None, :], posv, cfg.rope_theta)[:, :, 0]

    slot = pos % sb
    kc = _row_update(kc, k[:, :, None, :], slot)
    vc = _row_update(vc, v[:, :, None, :], slot)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32) * hd ** -0.5
    scores = jnp.einsum("bkgd,bksd->bkgs", qg, kc.astype(jnp.float32))
    out = _masked_softmax_attend(scores, vc, slot_pos_new, pos, window)
    out = out.reshape(b, hq * hd).astype(cd)
    return (out @ p["wo"].astype(cd)).astype(x.dtype), kc, vc


def cross_attention_decode(cfg, p, x, kc, vc, n_valid: int):
    """Cross-attention against static (precomputed) encoder K/V."""
    b, _ = x.shape
    hd = cfg.resolved_head_dim
    hkv, hq = cfg.n_kv_heads, cfg.n_heads
    g = hq // hkv
    cd = _cd(cfg)
    q = (x.astype(cd) @ p["wq"].astype(cd)).reshape(b, hkv, g, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32) * hd ** -0.5,
                        kc.astype(jnp.float32))
    m = scores.max(-1, keepdims=True)
    pr = jnp.exp(scores - m)
    pr = pr / jnp.maximum(pr.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bksd->bkgd", pr, vc.astype(jnp.float32))
    out = out.reshape(b, hq * hd).astype(cd)
    return (out @ p["wo"].astype(cd)).astype(x.dtype)


def mla_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               ckv: jnp.ndarray, krope: jnp.ndarray,
               slot_pos_new: jnp.ndarray, pos: jnp.ndarray):
    """Absorbed MLA decode.  x: (B, D); ckv: (B, S_buf, r);
    krope: (B, S_buf, dr); ``pos`` (B,) per-row."""
    b, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    sb = ckv.shape[1]
    cd = _cd(cfg)
    xc = x.astype(cd)

    q_lat = L.rms_norm(p["q_norm"], xc @ p["w_dq"].astype(cd), cfg.norm_eps)
    q = (q_lat @ p["w_uq"].astype(cd)).reshape(b, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope[:, :, None, :], pos[:, None, None],
                          cfg.rope_theta)[:, :, 0]
    w_uk = p["w_uk"].astype(cd).reshape(r, h, dn)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope, w_uk)      # absorb W_uk

    dkv = xc @ p["w_dkv"].astype(cd)
    c_new = L.rms_norm(p["kv_norm"], dkv[:, :r], cfg.norm_eps)
    kr_new = L.apply_rope(dkv[:, None, None, r:], pos[:, None, None],
                          cfg.rope_theta)[:, 0, 0]
    slot = pos % sb
    ckv = _row_update(ckv, c_new[:, None, :], slot)
    krope = _row_update(krope, kr_new[:, None, :], slot)
    scale = (dn + dr) ** -0.5
    scores = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32))) * scale
    valid = _valid_slots(slot_pos_new, pos, None)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    m = scores.max(-1, keepdims=True)
    pr = jnp.where(scores <= -1e29, 0.0, jnp.exp(scores - m))
    pr = pr / jnp.maximum(pr.sum(-1, keepdims=True), 1e-30)
    out_lat = jnp.einsum("bhs,bsr->bhr", pr, ckv.astype(jnp.float32))
    w_uv = p["w_uv"].astype(cd).reshape(r, h, dv)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(cd), w_uv)  # absorb W_uv
    out = out.reshape(b, h * dv)
    return (out @ p["wo"].astype(cd)).astype(x.dtype), ckv, krope


def mamba2_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  ssm_state: jnp.ndarray, conv_state: jnp.ndarray):
    """Single-token Mamba-2 step.  x: (B, D); ssm_state: (B, H, N, P) fp32;
    conv_state: (B, conv-1, conv_ch)."""
    from repro.kernels.ssd.ref import ssd_decode_step

    b, _ = x.shape
    h, pdim, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    d_in = h * pdim
    cd = _cd(cfg)
    xc = x.astype(cd)

    zxbcdt = xc @ p["in_proj"].astype(cd)
    z = zxbcdt[:, :d_in]
    xbc_new = zxbcdt[:, d_in: 2 * d_in + 2 * g * n]
    dt_raw = zxbcdt[:, 2 * d_in + 2 * g * n:]

    # conv ring: full window = [conv_state ; xbc_new]
    win = jnp.concatenate([conv_state, xbc_new[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(cd), p["conv_w"].astype(cd))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(cd))
    conv_state = win[:, 1:, :]

    xs = conv_out[:, :d_in].reshape(b, h, pdim)
    bmat = conv_out[:, d_in: d_in + g * n].reshape(b, g, n)
    cmat = conv_out[:, d_in + g * n:].reshape(b, g, n)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])

    ssm_state, y = ssd_decode_step(ssm_state, xs, dtv, a, bmat, cmat, p["d_skip"])
    y = y.reshape(b, d_in).astype(cd)
    y = L.rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cd)).astype(x.dtype)
    return out, ssm_state, conv_state


# ---------------------------------------------------------------------------
# family-level decode step
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jnp.ndarray, *,
                moe_runner: Optional[Any] = None) -> Tuple[Cache, jnp.ndarray]:
    """tokens: (B,) int32 — returns (cache', logits (B, V)).

    Every cache row advances at its own ``pos`` (continuous batching).

    ``moe_runner`` (optional) replaces the dense-combine MoE layer with an
    expert-parallel dispatch runner (``models/moe_ep.py`` — the latency-mode
    EP decode: the step's B tokens batched across expert shards through the
    conduit ``all_to_all``).  ``None`` keeps dense-combine, which stays the
    small-batch fallback (weight-bound at decode shapes).
    """
    pos = cache["pos"]
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)  # (B, D)

    if "slot_pos" in cache:
        sb = cache["slot_pos"].shape[1]
        slot_pos_new = cache["slot_pos"].at[jnp.arange(b), pos % sb].set(pos)
    else:
        slot_pos_new = None

    if cfg.family in ("dense", "vlm", "moe") and cfg.attn_type != "mla":
        def ffn(normed2, lp):
            if cfg.family == "moe":
                if moe_runner is not None:
                    return moe_runner(cfg, lp["moe"], normed2[:, None, :])[:, 0]
                return L.moe(cfg, lp["moe"], normed2[:, None, :],
                             dense_combine=True)[:, 0]
            return L.mlp(cfg, lp["mlp"], normed2)

        if "kp" in cache:
            # paged: gather the block-table view, run the *identical*
            # contiguous attention, scatter only the new row back
            bids = cache["block_ids"]
            sb = cache["slot_pos"].shape[1]
            slot = pos % sb

            def body(h, layer):
                lp, kp, vp = layer
                kc = gather_blocks(kp, bids)
                vc = gather_blocks(vp, bids)
                normed = L.apply_norm(cfg, lp["ln1"], h)
                a, kc, vc = attention_decode(
                    cfg, lp["attn"], normed, kc, vc, slot_pos_new, pos,
                    window=cfg.window)
                h = h + a
                f = ffn(L.apply_norm(cfg, lp["ln2"], h), lp)
                rows = jnp.arange(b)
                kp = scatter_block_rows(kp, bids, kc[rows, :, slot, :], slot)
                vp = scatter_block_rows(vp, bids, vc[rows, :, slot, :], slot)
                return h + f, (kp, vp)

            x, (kps, vps) = lax.scan(
                body, x, (params["layers"], cache["kp"], cache["vp"]))
            cache = dict(cache, kp=kps, vp=vps, slot_pos=slot_pos_new,
                         pos=pos + 1)
        else:
            def body(h, layer):
                lp, kc, vc = layer
                normed = L.apply_norm(cfg, lp["ln1"], h)
                a, kc, vc = attention_decode(cfg, lp["attn"], normed, kc, vc,
                                             slot_pos_new, pos,
                                             window=cfg.window)
                h = h + a
                f = ffn(L.apply_norm(cfg, lp["ln2"], h), lp)
                return h + f, (kc, vc)

            x, (ks, vs) = lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=ks, v=vs, slot_pos=slot_pos_new,
                         pos=pos + 1)

    elif cfg.attn_type == "mla":
        def body(h, layer):
            lp, ck, kr = layer
            normed = L.apply_norm(cfg, lp["ln1"], h)
            a, ck, kr = mla_decode(cfg, lp["attn"], normed, ck, kr,
                                   slot_pos_new, pos)
            h = h + a
            f = L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
            return h + f, (ck, kr)

        x, (cks, krs) = lax.scan(
            body, x, (params["layers"], cache["ckv"], cache["krope"]))
        cache = dict(cache, ckv=cks, krope=krs, slot_pos=slot_pos_new,
                     pos=pos + 1)

    elif cfg.family == "ssm":
        def body(h, layer):
            lp, st, cv = layer
            normed = L.apply_norm(cfg, lp["ln"], h)
            o, st, cv = mamba2_decode(cfg, lp["mamba"], normed, st, cv)
            return h + o, (st, cv)

        x, (sts, cvs) = lax.scan(
            body, x, (params["layers"], cache["ssm_state"], cache["conv_state"]))
        cache = dict(cache, ssm_state=sts, conv_state=cvs, pos=pos + 1)

    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(cfg, params, cache, x, slot_pos_new, pos)

    elif cfg.family == "encdec":
        def body(h, layer):
            lp, kc, vc, xk, xv = layer
            normed = L.apply_norm(cfg, lp["ln1"], h)
            a, kc, vc = attention_decode(cfg, lp["attn"], normed, kc, vc,
                                         slot_pos_new, pos, rope=False)
            h = h + a
            xa = cross_attention_decode(
                cfg, lp["xattn"], L.apply_norm(cfg, lp["ln_x"], h), xk, xv,
                cfg.encoder_seq)
            h = h + xa
            f = L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
            return h + f, (kc, vc)

        pos_emb = jnp.take(params["dec_pos"],
                           jnp.minimum(pos, params["dec_pos"].shape[0] - 1),
                           axis=0)
        x = x + pos_emb.astype(x.dtype)
        x, (ks, vs) = lax.scan(
            body, x,
            (params["dec_layers"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, k=ks, v=vs, slot_pos=slot_pos_new, pos=pos + 1)
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = _lm_logits(cfg, params, x[:, None, :])[:, 0]
    return cache, logits


def _decode_hybrid(cfg: ModelConfig, params: Params, cache: Cache,
                   x: jnp.ndarray, slot_pos_new, pos):
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    n_shared = max(cfg.n_shared_blocks, 1)

    def regroup(t):
        return jax.tree.map(
            lambda a: a[: n_groups * period].reshape(
                (n_groups, period) + a.shape[1:]), t)

    grouped_lp = regroup(params["layers"])
    grouped_st = regroup(cache["ssm_state"])
    grouped_cv = regroup(cache["conv_state"])
    rest_lp = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    rest_st = cache["ssm_state"][n_groups * period:]
    rest_cv = cache["conv_state"][n_groups * period:]
    shared = params["shared_blocks"]

    def ssm_one(h, layer):
        lp, st, cv = layer
        normed = L.apply_norm(cfg, lp["ln"], h)
        o, st, cv = mamba2_decode(cfg, lp["mamba"], normed, st, cv)
        return h + o, (st, cv)

    def group_body(carry, inp):
        h, g = carry
        glp, gst, gcv, kc, vc = inp
        h, (gst, gcv) = lax.scan(ssm_one, h, (glp, gst, gcv))
        sel = jax.tree.map(lambda a: a[g % n_shared], shared)
        normed = L.apply_norm(cfg, sel["ln1"], h)
        a, kc, vc = attention_decode(cfg, sel["attn"], normed, kc, vc,
                                     slot_pos_new, pos)
        h = h + a
        h = h + L.mlp(cfg, sel["mlp"], L.apply_norm(cfg, sel["ln2"], h))
        return (h, g + 1), (gst, gcv, kc, vc)

    (x, _), (sts, cvs, ks, vs) = lax.scan(
        group_body, (x, jnp.int32(0)),
        (grouped_lp, grouped_st, grouped_cv, cache["attn_k"], cache["attn_v"]))

    new_st = sts.reshape((n_groups * period,) + sts.shape[2:])
    new_cv = cvs.reshape((n_groups * period,) + cvs.shape[2:])
    if n_rem:
        x, (rst, rcv) = lax.scan(ssm_one, x, (rest_lp, rest_st, rest_cv))
        new_st = jnp.concatenate([new_st, rst], axis=0)
        new_cv = jnp.concatenate([new_cv, rcv], axis=0)

    cache = dict(cache, ssm_state=new_st, conv_state=new_cv,
                 attn_k=ks, attn_v=vs, slot_pos=slot_pos_new, pos=pos + 1)
    return x, cache
