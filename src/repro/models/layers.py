"""Composable pure-JAX model layers for every assigned architecture family.

No flax — parameters are plain pytrees (dicts of arrays), every layer is an
``init_*(cfg, key) -> params`` / ``apply(params, x, ...) -> y`` pair, and
layer stacks are ``lax.scan`` over stacked parameter pytrees so compile time
is O(1) in depth (96-layer nemotron compiles as fast as 4-layer whisper).

Attention/SSD have three interchangeable implementations selected by
``cfg.attn_impl``:

* ``pallas`` — the TPU kernels from ``repro.kernels`` (target hardware);
* ``jnp``    — blockwise flash-style scans in pure jnp: same asymptotic
  FLOPs/bytes, bounded memory, compiles on any backend — this is what the
  512-device dry-run lowers so ``cost_analysis`` reflects the real
  algorithm, not an interpreter;
* ``ref``    — the materialized oracle (tests only).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _init(key, shape, dtype, scale: float = 0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def resolve_attn_impl(cfg: ModelConfig) -> str:
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(cfg: ModelConfig, key, dim: Optional[int] = None) -> Params:
    del key
    return {"scale": jnp.ones((dim or cfg.d_model,), _dtype(cfg))}


def rms_norm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_layernorm(cfg: ModelConfig, key, dim: Optional[int] = None) -> Params:
    del key
    d = dim or cfg.d_model
    return {"scale": jnp.ones((d,), _dtype(cfg)),
            "bias": jnp.zeros((d,), _dtype(cfg))}


def layer_norm(params: Params, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(cfg: ModelConfig, key, dim: Optional[int] = None) -> Params:
    if cfg.family == "encdec":
        return init_layernorm(cfg, key, dim)
    return init_rmsnorm(cfg, key, dim)


def apply_norm(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "bias" in params:
        return layer_norm(params, x, cfg.norm_eps)
    return rms_norm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, D) with D even; positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention core — blockwise jnp flash (dry-run / CPU path) + dispatch
# ---------------------------------------------------------------------------


def _block_ranges(sq: int, skv: int, q_chunk: int, kv_chunk: int,
                  causal: bool, window: Optional[int], skip: bool,
                  offset: Optional[int] = None):
    """Static kv-block range visible to each q block."""
    n_q = -(-sq // q_chunk)
    n_kv = -(-skv // kv_chunk)
    if offset is None:
        offset = skv - sq  # decode/prefill alignment: q row i is abs pos offset+i
    out = []
    for i in range(n_q):
        lo, hi = 0, n_kv
        if skip:
            row_hi = offset + min((i + 1) * q_chunk, sq) - 1
            row_lo = offset + i * q_chunk
            if causal:
                hi = min(hi, row_hi // kv_chunk + 1)
            if window is not None:
                lo = max(lo, (row_lo - window + 1) // kv_chunk)
        out.append((i, lo, max(lo + 1, hi)))
    return out


def blockwise_attention(
    q: jnp.ndarray,       # (B, Hq, Sq, Dk)
    k: jnp.ndarray,       # (B, Hkv, Skv, Dk)
    v: jnp.ndarray,       # (B, Hkv, Skv, Dv)
    *,
    causal: bool,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    causal_skip: bool = True,
    q_offset: Optional[int] = None,
) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp.

    Outer loop over q chunks is a static python loop so each q chunk scans
    only its *visible* kv range (``causal_skip``: drops the ~2× wasted FLOPs
    a dense causal mask pays — a measured lever in EXPERIMENTS §Perf); inner
    loop is ``lax.scan`` over kv chunks with running (m, l, acc).

    ``q_offset`` pins q row 0 to an explicit absolute position instead of
    the default right-aligned ``skv - sq`` convention — the chunked-prefill
    path (``models/prefill.py``) attends a mid-sequence chunk of rows
    against a full-length K/V scratch, so row ``i`` sits at ``q_offset + i``
    with valid keys only in ``[0, q_offset + sq)``.  Keys at or beyond the
    written prefix are excluded by the causal mask alone, and masked kv
    blocks are exact no-ops of the online softmax (``alpha == 1``, zero
    contributions), which is what keeps a chunked pass bit-identical to the
    bulk pass per row.
    """
    b, hq, sq, dk = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    group = hq // hkv
    scale = scale if scale is not None else dk ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    offset = skv - sq if q_offset is None else q_offset

    qg = q.reshape(b, hkv, group, sq, dk).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    pad_q = (-sq) % q_chunk
    if pad_q:
        qg = jnp.pad(qg, ((0, 0),) * 3 + ((0, pad_q), (0, 0)))
    pad_kv = (-skv) % kv_chunk
    if pad_kv:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_kv = kf.shape[2] // kv_chunk
    kb = kf.reshape(b, hkv, n_kv, kv_chunk, dk)
    vb = vf.reshape(b, hkv, n_kv, kv_chunk, dv)

    outs = []
    for (i, lo, hi) in _block_ranges(sq, skv, q_chunk, kv_chunk, causal,
                                     window, causal_skip, offset):
        qi = lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=3)
        rows = offset + i * q_chunk + jnp.arange(q_chunk)

        def step(carry, inp):
            m, l, acc = carry
            kj, vj, jidx = inp
            cols = jidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qi, kj)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            mask &= (cols < skv)[None, :]                     # kv padding
            if causal:
                mask &= cols[None, :] <= rows[:, None]
            if window is not None:
                mask &= cols[None, :] > rows[:, None] - window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            p = jnp.where(s <= -1e29, 0.0, jnp.exp(s - m_new))
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkgqc,bkcd->bkgqd", p, vj)
            return (m_new, l_new, acc_new), None

        # Carry inits derived arithmetically from qi so their varying-axes
        # type matches the scan body under shard_map manual axes (an
        # explicit lax.pcast would do the same but its transpose lowers to
        # an all-reduce variant that crashes XLA-CPU's AllReducePromotion
        # pass at 512 devices — see EXPERIMENTS.md §Perf notes).
        zero_col = jax.lax.stop_gradient(qi[..., :1]) * 0.0
        m0 = zero_col - 1e30
        l0 = zero_col
        a0 = zero_col * jnp.zeros((dv,), jnp.float32)
        span = hi - lo
        ks = lax.dynamic_slice_in_dim(kb, lo, span, axis=2)
        vs = lax.dynamic_slice_in_dim(vb, lo, span, axis=2)
        (m, l, acc), _ = lax.scan(
            step, (m0, l0, a0),
            (jnp.moveaxis(ks, 2, 0), jnp.moveaxis(vs, 2, 0),
             lo + jnp.arange(span)),
        )
        l = jnp.where(l == 0.0, 1.0, l)
        outs.append(acc / l)
    out = jnp.concatenate(outs, axis=3)[..., :sq, :]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def attention_core(
    cfg: ModelConfig,
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window: Optional[int] = None,
    scale: Optional[float] = None, q_offset: Optional[int] = None,
) -> jnp.ndarray:
    impl = resolve_attn_impl(cfg)
    aligned = q_offset is None or q_offset == k.shape[2] - q.shape[2]
    if impl == "pallas" and q.shape[-1] == v.shape[-1] and aligned:
        from repro.kernels.flash_attention import flash_attention

        return flash_attention(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "ref" and aligned:
        from repro.kernels.flash_attention.ref import attention_ref

        return attention_ref(q, k, v, causal=causal, window=window, scale=scale)
    # mid-sequence q offsets (chunked prefill) only exist in the blockwise
    # path — the kernels keep the right-aligned convention
    return blockwise_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        causal_skip=cfg.causal_block_skip, q_offset=q_offset,
    )


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> Params:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "wq": _init(ks[0], (cfg.d_model, cfg.n_heads * hd), dt),
        "wk": _init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dt),
        "wv": _init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dt),
        "wo": _init(ks[3], (cfg.n_heads * hd, cfg.d_model), dt, depth_scale),
    }


def attention(
    cfg: ModelConfig, params: Params, x: jnp.ndarray,
    positions: jnp.ndarray, *, causal: bool = True,
    kv_override: Optional[tuple] = None,
    return_kv: bool = False,
):
    """x: (B, S, D) -> (B, S, D).  ``kv_override`` supplies precomputed
    (k, v, kv_positions) for cross-attention (whisper decoder).
    ``return_kv`` additionally returns the (roped) K/V — the prefill path's
    cache source."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(_cdtype(cfg))
    q = jnp.einsum("bsd,dh->bsh", xc, params["wq"].astype(_cdtype(cfg)))
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    if kv_override is None:
        k = jnp.einsum("bsd,dh->bsh", xc, params["wk"].astype(_cdtype(cfg)))
        v = jnp.einsum("bsd,dh->bsh", xc, params["wv"].astype(_cdtype(cfg)))
        k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
        if cfg.family != "encdec":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v, _ = kv_override
    out = attention_core(cfg, q, k, v, causal=causal, window=cfg.window)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out,
                   params["wo"].astype(_cdtype(cfg))).astype(x.dtype)
    if return_kv:
        return y, (k, v)
    return y


def cross_kv(cfg: ModelConfig, params: Params, enc_out: jnp.ndarray):
    """Precompute encoder K/V for the whisper decoder's cross-attention."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    ec = enc_out.astype(_cdtype(cfg))
    k = jnp.einsum("bsd,dh->bsh", ec, params["wk"].astype(_cdtype(cfg)))
    v = jnp.einsum("bsd,dh->bsh", ec, params["wv"].astype(_cdtype(cfg)))
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return k, v, jnp.arange(s)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (minicpm3)
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "w_dq": _init(ks[0], (cfg.d_model, cfg.q_lora_rank), dt),
        "q_norm": {"scale": jnp.ones((cfg.q_lora_rank,), dt)},
        "w_uq": _init(ks[1], (cfg.q_lora_rank, h * (dn + dr)), dt),
        "w_dkv": _init(ks[2], (cfg.d_model, cfg.kv_lora_rank + dr), dt),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora_rank,), dt)},
        "w_uk": _init(ks[3], (cfg.kv_lora_rank, h * dn), dt),
        "w_uv": _init(ks[4], (cfg.kv_lora_rank, h * dv), dt),
        "wo": _init(ks[5], (h * dv, cfg.d_model), dt, depth_scale),
    }


def mla_attention(
    cfg: ModelConfig, params: Params, x: jnp.ndarray, positions: jnp.ndarray,
    return_cache: bool = False,
):
    """Train/prefill path: expand the latent to per-head K/V (compute-rich),
    attend with the shared rope key appended.  ``return_cache`` also returns
    (c_kv latent (B,S,r), k_rope (B,S,dr)) — the MLA cache contents."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    cd = _cdtype(cfg)
    xc = x.astype(cd)

    q_lat = rms_norm(params["q_norm"], xc @ params["w_dq"].astype(cd), cfg.norm_eps)
    q = (q_lat @ params["w_uq"].astype(cd)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), positions, cfg.rope_theta)

    dkv = xc @ params["w_dkv"].astype(cd)                 # (B, S, r + dr)
    c_kv = rms_norm(params["kv_norm"], dkv[..., :r], cfg.norm_eps)
    k_rope = apply_rope(
        dkv[..., r:][:, None], positions, cfg.rope_theta
    )                                                     # (B, 1, S, dr) shared
    k_nope = (c_kv @ params["w_uk"].astype(cd)).reshape(b, s, h, dn)
    vfull = (c_kv @ params["w_uv"].astype(cd)).reshape(b, s, h, dv)

    qh = jnp.concatenate(
        [q_nope.transpose(0, 2, 1, 3), q_rope], axis=-1
    )                                                     # (B, H, S, dn+dr)
    kh = jnp.concatenate(
        [k_nope.transpose(0, 2, 1, 3),
         jnp.broadcast_to(k_rope, (b, h, s, dr))], axis=-1
    )
    vh = vfull.transpose(0, 2, 1, 3)
    out = attention_core(cfg, qh, kh, vh, causal=True,
                         scale=(dn + dr) ** -0.5)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * dv)
    y = (out @ params["wo"].astype(cd)).astype(x.dtype)
    if return_cache:
        return y, (c_kv, k_rope[:, 0])   # (B,S,r), (B,S,dr)
    return y


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _act(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(name)


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    f = d_ff or cfg.d_ff
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    p = {
        "w_up": _init(ks[0], (cfg.d_model, f), dt),
        "w_down": _init(ks[1], (f, cfg.d_model), dt, depth_scale),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _init(ks[2], (cfg.d_model, f), dt)
    return p


def mlp(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    cd = _cdtype(cfg)
    xc = x.astype(cd)
    up = xc @ params["w_up"].astype(cd)
    if cfg.gated_mlp:
        up = _act(cfg.activation, xc @ params["w_gate"].astype(cd)) * up
    else:
        up = _act(cfg.activation, up)
    return (up @ params["w_down"].astype(cd)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE (capacity-based scatter dispatch, per batch row ⇒ data-partitionable)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    p = {
        "router": _init(ks[0], (d, e), jnp.float32),
        "w_up": _init(ks[1], (e, d, f), dt),
        "w_down": _init(ks[2], (e, f, d), dt, depth_scale),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _init(ks[3], (e, d, f), dt)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def _expert_ffn(cfg: ModelConfig, params: Params, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: (..., E, C, D) -> (..., E, C, D), batched over experts."""
    cd = _cdtype(cfg)
    up = jnp.einsum("...ecd,edf->...ecf", xe, params["w_up"].astype(cd))
    if cfg.gated_mlp:
        gate = jnp.einsum("...ecd,edf->...ecf", xe, params["w_gate"].astype(cd))
        up = _act(cfg.activation, gate) * up
    else:
        up = _act(cfg.activation, up)
    return jnp.einsum("...ecf,efd->...ecd", up, params["w_down"].astype(cd))


def moe_route(cfg: ModelConfig, router: jnp.ndarray, xc: jnp.ndarray):
    """Top-k routing + per-row capacity bookkeeping.

    The single owner of the routing math: both the GSPMD dense path
    (:func:`moe`) and the expert-parallel dispatch path
    (``models/moe_ep.py``) call it, which is what makes the two paths
    token-for-token equivalent (same slots, same drops).

    Returns ``(weights (B,S,K) normalized, idx (B,S,K), keep (B,S,K) bool,
    dst (B,S,K) flat slot with ``e*cap`` as the overflow bin, cap)``.
    """
    b, s, _ = xc.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    logits = jnp.einsum("bsd,de->bse", xc.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = lax.top_k(probs, k)                   # (B, S, K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(s * k / e * cfg.capacity_factor))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)      # (B, S, K, E)
    flat_choice = onehot.reshape(b, s * k, e)
    pos_in_e = jnp.cumsum(flat_choice, axis=1) - flat_choice  # (B, S*K, E)
    slot = jnp.take_along_axis(
        pos_in_e.reshape(b, s, k, e), idx[..., None], axis=-1
    )[..., 0]                                              # (B, S, K)
    keep = (slot < cap)
    dst = jnp.where(keep, idx * cap + slot, e * cap)       # overflow bin
    return weights, idx, keep, dst, cap


def moe_dispatch(xc: jnp.ndarray, dst: jnp.ndarray, keep: jnp.ndarray,
                 e: int, cap: int) -> jnp.ndarray:
    """Scatter tokens into the per-expert capacity buffer.

    ``xc``: (B, S, D); ``dst``/``keep`` from :func:`moe_route`.  Returns the
    (B, E, cap, D) buffer — dropped (over-capacity) tokens land in the
    overflow bin and are sliced away.
    """
    b, s, d = xc.shape
    k = dst.shape[-1]
    xin = jnp.zeros((b, e * cap + 1, d), xc.dtype)
    src = jnp.broadcast_to(xc[:, :, None, :], (b, s, k, d)).reshape(b, s * k, d)
    xin = xin.at[jnp.arange(b)[:, None], dst.reshape(b, s * k)].add(
        src * keep.reshape(b, s * k, 1))
    return xin[:, : e * cap].reshape(b, e, cap, d)


def moe_combine(ye: jnp.ndarray, dst: jnp.ndarray, keep: jnp.ndarray,
                weights: jnp.ndarray) -> jnp.ndarray:
    """Gather expert outputs back to token order and mix by router weights.

    ``ye``: (B, E, cap, D) expert outputs; dropped tokens contribute zero
    (residual fallthrough happens at the block level).  Returns (B, S, D).
    """
    b, e, cap, d = ye.shape
    s, k = dst.shape[1], dst.shape[2]
    ye = ye.reshape(b, e * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((b, 1, d), ye.dtype)], axis=1)
    gathered = jnp.take_along_axis(
        ye, dst.reshape(b, s * k, 1), axis=1
    ).reshape(b, s, k, d)
    return (gathered * (weights * keep).astype(ye.dtype)[..., None]).sum(axis=2)


def moe(cfg: ModelConfig, params: Params, x: jnp.ndarray,
        dense_combine: bool = False) -> jnp.ndarray:
    """x: (B, S, D).  Routing/capacity are computed *per batch row*, so the
    whole layer partitions cleanly over the data axis (capacity per row ==
    per-device capacity with row-aligned sharding).  Dropped tokens (over
    capacity) fall through on the residual path, as in standard top-k MoE.

    ``dense_combine=True`` computes every expert on every token and mixes by
    router weights — used for decode, where S is 1 and the layer is bound by
    reading the expert *weights* anyway, so the extra FLOPs are free and the
    gather/scatter (and its collectives) disappear.
    """
    b, s, d = x.shape
    e = cfg.n_experts
    cd = _cdtype(cfg)
    xc = x.astype(cd)

    if dense_combine:
        # routing still owned by moe_route; the capacity bookkeeping it
        # also returns is unused here and DCE'd under jit
        weights, idx, _, _, _ = moe_route(cfg, params["router"], xc)
        combine = jnp.zeros((b, s, e), jnp.float32).at[
            jnp.arange(b)[:, None, None], jnp.arange(s)[None, :, None], idx
        ].add(weights)
        dense = _expert_ffn(cfg, params, jnp.broadcast_to(xc[:, None], (b, e, s, d)))
        y = jnp.einsum("besd,bse->bsd", dense, combine.astype(cd))
    else:
        weights, _, keep, dst, cap = moe_route(cfg, params["router"], xc)
        xe = moe_dispatch(xc, dst, keep, e, cap)
        ye = _expert_ffn(cfg, params, xe)
        y = moe_combine(ye, dst, keep, weights)

    if cfg.n_shared_experts:
        y = y + mlp(cfg, params["shared"], xc)
    return y.astype(x.dtype)


def moe_aux_loss(cfg: ModelConfig, x: jnp.ndarray, params: Params) -> jnp.ndarray:
    """Load-balancing auxiliary loss (Switch-style): E[f_e · p_e] · E."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = lax.top_k(probs, cfg.experts_per_token)
    hard = jax.nn.one_hot(idx, cfg.n_experts).sum(axis=2)  # (B, S, E)
    f = hard.mean(axis=(0, 1))
    p = probs.mean(axis=(0, 1))
    return cfg.n_experts * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------


def init_mamba2(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    d_in = cfg.ssm_heads * cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "in_proj": _init(ks[0], (cfg.d_model, proj_out), dt),
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_ch), dt, 0.1),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((cfg.ssm_heads,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.ssm_heads, dtype=jnp.float32)),
        "d_skip": jnp.ones((cfg.ssm_heads,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_in,), dt)},
        "out_proj": _init(ks[2], (d_in, cfg.d_model), dt, depth_scale),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   pad: bool = True) -> jnp.ndarray:
    """Depthwise causal conv over seq.  x: (B, S, C); w: (K, C).

    ``pad=False`` skips the leading zero-pad: the caller has already
    prepended the (K−1) preceding raw rows (the chunked-prefill conv
    resume), so VALID alignment alone yields the causal outputs — the same
    conv the padded call runs, since concatenated zeros and pad zeros are
    the same input tensor."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0))) if pad else x
    out = lax.conv_general_dilated(
        xp, w[:, None, :],          # (K, 1, C) HIO with feature groups
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def ssd_jnp(x, dtv, a, bmat, cmat, d_skip, chunk: int, init_state=None):
    """Chunked SSD in pure jnp (same math as the Pallas kernel): scan over
    chunks carrying the (H, N, P) state; intra-chunk work is batched matmuls.

    x: (B, S, H, P); dtv: (B, S, H); a: (H,); bmat/cmat: (B, S, G, N).
    Returns (y, final_state (B, H, N, P) fp32).

    ``init_state`` resumes the chunk walk from a carried (B, H, N, P) fp32
    state (the streamed-prefill hand-off) instead of zeros — bit-identical
    to one bulk call over the concatenated sequence whenever the resume
    point is a multiple of ``chunk`` (the walk visits the same blocks).
    """
    bsz, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // chunk

    def reshape_c(t):
        return jnp.moveaxis(
            t.reshape((bsz, nc, chunk) + t.shape[2:]), 1, 0
        )  # (nc, B, L, ...)

    xs, dts, bs, cs = map(reshape_c, (x, dtv, bmat, cmat))

    def step(state, inp):
        xc_, dt_, b_, c_ = inp                     # (B,L,H,P),(B,L,H),(B,L,G,N)
        xf = xc_.astype(jnp.float32)
        dtf = dt_.astype(jnp.float32)
        alog = dtf * a[None, None, :]              # (B, L, H)
        cum = jnp.cumsum(alog, axis=1)
        total = cum[:, -1]                         # (B, H)
        bh = jnp.repeat(b_, hpg, axis=2).astype(jnp.float32)   # (B,L,H,N)
        ch = jnp.repeat(c_, hpg, axis=2).astype(jnp.float32)
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L,L,H)
        ii = jnp.arange(chunk)
        causal = ii[:, None] >= ii[None, :]
        seg = jnp.where(causal[None, :, :, None], seg, -1e30)
        scores = jnp.einsum("blhn,bmhn->blmh", ch, bh)
        w = scores * jnp.exp(seg) * dtf[:, None, :, :]
        y = jnp.einsum("blmh,bmhp->blhp", w, xf)
        y += jnp.exp(cum)[..., None] * jnp.einsum("blhn,bhnp->blhp", ch, state)
        decay_end = jnp.exp(total[:, None] - cum) * dtf        # (B,L,H)
        state = jnp.exp(total)[..., None, None] * state + jnp.einsum(
            "blhn,blhp->bhnp", bh * decay_end[..., None], xf)
        return state, y

    state0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    final, ys = lax.scan(step, state0, (xs, dts, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * chunk, h, p)[:, :s]
    y = y + d_skip[None, None, :, None] * x[:, :s].astype(jnp.float32)
    return y, final


def mamba2_block(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 return_state: bool = False, init_state=None,
                 conv_state=None):
    """x: (B, S, D) -> (B, S, D).  Mamba-2 block: in_proj → causal conv →
    SSD (Pallas kernel on TPU, chunked jnp elsewhere) → gated RMSNorm →
    out_proj.  ``return_state`` also returns the decode cache contents:
    (final ssm state (B,H,N,P) fp32, conv tail (B, conv−1, C) raw pre-conv).

    ``init_state`` / ``conv_state`` resume a *mid-sequence* forward (the
    streamed-prefill chunk carry): ``init_state`` seeds the SSD chunk walk
    and ``conv_state`` supplies the (conv−1) raw pre-conv rows preceding
    this slice, which are prepended so the depthwise conv runs VALID over
    the extended stream — the exact rows the bulk conv would see.  With
    zero carries this is bitwise the plain call (prepended zeros ≡ the
    causal zero-pad), so chunk 0 needs no special case."""
    b, s, _ = x.shape
    h, p, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state
    d_in = h * p
    cd = _cdtype(cfg)
    xc = x.astype(cd)

    zxbcdt = xc @ params["in_proj"].astype(cd)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * g * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * g * n:]

    tail_len = cfg.ssm_conv - 1
    if conv_state is not None:
        # resume: the raw rows preceding this slice, carried by the caller
        assert conv_state.shape[1] == tail_len, conv_state.shape
        ext = jnp.concatenate([conv_state.astype(cd), xbc], axis=1)
        if return_state:
            conv_tail = ext[:, ext.shape[1] - tail_len:, :]
        xbc = _causal_conv1d(ext, params["conv_w"].astype(cd),
                             params["conv_b"].astype(cd), pad=False)
    else:
        if return_state:
            # decode resumes the depthwise conv from the last (conv−1) raw
            # inputs
            pad = max(0, tail_len - s)
            tail_src = (jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
                        if pad else xbc)
            conv_tail = tail_src[:, -tail_len:, :]
        xbc = _causal_conv1d(xbc, params["conv_w"].astype(cd),
                             params["conv_b"].astype(cd))
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(b, s, h, p)
    bmat = xbc[..., d_in: d_in + g * n].reshape(b, s, g, n)
    cmat = xbc[..., d_in + g * n:].reshape(b, s, g, n)
    dtv = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    a = -jnp.exp(params["a_log"])

    impl = resolve_attn_impl(cfg)
    if impl == "pallas":
        from repro.kernels.ssd import ssd as ssd_kernel, ssd_chunk_fed

        n_seg = int(cfg.ssm_stream_segments or 0)
        if n_seg > 1 and s > cfg.ssm_chunk:
            # chunk-fed scan: feed the kernel segment-by-segment with the
            # state carried across segments.  Segment cuts land on chunk
            # boundaries (tail rides the last segment), so the walk is
            # bit-identical to the bulk call.
            from repro.core.pipeline import chunk_slices
            full = s // cfg.ssm_chunk
            cuts = [(lo * cfg.ssm_chunk, hi * cfg.ssm_chunk)
                    for lo, hi in chunk_slices(full, min(n_seg, full))]
            cuts[-1] = (cuts[-1][0], s)

            def fetch(k):
                lo, hi = cuts[k]
                return (xs[:, lo:hi], dtv[:, lo:hi],
                        bmat[:, lo:hi], cmat[:, lo:hi])

            y, state = ssd_chunk_fed(fetch, len(cuts), a, params["d_skip"],
                                     chunk=cfg.ssm_chunk,
                                     init_state=init_state)
        else:
            y, state = ssd_kernel(xs, dtv, a, bmat, cmat, params["d_skip"],
                                  chunk=cfg.ssm_chunk, init_state=init_state)
        y = y.astype(jnp.float32)
    else:
        y, state = ssd_jnp(xs, dtv, a, bmat, cmat, params["d_skip"],
                           chunk=cfg.ssm_chunk, init_state=init_state)

    y = y.reshape(b, s, d_in).astype(cd)
    y = rms_norm(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ params["out_proj"].astype(cd)).astype(x.dtype)
    if return_state:
        return out, (state, conv_tail)
    return out
