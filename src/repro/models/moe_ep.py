"""Expert-parallel MoE dispatch over the conduit ``all_to_all``.

The GSPMD path (``layers.py::moe``) keeps every expert's weights on every
rank and lets the partitioner slice the capacity einsums; expert traffic
never appears as an ``all_to_all`` on the wire, so ``TransportPolicy.moe``
had nothing to bind.  This module is the manual counterpart: experts are
*sharded* over an ``expert`` mesh axis, and tokens travel to their experts
through the conduit registry — the FSHMEM claim (one-sided PGAS schedules
carrying application traffic classes) applied to MoE routing, the way
Sharma & Chow's PGAS communication library routes application scatter/
gather through the same one-sided primitives as bulk transfers.

Dataflow (inside one ``jax.shard_map`` region over the full mesh):

1. every rank top-k routes its *local* tokens with the exact per-row
   capacity bookkeeping of the dense path (``layers.moe_route`` /
   ``layers.moe_dispatch`` — shared code, so slots and capacity drops are
   token-for-token identical);
2. the (B_loc, E, cap, D) dispatch buffer is bucketed per destination
   expert shard — ``(n, E/n, B_loc, cap, D)``, leading dim = the expert
   axis size — and exchanged with ``Conduit.all_to_all`` (``xla`` |
   ``ring`` | ``bidir`` | ``auto``, honoring ``chunk_bytes``);
3. each rank applies its E/n local experts (``layers._expert_ffn``) to
   every arriving bucket;
4. results ride the reverse ``all_to_all`` home and are combined by router
   weight (``layers.moe_combine``) — over-capacity tokens contribute zero
   and fall through on the block's residual path, exactly like the dense
   path.

The batch is sharded over **every** mesh axis inside the region (not just
the data axes): each rank then differentiates distinct tokens, so the
``psum`` that ``shard_map``'s transpose inserts for the replicated router
and the expert-replicated weights is a true sum of partials — the same
reason ``models/artblock.py`` only differentiates tp-sharded tensors.

Steps 2–4 can run **streamed** (``stream_chunks`` > 1): the dispatch
buffer splits into ART chunks along the source-row dim and rides
``Conduit.streamed`` (the generalized scheduler of ``core/pipeline.py``),
so the expert FFN of bucket *k−1* — and its reverse ``all_to_all`` home —
overlaps bucket *k*'s forward exchange, bit-identical to the bulk path
(DESIGN §3).

Equivalence across transports and odd/even expert-axis sizes is asserted
by ``tests/test_moe_ep.py``; the dispatch-size crossover is swept into
``BENCH_moe.json`` by ``benchmarks/moe_dispatch.py``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import pipeline as pl
from repro.core.conduit import Conduit
from repro.models import layers as L


def supports_moe_ep(cfg: ModelConfig, mesh) -> bool:
    """Whether (cfg, mesh) can take the expert-parallel dispatch path.

    Requires an ``expert`` mesh axis of extent > 1 that divides
    ``cfg.n_experts``; anything else falls back to the dense GSPMD layer
    (same numerics, no manual region).
    """
    if "expert" not in mesh.axis_names or mesh.shape["expert"] <= 1:
        return False
    n = mesh.shape["expert"]
    return bool(cfg.n_experts) and cfg.n_experts % n == 0


def moe_ep_ffn(cfg: ModelConfig, x, router, w_up, w_gate, w_down, *,
               conduit: Conduit, stream_chunks: Optional[int] = None):
    """The routed MoE FFN, manual over the mesh (call inside ``shard_map``).

    ``x``: the local (B_loc, S, D) token shard; ``router``: the full (D, E)
    router (replicated); ``w_up``/``w_gate``/``w_down``: this rank's expert
    shard, leading dim E/n.  Returns (B_loc, S, D) in compute dtype — the
    shared expert and the residual add stay outside the region.

    ``stream_chunks`` > 1 replaces the bulk exchange with the *streamed*
    dispatch pipeline (``Conduit.streamed`` over ``pipeline.streamed``):
    the dispatch buffer splits into ART chunks along the source-row dim,
    and the expert FFN of bucket *k−1* (plus its reverse ``all_to_all``
    home) runs while bucket *k*'s forward ``all_to_all`` is in flight.
    Chunking slices disjoint token rows through the identical transport
    schedule, so the result is bit-identical to the bulk exchange
    (asserted by ``tests/test_moe_ep.py::TestStreamedDispatch``).
    """
    n = lax.axis_size(conduit.axis)
    e = cfg.n_experts
    e_loc = e // n
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    b = xc.shape[0]

    weights, _, keep, dst, cap = L.moe_route(cfg, router, xc)
    xe = L.moe_dispatch(xc, dst, keep, e, cap)            # (b, E, cap, D)

    # bucket per destination expert shard: expert q*e_loc+j lives on rank q
    send = xe.transpose(1, 0, 2, 3).reshape(n, e_loc, b, cap, -1)

    p_loc = {"w_up": w_up, "w_down": w_down}
    if w_gate is not None:
        p_loc["w_gate"] = w_gate

    def ffn_home(recv):
        # (n, b_k, e_loc, cap, D): leading (source rank, source row) batches
        # the expert einsums exactly like the dense path's (b,) batch
        ye = L._expert_ffn(cfg, p_loc, recv.transpose(0, 2, 1, 3, 4))
        return conduit.all_to_all(ye.transpose(0, 2, 1, 3, 4))

    c = max(1, min(int(stream_chunks or 1), b))
    if c == 1:
        recv = conduit.all_to_all(send)                   # slot q: from rank q
        back = ffn_home(recv)
    else:
        backs = conduit.streamed(
            "all_to_all", pl.split(send, c, axis=2),
            work=lambda k, recv: ffn_home(recv))
        back = jnp.concatenate(backs, axis=2)

    ye_full = back.reshape(e, b, cap, -1).transpose(1, 0, 2, 3)
    return L.moe_combine(ye_full, dst, keep, weights)


def _ep_gated(cfg, x, router, w_up, w_gate, w_down, *, conduit,
              stream_chunks=None):
    return moe_ep_ffn(cfg, x, router, w_up, w_gate, w_down, conduit=conduit,
                      stream_chunks=stream_chunks)


def _ep_ungated(cfg, x, router, w_up, w_down, *, conduit,
                stream_chunks=None):
    return moe_ep_ffn(cfg, x, router, w_up, None, w_down, conduit=conduit,
                      stream_chunks=stream_chunks)


def build_moe_ep_runner(cfg: ModelConfig, mesh, *, transport: str,
                        chunk_bytes: Optional[int] = None,
                        stream_chunks: Optional[int] = None,
                        decode: bool = False) -> Optional[Callable]:
    """MoE-layer runner routing expert dispatch through the conduit.

    Returns ``runner(cfg, moe_params, x) -> y`` — a drop-in for
    ``layers.moe`` that the step builder installs via
    ``models/shardctx.py`` — or ``None`` when (cfg, mesh) cannot take the
    expert-parallel path (the step then keeps the dense GSPMD layer).
    A batch that does not divide the mesh falls back per call, so prefill
    or eval shapes never fail to trace.

    ``decode=True`` builds the **latency-mode EP decode** runner
    (``dist/steps.build_serve_step``): ``x`` is the step's (B, 1, D) token
    batch, and the B in-flight slots are batched across the expert shards
    through the same conduit ``all_to_all`` — per-token capacity is exactly
    one slot per routed expert (``s = 1``), so nothing drops and the layer
    matches the dense-combine decode path.  Indivisible batches fall back
    to dense-combine (the weight-bound small-batch path) instead of the
    dispatch einsums.

    ``stream_chunks`` streams the exchange: the dispatch payload splits
    into that many ART chunks (clamped to the local row extent) and expert
    compute on bucket *k−1* overlaps bucket *k*'s ``all_to_all`` — see
    :func:`moe_ep_ffn`.  ``None``/1 keeps the bulk exchange.

    On meshes that also carry ``data``/``model`` axes, the region's weight
    specs (``P("expert", None, None)``) regather each expert shard's full
    (D, F) weights from their at-rest data×model placement per layer call
    — the same FSDP-style weight gather the ART-TP runner pays.  Running
    TP *inside* the expert region (model-sharded F with an in-region
    reduce) is future work; until then, size the expert axis so E/n
    expert weights fit a rank.
    """
    if not supports_moe_ep(cfg, mesh):
        return None
    conduit = Conduit(axis="expert", transport=transport,
                      chunk_bytes=chunk_bytes)
    axes = tuple(mesh.axis_names)
    act = P(axes, None, None)               # batch over EVERY mesh axis
    wspec = P("expert", None, None)
    rspec = P(None, None)
    cd = jnp.dtype(cfg.compute_dtype)

    def runner(cfg_: ModelConfig, p, x):
        if x.shape[0] % mesh.size:
            # indivisible batch: dense path (decode keeps dense-combine —
            # the weight-bound small-batch fallback)
            return L.moe(cfg_, p, x, dense_combine=decode)
        w_gate = p.get("w_gate")
        if w_gate is not None:
            fn = jax.shard_map(
                functools.partial(_ep_gated, cfg_, conduit=conduit,
                                  stream_chunks=stream_chunks),
                mesh=mesh, in_specs=(act, rspec, wspec, wspec, wspec),
                out_specs=act, check_vma=False)
            y = fn(x, p["router"], p["w_up"], w_gate, p["w_down"])
        else:
            fn = jax.shard_map(
                functools.partial(_ep_ungated, cfg_, conduit=conduit,
                                  stream_chunks=stream_chunks),
                mesh=mesh, in_specs=(act, rspec, wspec, wspec),
                out_specs=act, check_vma=False)
            y = fn(x, p["router"], p["w_up"], p["w_down"])
        if cfg_.n_shared_experts:
            y = y + L.mlp(cfg_, p["shared"], x.astype(cd))
        return y.astype(x.dtype)

    return runner


__all__ = ["supports_moe_ep", "moe_ep_ffn", "build_moe_ep_runner"]
