"""Activation-sharding context: the step builder injects sharding
constraints into the (mesh-agnostic) model code.

The step builders in ``repro.dist.steps`` install a tag→constraint function
for the duration of a trace (``build_train_step`` / ``build_prefill_step``
via :func:`activation_sharding`); model code calls
``constrain(x, "residual")`` at block boundaries.
Outside any context this is the identity, so model code runs unchanged in
unit tests / single-device smoke tests.

Tags used by the model zoo:
  residual   — the (B, S, D) stream at layer boundaries (SP shards S on tp)
  logit_hidden — final hidden entering the LM head
"""

from __future__ import annotations

import contextlib
from typing import Callable, Optional

_ACTIVE: Optional[Callable] = None
_TP_BLOCK: Optional[Callable] = None


@contextlib.contextmanager
def activation_sharding(fn: Callable, tp_block: Optional[Callable] = None):
    """``fn(x, tag)`` applies sharding constraints; ``tp_block`` (optional)
    is the ART-TP dense-block runner installed by
    ``repro.dist.steps.build_train_step`` when ``StepConfig.art_tp`` is on:
    ``tp_block(cfg, layer_params, x, positions) -> x`` executes the block
    with hand-scheduled ring collectives (models/artblock.py)."""
    global _ACTIVE, _TP_BLOCK
    old, old_tp = _ACTIVE, _TP_BLOCK
    _ACTIVE, _TP_BLOCK = fn, tp_block
    try:
        yield
    finally:
        _ACTIVE, _TP_BLOCK = old, old_tp


def constrain(x, tag: str):
    if _ACTIVE is None:
        return x
    return _ACTIVE(x, tag)


def tp_block_runner() -> Optional[Callable]:
    return _TP_BLOCK
