"""Activation-sharding context: the step builder injects sharding
constraints into the (mesh-agnostic) model code.

The step builders in ``repro.dist.steps`` install a tag→constraint function
for the duration of a trace (``build_train_step`` / ``build_prefill_step``
via :func:`activation_sharding`); model code calls
``constrain(x, "residual")`` at block boundaries.
Outside any context this is the identity, so model code runs unchanged in
unit tests / single-device smoke tests.

Tags used by the model zoo:
  residual   — the (B, S, D) stream at layer boundaries (SP shards S on tp)
  logit_hidden — final hidden entering the LM head
"""

from __future__ import annotations

import contextlib
from typing import Callable, Optional

_ACTIVE: Optional[Callable] = None
_TP_BLOCK: Optional[Callable] = None
_MOE_FFN: Optional[Callable] = None


@contextlib.contextmanager
def activation_sharding(fn: Callable, tp_block: Optional[Callable] = None,
                        moe_ffn: Optional[Callable] = None):
    """``fn(x, tag)`` applies sharding constraints.

    ``tp_block`` (optional) is the ART-TP dense-block runner installed by
    ``repro.dist.steps.build_train_step`` when ``TransportPolicy.tp`` names
    a ring family: ``tp_block(cfg, layer_params, x, positions) -> x``
    executes the block with hand-scheduled ring collectives
    (models/artblock.py).

    ``moe_ffn`` (optional) is the expert-parallel MoE runner installed when
    ``TransportPolicy.moe`` names a conduit transport and the mesh has an
    ``expert`` axis: ``moe_ffn(cfg, moe_params, x) -> y`` replaces
    ``layers.moe`` with the bucketed all_to_all dispatch of
    ``models/moe_ep.py``."""
    global _ACTIVE, _TP_BLOCK, _MOE_FFN
    old, old_tp, old_moe = _ACTIVE, _TP_BLOCK, _MOE_FFN
    _ACTIVE, _TP_BLOCK, _MOE_FFN = fn, tp_block, moe_ffn
    try:
        yield
    finally:
        _ACTIVE, _TP_BLOCK, _MOE_FFN = old, old_tp, old_moe


def constrain(x, tag: str):
    if _ACTIVE is None:
        return x
    return _ACTIVE(x, tag)


def tp_block_runner() -> Optional[Callable]:
    return _TP_BLOCK


def moe_ffn_runner() -> Optional[Callable]:
    """The installed expert-parallel MoE runner, or None (dense GSPMD)."""
    return _MOE_FFN
