"""Pure-JAX model zoo (no flax): layers, family assembly, decode path."""

from repro.models import decode, layers, model, moe_ep
from repro.models.decode import decode_step, init_cache
from repro.models.model import (
    count_params_analytic,
    forward,
    init_params,
    loss_fn,
)
from repro.models.moe_ep import build_moe_ep_runner, supports_moe_ep

__all__ = [
    "decode", "layers", "model", "moe_ep",
    "decode_step", "init_cache",
    "count_params_analytic", "forward", "init_params", "loss_fn",
    "build_moe_ep_runner", "supports_moe_ep",
]
