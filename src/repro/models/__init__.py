"""Pure-JAX model zoo (no flax): layers, family assembly, decode path."""

from repro.models import decode, layers, model
from repro.models.decode import decode_step, init_cache
from repro.models.model import (
    count_params_analytic,
    forward,
    init_params,
    loss_fn,
)

__all__ = [
    "decode", "layers", "model",
    "decode_step", "init_cache",
    "count_params_analytic", "forward", "init_params", "loss_fn",
]
