"""ART-on-tensor-parallel transformer block (the paper's technique applied
to training, beyond-paper §Perf lever).

Runs *manually* over the "model" axis (partial-manual ``jax.shard_map``:
data axes stay GSPMD).  Every TP collective of the dense block is replaced
by a hand-scheduled ring from ``core.overlap`` — the gasnet_put chunk
pipeline of Sec. III-B:

  column-parallel QKV/up:  ``allgather_matmul``  (gather hidden under the
                           sub-matmuls, bidirectional ring)
  row-parallel O/down:     ``matmul_reducescatter`` (partial sums ride the
                           ring while the next sub-matmul runs — literally
                           Fig. 6(a) per layer)
  K/V broadcast:           ``ring_all_gather`` of the (small) S-sharded
                           K/V projections (GQA: n_kv < tp, so K/V are
                           computed outside and ring-gathered whole)

Structure note: norms and the K/V projections run OUTSIDE the manual
region (GSPMD), so every tensor the manual region differentiates is
tp-SHARDED — gradients w.r.t. *replicated* shard_map inputs trip an
XLA-CPU crash at 512 devices (minimal repro in EXPERIMENTS.md §Perf
notes), and replicated-input wgrads would psum over tp anyway.

Constraints: n_heads % tp == 0, d_ff % tp == 0, d_model % tp == 0,
S % tp == 0 (sequence-sharded residual).

When the conduit's ``matmul_schedule`` picks the ``fused`` family
(``TransportPolicy.tp="fused"``, or ``auto`` when the cost model favors
it), both TP edges run the in-kernel Pallas rings of
``kernels/cc_matmul`` instead — same schedule, hop consumed inside the
kernel, bit-identical outputs to the ``core.overlap`` path.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import netmodel as nm
from repro.core.conduit import Conduit
from repro.core.overlap import allgather_matmul, matmul_reducescatter
from repro.kernels.cc_matmul import (
    allgather_matmul_pallas,
    matmul_reducescatter_pallas,
)
from repro.models import layers as L

Params = Dict[str, Any]

#: default conduit for the manual TP regions: counter-rotating rings over
#: the "model" axis (what `TransportPolicy.tp="bidir"` resolves to).
DEFAULT_CONDUIT = Conduit(axis="model", transport="bidir")


def supports_art_tp(cfg: ModelConfig, tp: int) -> bool:
    if cfg.family not in ("dense", "vlm") or cfg.attn_type == "mla":
        return False
    if cfg.n_heads % tp != 0:
        return False
    if cfg.d_ff % tp != 0 or cfg.d_model % tp != 0:
        return False
    return True


def _resolve(conduit: Conduit | None, axis: str | None) -> Conduit:
    if conduit is not None:
        return conduit
    if axis is not None and axis != DEFAULT_CONDUIT.axis:
        return Conduit(axis=axis, transport="bidir")
    return DEFAULT_CONDUIT


def _edge_cost(op: str, x, w, conduit: Conduit):
    """(global payload bytes, modeled matmul seconds) of one TP edge —
    the inputs `Conduit.matmul_schedule` prices the schedule families on."""
    n = lax.axis_size(conduit.axis)
    item = jnp.dtype(x.dtype).itemsize
    b, s = x.shape[0], x.shape[-2]
    k, m = w.shape
    if op == "all_gather":
        size = int(x.size) * item * n
        flops = 2.0 * b * (s * n) * k * m
    else:
        size = b * s * m * item
        flops = 2.0 * b * s * k * m
    return size, flops / nm.MXU_BF16_FLOPS


def _vmap_ag(x, w, conduit: Conduit):
    size, tc = _edge_cost("all_gather", x, w, conduit)
    if conduit.matmul_schedule("all_gather", size, tc) == "fused":
        return allgather_matmul_pallas(
            x, w, axis=conduit.axis,
            bidirectional=conduit.matmul_bidirectional(size))
    return jax.vmap(lambda xb: allgather_matmul(xb, w, conduit=conduit))(x)


def _vmap_rs(x, w, conduit: Conduit):
    size, tc = _edge_cost("reduce_scatter", x, w, conduit)
    if conduit.matmul_schedule("reduce_scatter", size, tc) == "fused":
        return matmul_reducescatter_pallas(
            x, w, axis=conduit.axis,
            bidirectional=conduit.matmul_bidirectional(size))
    return jax.vmap(
        lambda xb: matmul_reducescatter(xb, w, conduit=conduit))(x)


def art_attention_part(cfg: ModelConfig, x, a_in, k_shard, v_shard,
                       wq, wo, positions, *, axis: str | None = None,
                       conduit: Conduit | None = None):
    """Manual region 1: QKV via ART rings + local-head attention + O ring.

    x, a_in: (B, S/tp, D) local; k_shard/v_shard: (B, S/tp, n_kv·hd);
    wq: (D, hq_loc·hd) column-local; wo: (hq_loc·hd, D) row-local.
    ``conduit`` selects the ring flavor (default: bidirectional rings over
    "model"); the legacy ``axis=`` spelling still works.
    """
    conduit = _resolve(conduit, axis)
    axis = conduit.axis
    tp = lax.axis_size(axis)
    my = lax.axis_index(axis)
    cd = jnp.dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    hq_loc = cfg.n_heads // tp
    b = x.shape[0]

    q = _vmap_ag(a_in.astype(cd), wq.astype(cd), conduit)  # (B, S, nq)
    s_full = q.shape[1]
    q = q.reshape(b, s_full, hq_loc, hd).transpose(0, 2, 1, 3)

    # gasnet-style K/V broadcast: ring-gather the sequence-sharded K/V
    k = jax.vmap(conduit.all_gather)(k_shard.astype(cd))
    v = jax.vmap(conduit.all_gather)(v_shard.astype(cd))
    n_kv = k.shape[-1] // hd
    k = k.reshape(b, s_full, n_kv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s_full, n_kv, hd).transpose(0, 2, 1, 3)

    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    group = cfg.n_heads // cfg.n_kv_heads
    q_global = my * hq_loc + jnp.arange(hq_loc)
    kv_idx = q_global // group
    k_sel = jnp.take(k, kv_idx, axis=1)        # (B, hq_loc, S, hd)
    v_sel = jnp.take(v, kv_idx, axis=1)

    out = L.blockwise_attention(
        q, k_sel, v_sel, causal=True, window=cfg.window,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        causal_skip=cfg.causal_block_skip)
    out = out.transpose(0, 2, 1, 3).reshape(b, s_full, hq_loc * hd)
    return x + _vmap_rs(out, wo.astype(cd), conduit).astype(x.dtype)


def art_mlp_part(cfg: ModelConfig, h, m_in, w_up, w_gate, w_down,
                 *, axis: str | None = None,
                 conduit: Conduit | None = None):
    """Manual region 2: gated MLP with AG/RS rings.  h, m_in local."""
    conduit = _resolve(conduit, axis)
    cd = jnp.dtype(cfg.compute_dtype)
    m_in = m_in.astype(cd)
    w_up = w_up.astype(cd)
    if w_gate is not None:
        up_cat = _vmap_ag(m_in, jnp.concatenate(
            [w_up, w_gate.astype(cd)], axis=1), conduit)
        f_loc = w_up.shape[1]
        act = L._act(cfg.activation, up_cat[..., f_loc:]) * up_cat[..., :f_loc]
    else:
        act = L._act(cfg.activation, _vmap_ag(m_in, w_up, conduit))
    return h + _vmap_rs(act, w_down.astype(cd), conduit).astype(h.dtype)
