"""Prefill: forward over a prompt that also materializes the decode cache.

``prefill_32k`` cells lower exactly this — a forward pass that returns
(populated cache, next-token logits).  The cache layouts match
``decode.init_cache`` exactly, so ``decode_step`` continues from a prefill
without reshaping (asserted by tests/test_serving.py).

Ring-buffer fill: the cache keeps the last ``sb`` positions
(``sb = decode.kv_buf_len(cfg, cap)``).  Position ``p`` lives at slot
``p % sb``; for ``S >= sb`` the slots hold positions ``[S−sb, S)`` as the
permutation ``slot j ← pos S−sb+((j−S) mod sb)``, and for ``S < sb`` slots
``[S, sb)`` stay empty (``slot_pos = −1`` masks them).

**Chunked streamed prefill** (:func:`prefill_chunked`): the prompt is split
into fixed-size chunks driven by ``core/pipeline.chunk_pipeline_carried``
— chunk *k*'s forward overlaps chunk *k−1*'s cache write (the paper's bulk
``gasnet_put`` of the prompt cache turned into an ART stream; on a
sequence-sharded cache the per-chunk ring scatter *is* the wire transfer).
Each chunk attends against a full-length K/V scratch with the chunk's
absolute ``q_offset``, so every row runs the exact bulk blockwise-softmax
recipe and the resulting cache is **bit-identical** to :func:`prefill`
(asserted by tests/test_serving.py, odd chunk sizes included).  The
incremental flavor (:func:`prefill_chunk` over :func:`init_prefill_scratch`
/ :func:`scratch_to_cache`) is what the continuous-batching server admits
between decode steps.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import pipeline as pl
from repro.models import layers as L
from repro.models.decode import kv_buf_len
from repro.models.model import (
    _lm_logits,
    _maybe_remat,
    encode,
)
from repro.models.shardctx import constrain

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _slot_map(s: int, sb: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (pos_for_slot (sb,) int32 with −1 empty, gather_idx (sb,))."""
    j = jnp.arange(sb)
    if s >= sb:
        pos = s - sb + ((j - s) % sb)
        return pos.astype(jnp.int32), pos.astype(jnp.int32)
    pos = jnp.where(j < s, j, -1)
    return pos.astype(jnp.int32), jnp.maximum(pos, 0).astype(jnp.int32)


def _ring_fill(seq_t: jnp.ndarray, sb: int, seq_axis: int):
    """Scatter a (..., S, ...) sequence tensor into its ring-buffer layout."""
    s = seq_t.shape[seq_axis]
    slot_pos, idx = _slot_map(s, sb)
    filled = jnp.take(seq_t, idx, axis=seq_axis)
    if s < sb:
        # zero the empty tail so the cache has no garbage (masked anyway)
        shape = [1] * seq_t.ndim
        shape[seq_axis] = sb
        mask = (slot_pos >= 0).reshape(shape)
        filled = jnp.where(mask, filled, jnp.zeros_like(filled))
    return filled, slot_pos


# ---------------------------------------------------------------------------
# family prefills
# ---------------------------------------------------------------------------


def _prefill_gqa(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        normed2 = L.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            h = h + L.moe(cfg, lp["moe"], normed2)
        else:
            h = h + L.mlp(cfg, lp["mlp"], normed2)
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (kc.astype(jnp.dtype(cfg.param_dtype)),
                                          vc.astype(jnp.dtype(cfg.param_dtype)))

    x, (ks, vs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"k": ks, "v": vs, "slot_pos": slot_pos}


def _prefill_mla(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (ckv, krope) = L.mla_attention(cfg, lp["attn"], normed, positions,
                                          return_cache=True)
        h = h + a
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        cc, _ = _ring_fill(ckv, sb, seq_axis=1)
        kr, _ = _ring_fill(krope, sb, seq_axis=1)
        return constrain(h, "residual"), (cc.astype(dt), kr.astype(dt))

    x, (cks, krs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ckv": cks, "krope": krs, "slot_pos": slot_pos}


def _prefill_ssm_stack(cfg: ModelConfig, stack: Params, x: jnp.ndarray):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln"], h)
        o, (state, conv_tail) = L.mamba2_block(cfg, lp["mamba"], normed,
                                               return_state=True)
        return constrain(h + o, "residual"), (state, conv_tail.astype(dt))

    return lax.scan(_maybe_remat(cfg, body), x, stack)


def _prefill_hybrid(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    n_shared = max(cfg.n_shared_blocks, 1)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), params["layers"])
    rest = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    shared = params["shared_blocks"]

    def group_body(carry, glayers):
        h, g = carry
        h, (st, cv) = _prefill_ssm_stack(cfg, glayers, h)
        sel = jax.tree.map(lambda a: a[g % n_shared], shared)
        normed = L.apply_norm(cfg, sel["ln1"], h)
        a, (k, v) = L.attention(cfg, sel["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        h = h + L.mlp(cfg, sel["mlp"], L.apply_norm(cfg, sel["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return (constrain(h, "residual"), g + 1), (
            st, cv, kc.astype(dt), vc.astype(dt))

    (x, _), (sts, cvs, ks, vs) = lax.scan(
        _maybe_remat(cfg, group_body), (x, jnp.int32(0)), grouped)
    ssm_state = sts.reshape((n_groups * period,) + sts.shape[2:])
    conv_state = cvs.reshape((n_groups * period,) + cvs.shape[2:])
    if n_rem:
        x, (rst, rcv) = _prefill_ssm_stack(cfg, rest, x)
        ssm_state = jnp.concatenate([ssm_state, rst], axis=0)
        conv_state = jnp.concatenate([conv_state, rcv], axis=0)
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ssm_state": ssm_state, "conv_state": conv_state,
               "attn_k": ks, "attn_v": vs, "slot_pos": slot_pos}


def _prefill_encdec(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                    frontend_embeds: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    enc = encode(cfg, params, frontend_embeds)
    x = jnp.take(params["embed"], tokens, axis=0)
    s = x.shape[1]
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], 0, s, 0).astype(x.dtype)
    dpos = jnp.arange(s)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, dpos,
                                return_kv=True)
        h = h + a
        kv = L.cross_kv(cfg, lp["xattn"], enc)
        h = h + L.attention(cfg, lp["xattn"],
                            L.apply_norm(cfg, lp["ln_x"], h),
                            dpos, causal=False, kv_override=kv)
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (
            kc.astype(dt), vc.astype(dt),
            kv[0].astype(dt), kv[1].astype(dt))

    x, (ks, vs, xks, xvs) = lax.scan(_maybe_remat(cfg, body), x,
                                     params["dec_layers"])
    slot_pos, _ = _slot_map(s, sb)
    return x, {"k": ks, "v": vs, "cross_k": xks, "cross_v": xvs,
               "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,
    *,
    cache_len: Optional[int] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Run the prompt, build the decode cache, return next-token logits.

    ``cache_len``: ring-buffer capacity (default: prompt length); the SWA
    window caps it (h2o-danube long contexts keep a 4096-slot cache).
    """
    from repro.models.model import _embed

    if cfg.family == "encdec":
        s = tokens.shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        x, cache = _prefill_encdec(cfg, params, tokens, frontend_embeds, sb)
        s_total = s
    else:
        x = constrain(_embed(cfg, params, tokens, frontend_embeds), "residual")
        s_total = x.shape[1]
        sb = kv_buf_len(cfg, cache_len or s_total)
        positions = jnp.arange(s_total)
        if cfg.family in ("dense", "vlm", "moe") and cfg.attn_type != "mla":
            x, cache = _prefill_gqa(cfg, params, x, positions, sb)
        elif cfg.attn_type == "mla":
            x, cache = _prefill_mla(cfg, params, x, positions, sb)
        elif cfg.family == "ssm":
            x, (st, cv) = _prefill_ssm_stack(cfg, params["layers"], x)
            cache = {"ssm_state": st, "conv_state": cv}
        elif cfg.family == "hybrid":
            x, cache = _prefill_hybrid(cfg, params, x, positions, sb)
        else:
            raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    last = constrain(x[:, -1:, :], "logit_hidden")
    logits = _lm_logits(cfg, params, last)[:, 0]
    return _finish_cache(cache, tokens.shape[0], s_total), logits


def _finish_cache(cache: Cache, batch: int, s_total: int) -> Cache:
    """Stamp the per-slot position bookkeeping (every row at ``s_total``)."""
    cache["pos"] = jnp.full((batch,), s_total, jnp.int32)
    if "slot_pos" in cache:
        cache["slot_pos"] = jnp.broadcast_to(
            cache["slot_pos"], (batch,) + cache["slot_pos"].shape[-1:])
    return cache


# ---------------------------------------------------------------------------
# chunked streamed prefill (the ART schedule on the prompt hot path)
# ---------------------------------------------------------------------------


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Whether the arch can take the chunked streamed prefill path.

    Requires the GQA ring-buffer cache (dense/vlm non-MLA families; MoE
    capacity is bookkept per call, so chunking would change its drop set)
    and the blockwise attention impl (the ``q_offset`` convention only
    exists there).  Everything else falls back to bulk :func:`prefill` —
    same numerics, one chunk.
    """
    return (cfg.family in ("dense", "vlm") and cfg.attn_type != "mla"
            and L.resolve_attn_impl(cfg) == "jnp")


def prefill_chunk_cuts(s_total: int, chunk_len: Optional[int] = None,
                       n_chunks: Optional[int] = None
                       ) -> List[Tuple[int, int]]:
    """Static ``(lo, hi)`` chunk boundaries over a prompt of ``s_total``.

    ``chunk_len`` cuts fixed-size chunks (ragged tail); ``n_chunks``
    delegates to ``pipeline.chunk_slices`` (near-equal cuts).  Neither
    (or a chunk covering the prompt) means one bulk chunk.
    """
    if chunk_len:
        c = max(1, int(chunk_len))
        return [(lo, min(lo + c, s_total)) for lo in range(0, s_total, c)]
    return pl.chunk_slices(s_total, max(1, int(n_chunks or 1)))


def init_prefill_scratch(cfg: ModelConfig, batch: int,
                         prompt_len: int) -> Cache:
    """Full-length K/V scratch one incremental prefill writes into.

    Compute-dtype (the cast to the cache's param dtype happens at the ring
    fill, exactly where bulk prefill casts), allocated at the prompt length
    so chunked attention reduces over the same key extent as bulk — the
    structural bit-identity argument of this module's docstring.
    """
    assert supports_chunked_prefill(cfg), cfg.name
    hd = cfg.resolved_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, prompt_len, hd)
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd),
            "pos": jnp.zeros((batch,), jnp.int32)}


def _chunk_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     kbuf: jnp.ndarray, vbuf: jnp.ndarray, lo: int):
    """The chunk-rows flavor of ``layers.attention``: q from the chunk,
    K/V written into (and attended against) the full-length scratch at the
    static offset ``lo`` — per-row the exact bulk recipe."""
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    positions = lo + jnp.arange(c)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(cd))
    q = q.reshape(b, c, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", xc, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dh->bsh", xc, p["wv"].astype(cd))
    k = k.reshape(b, c, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, c, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    kbuf = lax.dynamic_update_slice_in_dim(kbuf, k, lo, axis=2)
    vbuf = lax.dynamic_update_slice_in_dim(vbuf, v, lo, axis=2)
    out = L.attention_core(cfg, q, kbuf, vbuf, causal=True,
                           window=cfg.window, q_offset=lo)
    out = out.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out,
                   p["wo"].astype(cd)).astype(x.dtype)
    return y, kbuf, vbuf


def _chunk_body(cfg: ModelConfig, params: Params, ks: jnp.ndarray,
                vs: jnp.ndarray, x: jnp.ndarray, lo: int):
    """One chunk's forward through every layer.  ``ks``/``vs``:
    (L, B, Hkv, S, hd) compute-dtype scratch; ``x``: (B, C, D) embedded
    chunk rows at absolute positions ``[lo, lo+C)``.  Returns
    ``(ks', vs', h)`` with the chunk's K/V written in."""
    def body(h, layer):
        lp, kbuf, vbuf = layer
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, kbuf, vbuf = _chunk_attention(cfg, lp["attn"], normed,
                                         kbuf, vbuf, lo)
        h = h + a
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        return constrain(h, "residual"), (kbuf, vbuf)

    h, (ks, vs) = lax.scan(_maybe_remat(cfg, body), x,
                           (params["layers"], ks, vs))
    return ks, vs, h


def _chunk_logits(cfg: ModelConfig, params: Params,
                  h: jnp.ndarray) -> jnp.ndarray:
    x = L.apply_norm(cfg, params["final_norm"], h)
    last = constrain(x[:, -1:, :], "logit_hidden")
    return _lm_logits(cfg, params, last)[:, 0]


def prefill_chunk(cfg: ModelConfig, params: Params, scratch: Cache,
                  tokens: jnp.ndarray, lo: int
                  ) -> Tuple[Cache, jnp.ndarray]:
    """One incremental prefill chunk (the server's admission step).

    ``tokens``: (B, C) — the prompt slice ``[lo, lo+C)``; ``lo`` is static
    (each (chunk shape, offset) pair is its own jitted program, which is
    what keeps the path bit-identical to bulk).  Returns the updated
    scratch and the chunk's next-token logits (meaningful once the final
    chunk has run).
    """
    from repro.models.model import _embed

    x = constrain(_embed(cfg, params, tokens, None), "residual")
    ks, vs, h = _chunk_body(cfg, params, scratch["k"], scratch["v"], x, lo)
    hi = lo + tokens.shape[1]
    new = {"k": ks, "v": vs,
           "pos": jnp.full_like(scratch["pos"], hi)}
    return new, _chunk_logits(cfg, params, h)


def scratch_to_cache(cfg: ModelConfig, scratch: Cache,
                     cache_len: Optional[int] = None) -> Cache:
    """Ring-fill a *completed* prefill scratch into the decode-cache layout
    — bit-identical to the cache bulk :func:`prefill` builds."""
    dt = jnp.dtype(cfg.param_dtype)
    s = scratch["k"].shape[3]
    batch = scratch["k"].shape[1]
    sb = kv_buf_len(cfg, cache_len or s)
    kc, _ = _ring_fill(scratch["k"], sb, seq_axis=3)
    vc, _ = _ring_fill(scratch["v"], sb, seq_axis=3)
    slot_pos, _ = _slot_map(s, sb)
    cache = {"k": kc.astype(dt), "v": vc.astype(dt), "slot_pos": slot_pos}
    return _finish_cache(cache, batch, s)


# ---------------------------------------------------------------------------
# paged KV block pool (PR 6): slot cache <-> pool blocks
# ---------------------------------------------------------------------------


def cache_to_blocks(cfg: ModelConfig, slot_cache: Cache, block_size: int):
    """Split a single-request ring cache into pool blocks.

    ``slot_cache``: the batch-1 cache :func:`prefill` /
    :func:`scratch_to_cache` builds (``k``/``v`` (L, 1, Hkv, sb, hd)).
    Returns ``(blocks_k, blocks_v, slot_pos_row, pos_row)`` with blocks
    shaped (L, sb/blk, Hkv, blk, hd) — a pure reshape of the ring layout
    (``block_size`` must divide ``sb``), so pushing the blocks into a
    pool and gathering them back via the block table reproduces the
    contiguous cache bit for bit.  These are the "finished chunk-blocks"
    a prefill rank PUTs into the decode pool (``core/pgas.BlockSegment``
    prices the one-sided writes).
    """
    k = slot_cache["k"]
    nl, b1, hkv, sb, hd = k.shape
    assert b1 == 1, k.shape
    if sb % block_size:
        raise ValueError(
            f"block_size {block_size} must divide the ring extent {sb}")
    npb = sb // block_size

    def split(a):
        blocks = a[:, 0].reshape(nl, hkv, npb, block_size, hd)
        return blocks.transpose(0, 2, 1, 3, 4)

    return (split(k), split(slot_cache["v"]),
            slot_cache["slot_pos"][0], slot_cache["pos"][0])


def scratch_to_blocks(cfg: ModelConfig, scratch: Cache, block_size: int,
                      cache_len: Optional[int] = None):
    """Ring-fill a completed prefill scratch straight into pool blocks
    (:func:`scratch_to_cache` composed with :func:`cache_to_blocks` —
    the paged flavor of the server's admission conversion)."""
    return cache_to_blocks(cfg, scratch_to_cache(cfg, scratch,
                                                 cache_len=cache_len),
                           block_size)


def seed_scratch_from_blocks(cfg: ModelConfig, scratch: Cache,
                             blocks_k: jnp.ndarray,
                             blocks_v: jnp.ndarray) -> Cache:
    """Seed a fresh prefill scratch with ``m`` cached prefix blocks.

    The prefix-cache hit path: positions ``[0, m·blk)`` of the scratch
    are restored from pool blocks instead of recomputed, and chunked
    prefill resumes at the first uncached chunk.  Valid only while the
    cached prefix never wrapped the ring (slot ``j`` == position ``j`` —
    the server's sharing guard), and bit-exact when the pool dtype equals
    the compute dtype (the reduced/test configs; otherwise the prefix
    K/V round-trips through the param dtype, ulp-level like any
    cross-program reshard).
    """
    nl, m, hkv, blk, hd = blocks_k.shape
    cd = jnp.dtype(cfg.compute_dtype)

    def merge(buf, blocks):
        flat = blocks.transpose(0, 2, 1, 3, 4).reshape(nl, hkv, m * blk, hd)
        return lax.dynamic_update_slice_in_dim(
            buf, flat[:, None].astype(cd), 0, axis=3)

    return dict(scratch, k=merge(scratch["k"], blocks_k),
                v=merge(scratch["v"], blocks_v))


def prefill_chunked(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,
    *,
    cache_len: Optional[int] = None,
    chunk_len: Optional[int] = None,
    n_chunks: Optional[int] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Chunked streamed prefill: :func:`prefill`, as an ART pipeline.

    The prompt runs in fixed-size chunks through
    ``pipeline.chunk_pipeline_carried``: chunk *k*'s forward (the carried
    compute) overlaps chunk *k−1*'s ring-cache scatter (the transfer — on a
    sequence-sharded cache that scatter is the wire write, the bulk
    ``gasnet_put`` of the paper's serving shape split into ART chunks).
    Cache and logits are bit-identical to bulk :func:`prefill` — every row
    runs the same blockwise recipe against the same key extent (module
    docstring) — asserted across odd chunk sizes by tests/test_serving.py.

    Archs outside :func:`supports_chunked_prefill` fall back to bulk.
    """
    from repro.models.model import _embed

    s_total = (tokens.shape[1] + (cfg.frontend_tokens
                                  if cfg.frontend and cfg.family == "vlm"
                                  else 0))
    cuts = prefill_chunk_cuts(s_total, chunk_len, n_chunks)
    if len(cuts) <= 1 or not supports_chunked_prefill(cfg):
        return prefill(cfg, params, tokens, frontend_embeds,
                       cache_len=cache_len)

    batch = tokens.shape[0]
    dt = jnp.dtype(cfg.param_dtype)
    sb = kv_buf_len(cfg, cache_len or s_total)
    x_full = constrain(_embed(cfg, params, tokens, frontend_embeds),
                       "residual")
    scratch = init_prefill_scratch(cfg, batch, s_total)

    def compute(k, carry):
        ks, vs = carry
        lo, hi = cuts[k]
        ks, vs, h = _chunk_body(cfg, params, ks, vs, x_full[:, lo:hi], lo)
        # the payload the "wire" carries: this chunk's K/V slab (+ the
        # residual tail that only the final chunk's logits consume)
        return (ks[:, :, :, lo:hi], vs[:, :, :, lo:hi], h), (ks, vs)

    def consume(state, k, arrived):
        ring_k, ring_v, _ = state
        ck, cv, h = arrived
        lo, hi = cuts[k]
        # ring slots of positions [lo, hi); a chunk longer than the ring
        # keeps only its last sb positions (earlier ones would be
        # overwritten within the chunk anyway)
        first = max(lo, hi - sb)
        slots = jnp.asarray([p % sb for p in range(first, hi)], jnp.int32)
        ring_k = ring_k.at[:, :, :, slots].set(
            ck[:, :, :, first - lo:].astype(dt))
        ring_v = ring_v.at[:, :, :, slots].set(
            cv[:, :, :, first - lo:].astype(dt))
        return ring_k, ring_v, h

    hd = cfg.resolved_head_dim
    ring_shape = (cfg.n_layers, batch, cfg.n_kv_heads, sb, hd)
    init = (jnp.zeros(ring_shape, dt), jnp.zeros(ring_shape, dt), None)
    (ring_k, ring_v, h_last), _ = pl.chunk_pipeline_carried(
        len(cuts), compute, lambda k, payload: payload, consume,
        carry=(scratch["k"], scratch["v"]), init=init)

    slot_pos, _ = _slot_map(s_total, sb)
    cache = _finish_cache(
        {"k": ring_k, "v": ring_v, "slot_pos": slot_pos}, batch, s_total)
    return cache, _chunk_logits(cfg, params, h_last)
