"""Prefill: forward over a prompt that also materializes the decode cache.

``prefill_32k`` cells lower exactly this — a forward pass that returns
(populated cache, next-token logits).  The cache layouts match
``decode.init_cache`` exactly, so ``decode_step`` continues from a prefill
without reshaping (asserted by tests/test_serving.py).

Ring-buffer fill: the cache keeps the last ``sb`` positions
(``sb = decode.kv_buf_len(cfg, cap)``).  Position ``p`` lives at slot
``p % sb``; for ``S >= sb`` the slots hold positions ``[S−sb, S)`` as the
permutation ``slot j ← pos S−sb+((j−S) mod sb)``, and for ``S < sb`` slots
``[S, sb)`` stay empty (``slot_pos = −1`` masks them).

**Chunked streamed prefill** (:func:`prefill_chunked`): the prompt is split
into fixed-size chunks driven by ``core/pipeline.chunk_pipeline_carried``
— chunk *k*'s forward overlaps chunk *k−1*'s cache write (the paper's bulk
``gasnet_put`` of the prompt cache turned into an ART stream; on a
sequence-sharded cache the per-chunk ring scatter *is* the wire transfer).
Each chunk attends against a full-length K/V scratch with the chunk's
absolute ``q_offset``, so every row runs the exact bulk blockwise-softmax
recipe and the resulting cache is **bit-identical** to :func:`prefill`
(asserted by tests/test_serving.py, odd chunk sizes included).  The
incremental flavor (:func:`prefill_chunk` over :func:`init_prefill_scratch`
/ :func:`scratch_to_cache`) is what the continuous-batching server admits
between decode steps.

**The chunk-carry contract** (``configs.base.chunk_carry_spec``) makes that
path total over the config zoo — every family defines what a chunk hands to
the next one, and this module implements the triple per carry kind:

* ``ring`` (GQA dense / vlm / moe) — full-length K/V scratch rows, as
  above; vlm chunks slice the frontend-embedding rows exactly like the
  bulk concat (both are row-wise).  MoE rides the same carry with
  **chunk-local capacity**: ``layers.moe_route`` bookkeeps capacity per
  call, so each chunk's drop set is computed from the chunk length —
  :func:`moe_chunk_agree_mask` states (and tests/test_zoo.py asserts) the
  equivalence bound: each MoE layer's output is bitwise equal at every
  token whose keep decisions match, and the whole forward is exact when
  they match everywhere — in particular when no row overflows either
  program.
* ``latent`` (MLA) — full-length latent ``ckv`` + shared rope-key rows;
  per-head K/V are re-expanded from the scratch each chunk (rows past the
  chunk are zeros, and causally masked contributions are *exactly* zero in
  the blockwise recipe, so the reduction is bulk's).
* ``state`` (mamba2) — **constant-size** carry: the per-layer SSD state
  (the ``ssd`` kernel's ``init_state`` resume hook) plus the (conv−1) raw
  pre-conv rows.  Bit-identical to bulk whenever interior cuts land on
  multiples of ``ssm_chunk`` (the SSD chunk walk visits the same blocks;
  ``ChunkCarrySpec.chunk_multiple`` says so and
  :func:`prefill_chunk_cuts` aligns cuts to it).
* ``hybrid`` (zamba2) — the ``state`` pair per layer plus ring rows for
  the shared attention blocks.
* ``encdec`` (whisper) — chunk 0 runs the encoder once and materializes
  the cross-K/V; decoder chunks then stream like ``ring`` rows (no rope,
  learned positions sliced at the chunk offset).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import (
    ChunkCarrySpec,
    ModelConfig,
    chunk_carry_spec,
    serving_features,
)
from repro.core import pipeline as pl
from repro.models import layers as L
from repro.models.decode import kv_buf_len
from repro.models.model import (
    _lm_logits,
    _maybe_remat,
    encode,
)
from repro.models.shardctx import constrain

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _slot_map(s: int, sb: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (pos_for_slot (sb,) int32 with −1 empty, gather_idx (sb,))."""
    j = jnp.arange(sb)
    if s >= sb:
        pos = s - sb + ((j - s) % sb)
        return pos.astype(jnp.int32), pos.astype(jnp.int32)
    pos = jnp.where(j < s, j, -1)
    return pos.astype(jnp.int32), jnp.maximum(pos, 0).astype(jnp.int32)


def _ring_fill(seq_t: jnp.ndarray, sb: int, seq_axis: int):
    """Scatter a (..., S, ...) sequence tensor into its ring-buffer layout."""
    s = seq_t.shape[seq_axis]
    slot_pos, idx = _slot_map(s, sb)
    filled = jnp.take(seq_t, idx, axis=seq_axis)
    if s < sb:
        # zero the empty tail so the cache has no garbage (masked anyway)
        shape = [1] * seq_t.ndim
        shape[seq_axis] = sb
        mask = (slot_pos >= 0).reshape(shape)
        filled = jnp.where(mask, filled, jnp.zeros_like(filled))
    return filled, slot_pos


# ---------------------------------------------------------------------------
# family prefills
# ---------------------------------------------------------------------------


def _prefill_gqa(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        normed2 = L.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            h = h + L.moe(cfg, lp["moe"], normed2)
        else:
            h = h + L.mlp(cfg, lp["mlp"], normed2)
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (kc.astype(jnp.dtype(cfg.param_dtype)),
                                          vc.astype(jnp.dtype(cfg.param_dtype)))

    x, (ks, vs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"k": ks, "v": vs, "slot_pos": slot_pos}


def _prefill_mla(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (ckv, krope) = L.mla_attention(cfg, lp["attn"], normed, positions,
                                          return_cache=True)
        h = h + a
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        cc, _ = _ring_fill(ckv, sb, seq_axis=1)
        kr, _ = _ring_fill(krope, sb, seq_axis=1)
        return constrain(h, "residual"), (cc.astype(dt), kr.astype(dt))

    x, (cks, krs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ckv": cks, "krope": krs, "slot_pos": slot_pos}


def _prefill_ssm_stack(cfg: ModelConfig, stack: Params, x: jnp.ndarray):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln"], h)
        o, (state, conv_tail) = L.mamba2_block(cfg, lp["mamba"], normed,
                                               return_state=True)
        return constrain(h + o, "residual"), (state, conv_tail.astype(dt))

    return lax.scan(_maybe_remat(cfg, body), x, stack)


def _prefill_hybrid(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    n_shared = max(cfg.n_shared_blocks, 1)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), params["layers"])
    rest = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    shared = params["shared_blocks"]

    def group_body(carry, glayers):
        h, g = carry
        h, (st, cv) = _prefill_ssm_stack(cfg, glayers, h)
        sel = jax.tree.map(lambda a: a[g % n_shared], shared)
        normed = L.apply_norm(cfg, sel["ln1"], h)
        a, (k, v) = L.attention(cfg, sel["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        h = h + L.mlp(cfg, sel["mlp"], L.apply_norm(cfg, sel["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return (constrain(h, "residual"), g + 1), (
            st, cv, kc.astype(dt), vc.astype(dt))

    (x, _), (sts, cvs, ks, vs) = lax.scan(
        _maybe_remat(cfg, group_body), (x, jnp.int32(0)), grouped)
    ssm_state = sts.reshape((n_groups * period,) + sts.shape[2:])
    conv_state = cvs.reshape((n_groups * period,) + cvs.shape[2:])
    if n_rem:
        x, (rst, rcv) = _prefill_ssm_stack(cfg, rest, x)
        ssm_state = jnp.concatenate([ssm_state, rst], axis=0)
        conv_state = jnp.concatenate([conv_state, rcv], axis=0)
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ssm_state": ssm_state, "conv_state": conv_state,
               "attn_k": ks, "attn_v": vs, "slot_pos": slot_pos}


def _prefill_encdec(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                    frontend_embeds: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    enc = encode(cfg, params, frontend_embeds)
    x = jnp.take(params["embed"], tokens, axis=0)
    s = x.shape[1]
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], 0, s, 0).astype(x.dtype)
    dpos = jnp.arange(s)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, dpos,
                                return_kv=True)
        h = h + a
        kv = L.cross_kv(cfg, lp["xattn"], enc)
        h = h + L.attention(cfg, lp["xattn"],
                            L.apply_norm(cfg, lp["ln_x"], h),
                            dpos, causal=False, kv_override=kv)
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (
            kc.astype(dt), vc.astype(dt),
            kv[0].astype(dt), kv[1].astype(dt))

    x, (ks, vs, xks, xvs) = lax.scan(_maybe_remat(cfg, body), x,
                                     params["dec_layers"])
    slot_pos, _ = _slot_map(s, sb)
    return x, {"k": ks, "v": vs, "cross_k": xks, "cross_v": xvs,
               "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,
    *,
    cache_len: Optional[int] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Run the prompt, build the decode cache, return next-token logits.

    ``cache_len``: ring-buffer capacity (default: prompt length); the SWA
    window caps it (h2o-danube long contexts keep a 4096-slot cache).
    """
    from repro.models.model import _embed

    if cfg.family == "encdec":
        s = tokens.shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        x, cache = _prefill_encdec(cfg, params, tokens, frontend_embeds, sb)
        s_total = s
    else:
        x = constrain(_embed(cfg, params, tokens, frontend_embeds), "residual")
        s_total = x.shape[1]
        sb = kv_buf_len(cfg, cache_len or s_total)
        positions = jnp.arange(s_total)
        if cfg.family in ("dense", "vlm", "moe") and cfg.attn_type != "mla":
            x, cache = _prefill_gqa(cfg, params, x, positions, sb)
        elif cfg.attn_type == "mla":
            x, cache = _prefill_mla(cfg, params, x, positions, sb)
        elif cfg.family == "ssm":
            x, (st, cv) = _prefill_ssm_stack(cfg, params["layers"], x)
            cache = {"ssm_state": st, "conv_state": cv}
        elif cfg.family == "hybrid":
            x, cache = _prefill_hybrid(cfg, params, x, positions, sb)
        else:
            raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    last = constrain(x[:, -1:, :], "logit_hidden")
    logits = _lm_logits(cfg, params, last)[:, 0]
    return _finish_cache(cache, tokens.shape[0], s_total), logits


def _finish_cache(cache: Cache, batch: int, s_total: int) -> Cache:
    """Stamp the per-slot position bookkeeping (every row at ``s_total``)."""
    cache["pos"] = jnp.full((batch,), s_total, jnp.int32)
    if "slot_pos" in cache:
        cache["slot_pos"] = jnp.broadcast_to(
            cache["slot_pos"], (batch,) + cache["slot_pos"].shape[-1:])
    return cache


# ---------------------------------------------------------------------------
# chunked streamed prefill (the ART schedule on the prompt hot path)
# ---------------------------------------------------------------------------


def chunk_support(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether streamed prefill can run, with the fallback reason if not.

    The chunk-carry contract itself is total over the zoo
    (:func:`repro.configs.base.chunk_carry_spec`); the one thing that can
    gate it out at *runtime* is the attention kernel: every
    attention-bearing carry kind needs the blockwise ``jnp`` path, whose
    mid-sequence ``q_offset`` convention is what makes a chunk's rows run
    the exact bulk recipe.  Pure SSM has no attention and chunks under any
    impl.  Callers that fall back must say so (the server emits a build
    warning and a ``stats()`` signal with this reason).
    """
    spec = chunk_carry_spec(cfg)
    if spec.kind != "state":
        impl = L.resolve_attn_impl(cfg)
        if impl != "jnp":
            return False, (
                f"attn_impl resolves to {impl!r}; chunked prefill needs the "
                f"blockwise jnp path (mid-sequence q_offset)")
    return True, ""


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Boolean face of :func:`chunk_support` (capability rows live in
    ``configs.base.serving_features``; this is the runtime kernel gate)."""
    return chunk_support(cfg)[0]


def prefill_chunk_cuts(s_total: int, chunk_len: Optional[int] = None,
                       n_chunks: Optional[int] = None, *,
                       multiple: int = 1) -> List[Tuple[int, int]]:
    """Static ``(lo, hi)`` chunk boundaries over a prompt of ``s_total``.

    ``chunk_len`` cuts fixed-size chunks (ragged tail); ``n_chunks``
    delegates to ``pipeline.chunk_slices`` (near-equal cuts).  Neither
    (or a chunk covering the prompt) means one bulk chunk.

    ``multiple``: every *interior* cut lands on a multiple of it (the
    carry contract's ``chunk_multiple`` — SSD state hand-off is bit-exact
    only on ``ssm_chunk`` boundaries).  ``chunk_len`` rounds up to the
    multiple; ``n_chunks`` boundaries snap down to it (dropping cuts that
    collide — the chunk count may shrink, coverage never changes).  Both
    spellings tile ``[0, s_total)`` exactly once for every input.
    """
    m = max(1, int(multiple))
    if chunk_len:
        c = -(-max(1, int(chunk_len)) // m) * m
        return [(lo, min(lo + c, s_total)) for lo in range(0, s_total, c)]
    cuts = pl.chunk_slices(s_total, max(1, int(n_chunks or 1)))
    if m > 1 and len(cuts) > 1:
        snapped = sorted({(hi // m) * m for _, hi in cuts[:-1]})
        edges = [0] + [b for b in snapped if 0 < b < s_total] + [s_total]
        cuts = list(zip(edges[:-1], edges[1:]))
    return cuts


def _ssm_scratch(cfg: ModelConfig, n_layers: int, batch: int
                 ) -> Dict[str, jnp.ndarray]:
    """The constant-size state carry: per-layer SSD state (fp32, as the
    kernel accumulates) + the (conv−1) raw pre-conv rows (compute dtype,
    as the conv consumes them)."""
    cd = jnp.dtype(cfg.compute_dtype)
    conv_ch = (cfg.ssm_heads * cfg.ssm_head_dim
               + 2 * cfg.ssm_groups * cfg.ssm_state)
    return {
        "ssm_state": jnp.zeros(
            (n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
             cfg.ssm_head_dim), jnp.float32),
        "conv_state": jnp.zeros(
            (n_layers, batch, cfg.ssm_conv - 1, conv_ch), cd),
    }


def init_prefill_scratch(cfg: ModelConfig, batch: int,
                         prompt_len: int) -> Cache:
    """The chunk-carry scratch one incremental prefill writes into.

    Per-family layout (the ``kind`` of :func:`chunk_carry_spec`):

    * ``ring`` — full-length K/V, compute dtype (the cast to the cache's
      param dtype happens at the ring fill, exactly where bulk casts);
    * ``latent`` — full-length ``ckv`` + rope-key rows;
    * ``state`` — :func:`_ssm_scratch` only: **constant size**, the
      ``prompt_len`` argument is deliberately unused;
    * ``hybrid`` — the state pair + per-shared-application ring rows;
    * ``encdec`` — decoder K/V + the one-time cross-K/V extent.

    Full-length attention scratch is what lets every chunk reduce over the
    same key extent as bulk — the structural bit-identity argument of this
    module's docstring.
    """
    ok, why = chunk_support(cfg)
    assert ok, f"{cfg.name}: {why}"
    cd = jnp.dtype(cfg.compute_dtype)
    pos = {"pos": jnp.zeros((batch,), jnp.int32)}
    spec = chunk_carry_spec(cfg)
    if spec.kind == "state":
        return {**_ssm_scratch(cfg, cfg.n_layers, batch), **pos}
    hd = cfg.resolved_head_dim
    kv_shape = (cfg.n_layers, batch, cfg.n_kv_heads, prompt_len, hd)
    if spec.kind == "latent":
        return {"ckv": jnp.zeros((cfg.n_layers, batch, prompt_len,
                                  cfg.kv_lora_rank), cd),
                "krope": jnp.zeros((cfg.n_layers, batch, prompt_len,
                                    cfg.qk_rope_dim), cd), **pos}
    if spec.kind == "hybrid":
        n_app = cfg.n_layers // cfg.hybrid_period
        app_shape = (n_app, batch, cfg.n_kv_heads, prompt_len, hd)
        return {**_ssm_scratch(cfg, cfg.n_layers, batch),
                "attn_k": jnp.zeros(app_shape, cd),
                "attn_v": jnp.zeros(app_shape, cd), **pos}
    if spec.kind == "encdec":
        xshape = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_seq, hd)
        return {"k": jnp.zeros(kv_shape, cd), "v": jnp.zeros(kv_shape, cd),
                "cross_k": jnp.zeros(xshape, cd),
                "cross_v": jnp.zeros(xshape, cd), **pos}
    return {"k": jnp.zeros(kv_shape, cd), "v": jnp.zeros(kv_shape, cd),
            **pos}


def _chunk_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     kbuf: jnp.ndarray, vbuf: jnp.ndarray, lo: int):
    """The chunk-rows flavor of ``layers.attention``: q from the chunk,
    K/V written into (and attended against) the full-length scratch at the
    static offset ``lo`` — per-row the exact bulk recipe (including the
    encdec no-rope convention: whisper uses learned positions only)."""
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    positions = lo + jnp.arange(c)
    q = jnp.einsum("bsd,dh->bsh", xc, p["wq"].astype(cd))
    q = q.reshape(b, c, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,dh->bsh", xc, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dh->bsh", xc, p["wv"].astype(cd))
    k = k.reshape(b, c, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, c, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.family != "encdec":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    kbuf = lax.dynamic_update_slice_in_dim(kbuf, k, lo, axis=2)
    vbuf = lax.dynamic_update_slice_in_dim(vbuf, v, lo, axis=2)
    out = L.attention_core(cfg, q, kbuf, vbuf, causal=True,
                           window=cfg.window, q_offset=lo)
    out = out.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out,
                   p["wo"].astype(cd)).astype(x.dtype)
    return y, kbuf, vbuf


def _chunk_body(cfg: ModelConfig, params: Params, ks: jnp.ndarray,
                vs: jnp.ndarray, x: jnp.ndarray, lo: int):
    """One chunk's forward through every layer.  ``ks``/``vs``:
    (L, B, Hkv, S, hd) compute-dtype scratch; ``x``: (B, C, D) embedded
    chunk rows at absolute positions ``[lo, lo+C)``.  Returns
    ``(ks', vs', h)`` with the chunk's K/V written in."""
    def body(h, layer):
        lp, kbuf, vbuf = layer
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, kbuf, vbuf = _chunk_attention(cfg, lp["attn"], normed,
                                         kbuf, vbuf, lo)
        h = h + a
        normed2 = L.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            # chunk-local capacity: moe_route sees this chunk's rows only,
            # so its capacity bookkeeping is per chunk — the documented
            # exact-iff-no-overflow bound (moe_chunk_agree_mask)
            h = h + L.moe(cfg, lp["moe"], normed2)
        else:
            h = h + L.mlp(cfg, lp["mlp"], normed2)
        return constrain(h, "residual"), (kbuf, vbuf)

    h, (ks, vs) = lax.scan(_maybe_remat(cfg, body), x,
                           (params["layers"], ks, vs))
    return ks, vs, h


def _chunk_mla_attention(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                         cbuf: jnp.ndarray, kbuf: jnp.ndarray, lo: int):
    """The chunk-rows flavor of ``layers.mla_attention``: the chunk's
    latent rows land in the full-length scratch, per-head K/V are
    re-expanded from the *whole* scratch (zero rows past the chunk expand
    to zero keys/values, all causally masked — exact no-ops in the
    blockwise recipe), and q attends at the absolute offset."""
    b, c, _ = x.shape
    h, dn, dr, dv = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    r = cfg.kv_lora_rank
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    positions = lo + jnp.arange(c)

    q_lat = L.rms_norm(p["q_norm"], xc @ p["w_dq"].astype(cd), cfg.norm_eps)
    q = (q_lat @ p["w_uq"].astype(cd)).reshape(b, c, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope.transpose(0, 2, 1, 3), positions,
                          cfg.rope_theta)

    dkv = xc @ p["w_dkv"].astype(cd)
    c_kv = L.rms_norm(p["kv_norm"], dkv[..., :r], cfg.norm_eps)
    k_rope = L.apply_rope(dkv[..., r:][:, None], positions, cfg.rope_theta)
    cbuf = lax.dynamic_update_slice_in_dim(cbuf, c_kv, lo, axis=1)
    kbuf = lax.dynamic_update_slice_in_dim(kbuf, k_rope[:, 0], lo, axis=1)

    s_full = cbuf.shape[1]
    k_nope = (cbuf @ p["w_uk"].astype(cd)).reshape(b, s_full, h, dn)
    vfull = (cbuf @ p["w_uv"].astype(cd)).reshape(b, s_full, h, dv)
    qh = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], axis=-1)
    kh = jnp.concatenate(
        [k_nope.transpose(0, 2, 1, 3),
         jnp.broadcast_to(kbuf[:, None], (b, h, s_full, dr))], axis=-1)
    vh = vfull.transpose(0, 2, 1, 3)
    out = L.attention_core(cfg, qh, kh, vh, causal=True,
                           scale=(dn + dr) ** -0.5, q_offset=lo)
    out = out.transpose(0, 2, 1, 3).reshape(b, c, h * dv)
    y = (out @ p["wo"].astype(cd)).astype(x.dtype)
    return y, cbuf, kbuf


def _chunk_mla_body(cfg: ModelConfig, params: Params, cks: jnp.ndarray,
                    krs: jnp.ndarray, x: jnp.ndarray, lo: int):
    """One chunk through an MLA stack.  ``cks``: (L, B, S, r) latent
    scratch; ``krs``: (L, B, S, dr) shared rope-key scratch."""
    def body(h, layer):
        lp, cbuf, kbuf = layer
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, cbuf, kbuf = _chunk_mla_attention(cfg, lp["attn"], normed,
                                             cbuf, kbuf, lo)
        h = h + a
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        return constrain(h, "residual"), (cbuf, kbuf)

    h, (cks, krs) = lax.scan(_maybe_remat(cfg, body), x,
                             (params["layers"], cks, krs))
    return cks, krs, h


def _chunk_ssm_stack(cfg: ModelConfig, stack: Params, states: jnp.ndarray,
                     tails: jnp.ndarray, x: jnp.ndarray):
    """One chunk through a mamba2 stack, resuming each layer from its
    carried (SSD state, conv tail) pair — the ``ssd`` kernel's
    ``init_state`` hook plus a VALID conv over [tail ‖ chunk rows].
    Returns ``(h, states', tails')`` (constant-size carry)."""
    def body(h, layer):
        lp, st, cv = layer
        normed = L.apply_norm(cfg, lp["ln"], h)
        o, (st, cv) = L.mamba2_block(cfg, lp["mamba"], normed,
                                     return_state=True, init_state=st,
                                     conv_state=cv)
        return constrain(h + o, "residual"), (st, cv)

    h, (sts, cvs) = lax.scan(_maybe_remat(cfg, body), x,
                             (stack, states, tails))
    return h, sts, cvs


def _chunk_hybrid(cfg: ModelConfig, params: Params, scratch: Cache,
                  x: jnp.ndarray, lo: int):
    """One chunk through a zamba2 hybrid: grouped SSM stacks carry their
    state pairs, the shared attention applications ride the ring carry."""
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    n_shared = max(cfg.n_shared_blocks, 1)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), params["layers"])
    rest = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    shared = params["shared_blocks"]
    regroup = lambda a: a[: n_groups * period].reshape(
        (n_groups, period) + a.shape[1:])
    gst = regroup(scratch["ssm_state"])
    gcv = regroup(scratch["conv_state"])

    def group_body(carry, inp):
        h, g = carry
        glayers, st, cv, kbuf, vbuf = inp
        h, st, cv = _chunk_ssm_stack(cfg, glayers, st, cv, h)
        sel = jax.tree.map(lambda a: a[g % n_shared], shared)
        normed = L.apply_norm(cfg, sel["ln1"], h)
        a, kbuf, vbuf = _chunk_attention(cfg, sel["attn"], normed,
                                         kbuf, vbuf, lo)
        h = h + a
        h = h + L.mlp(cfg, sel["mlp"], L.apply_norm(cfg, sel["ln2"], h))
        return (constrain(h, "residual"), g + 1), (st, cv, kbuf, vbuf)

    (h, _), (gst, gcv, ks, vs) = lax.scan(
        _maybe_remat(cfg, group_body), (x, jnp.int32(0)),
        (grouped, gst, gcv, scratch["attn_k"], scratch["attn_v"]))
    ssm_state = gst.reshape((n_groups * period,) + gst.shape[2:])
    conv_state = gcv.reshape((n_groups * period,) + gcv.shape[2:])
    if n_rem:
        h, rst, rcv = _chunk_ssm_stack(
            cfg, rest, scratch["ssm_state"][n_groups * period:],
            scratch["conv_state"][n_groups * period:], h)
        ssm_state = jnp.concatenate([ssm_state, rst], axis=0)
        conv_state = jnp.concatenate([conv_state, rcv], axis=0)
    return dict(scratch, ssm_state=ssm_state, conv_state=conv_state,
                attn_k=ks, attn_v=vs), h


def _chunk_encdec(cfg: ModelConfig, params: Params, scratch: Cache,
                  tokens: jnp.ndarray, lo: int,
                  frontend_embeds: Optional[jnp.ndarray]):
    """One decoder chunk of an encoder-decoder.  Chunk 0 runs the encoder
    once and materializes every layer's cross-K/V into the scratch; later
    chunks reuse it (the "encoder-once" carry).  Decoder self-attention
    streams like the ring kind (no rope — whisper's learned positions are
    sliced at the chunk offset instead)."""
    enc = None
    if lo == 0:
        assert frontend_embeds is not None, "encdec chunk 0 needs frames"
        enc = encode(cfg, params, frontend_embeds)

    x = jnp.take(params["embed"], tokens, axis=0)
    c = x.shape[1]
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], lo, c,
                                     0).astype(x.dtype)
    dpos = lo + jnp.arange(c)

    def body(h, layer):
        lp, kbuf, vbuf, k1, v1 = layer
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, kbuf, vbuf = _chunk_attention(cfg, lp["attn"], normed,
                                         kbuf, vbuf, lo)
        h = h + a
        if enc is not None:
            # chunk 0: the same per-layer cross_kv call bulk prefill makes
            # inside its scan — later chunks reuse the materialized rows
            k1, v1, _ = L.cross_kv(cfg, lp["xattn"], enc)
        h = h + L.attention(cfg, lp["xattn"],
                            L.apply_norm(cfg, lp["ln_x"], h),
                            dpos, causal=False, kv_override=(k1, v1, None))
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        return constrain(h, "residual"), (kbuf, vbuf, k1, v1)

    h, (ks, vs, xks, xvs) = lax.scan(
        _maybe_remat(cfg, body), x,
        (params["dec_layers"], scratch["k"], scratch["v"],
         scratch["cross_k"], scratch["cross_v"]))
    return dict(scratch, k=ks, v=vs, cross_k=xks, cross_v=xvs), h


def _embed_chunk(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                 frontend_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    """A chunk's row-slice of ``model._embed`` — the frontend projection
    and the concat are both row-wise, so slicing fe/text rows per chunk
    reproduces the bulk rows bit for bit."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if frontend_embeds is not None and frontend_embeds.shape[1]:
        cd = jnp.dtype(cfg.compute_dtype)
        vis = (frontend_embeds.astype(cd)
               @ params["frontend_proj"].astype(cd)).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _chunk_logits(cfg: ModelConfig, params: Params,
                  h: jnp.ndarray) -> jnp.ndarray:
    x = L.apply_norm(cfg, params["final_norm"], h)
    last = constrain(x[:, -1:, :], "logit_hidden")
    return _lm_logits(cfg, params, last)[:, 0]


def prefill_chunk(cfg: ModelConfig, params: Params, scratch: Cache,
                  tokens: jnp.ndarray, lo: int,
                  frontend_embeds: Optional[jnp.ndarray] = None,
                  ) -> Tuple[Cache, jnp.ndarray]:
    """One incremental prefill chunk (the server's admission step).

    ``tokens``: (B, C) — the prompt's *token* rows in ``[lo, lo+C)``;
    ``lo`` is static (each (chunk shape, offset) pair is its own jitted
    program, which is what keeps the path bit-identical to bulk).
    ``frontend_embeds``: the chunk's frontend rows — for vlm, the
    fe-row slice of the chunk (frontend rows precede text rows exactly as
    in the bulk concat); for encdec, the *full* frame tensor on chunk 0
    only (the encoder runs once).  Dispatches on the carry kind of
    :func:`chunk_carry_spec`; returns the updated scratch and the chunk's
    next-token logits (meaningful once the final chunk has run).
    """
    spec = chunk_carry_spec(cfg)
    if spec.kind == "encdec":
        new, h = _chunk_encdec(cfg, params, scratch, tokens, lo,
                               frontend_embeds)
        hi = lo + tokens.shape[1]
    else:
        x = constrain(_embed_chunk(cfg, params, tokens, frontend_embeds),
                      "residual")
        hi = lo + x.shape[1]
        if spec.kind == "latent":
            cks, krs, h = _chunk_mla_body(cfg, params, scratch["ckv"],
                                          scratch["krope"], x, lo)
            new = dict(scratch, ckv=cks, krope=krs)
        elif spec.kind == "state":
            h, sts, cvs = _chunk_ssm_stack(cfg, params["layers"],
                                           scratch["ssm_state"],
                                           scratch["conv_state"], x)
            new = dict(scratch, ssm_state=sts, conv_state=cvs)
        elif spec.kind == "hybrid":
            new, h = _chunk_hybrid(cfg, params, scratch, x, lo)
        else:
            ks, vs, h = _chunk_body(cfg, params, scratch["k"],
                                    scratch["v"], x, lo)
            new = dict(scratch, k=ks, v=vs)
    new["pos"] = jnp.full_like(scratch["pos"], hi)
    return new, _chunk_logits(cfg, params, h)


def scratch_to_cache(cfg: ModelConfig, scratch: Cache,
                     cache_len: Optional[int] = None) -> Cache:
    """Convert a *completed* prefill scratch into the decode-cache layout
    — bit-identical to the cache bulk :func:`prefill` builds.  Ring kinds
    ring-fill their sequence rows (casting to the param dtype exactly
    where bulk casts); the state kind's carry already *is* the cache."""
    dt = jnp.dtype(cfg.param_dtype)
    spec = chunk_carry_spec(cfg)

    if spec.kind == "state":
        return {"ssm_state": scratch["ssm_state"],
                "conv_state": scratch["conv_state"].astype(dt),
                "pos": scratch["pos"]}

    def fill(name, seq_axis, sb):
        filled, _ = _ring_fill(scratch[name], sb, seq_axis=seq_axis)
        return filled.astype(dt)

    if spec.kind == "latent":
        s = scratch["ckv"].shape[2]
        batch = scratch["ckv"].shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        cache = {"ckv": fill("ckv", 2, sb), "krope": fill("krope", 2, sb)}
    elif spec.kind == "hybrid":
        s = scratch["attn_k"].shape[3]
        batch = scratch["attn_k"].shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        cache = {"ssm_state": scratch["ssm_state"],
                 "conv_state": scratch["conv_state"].astype(dt),
                 "attn_k": fill("attn_k", 3, sb),
                 "attn_v": fill("attn_v", 3, sb)}
    elif spec.kind == "encdec":
        s = scratch["k"].shape[3]
        batch = scratch["k"].shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        cache = {"k": fill("k", 3, sb), "v": fill("v", 3, sb),
                 "cross_k": scratch["cross_k"].astype(dt),
                 "cross_v": scratch["cross_v"].astype(dt)}
    else:
        s = scratch["k"].shape[3]
        batch = scratch["k"].shape[1]
        sb = kv_buf_len(cfg, cache_len or s)
        cache = {"k": fill("k", 3, sb), "v": fill("v", 3, sb)}
    slot_pos, _ = _slot_map(s, sb)
    cache["slot_pos"] = slot_pos
    return _finish_cache(cache, batch, s)


def moe_chunk_agree_mask(cfg: ModelConfig, moe_params: Params,
                         x: jnp.ndarray,
                         cuts: List[Tuple[int, int]]):
    """The MoE chunk-local capacity bound, stated operationally.

    ``x``: (B, S, D) — one MoE layer's input rows; ``cuts``: the chunk
    boundaries.  Returns ``(agree, keep_bulk, keep_chunk)`` where the
    ``keep_*`` are the (B, S, K) per-(token, expert) keep decisions of
    the bulk program (capacity bookkept over S) and the chunk-local
    program (capacity bookkept per chunk), and ``agree`` (B, S) is their
    rowwise conjunction.

    **Bound**: routing logits, top-k choice, and normalized weights are
    all per-row (``layers.moe_route`` normalizes over the chosen k
    *before* applying capacity), the dispatch slot a token combines from
    holds that token's own row, and the expert FFN is row-independent —
    so capacity only decides *which* (token, expert) pairs contribute,
    and *this layer's* MoE output is bitwise equal at every token where
    ``agree`` holds.  Attention then mixes rows, so whole-forward
    identity needs agreement everywhere: when no row overflows in either
    program at any layer (``agree`` all-True throughout, e.g. a capacity
    factor ≥ ``n_experts``), chunked prefill ≡ bulk bit for bit; when
    drops differ, outputs diverge and this mask names the first culprit
    rows.  tests/test_zoo.py asserts both directions.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    keep_bulk = L.moe_route(cfg, moe_params["router"], xc)[2]
    keep_chunk = jnp.concatenate(
        [L.moe_route(cfg, moe_params["router"], xc[:, lo:hi])[2]
         for lo, hi in cuts], axis=1)
    agree = jnp.all(keep_bulk == keep_chunk, axis=-1)
    return agree, keep_bulk, keep_chunk


# ---------------------------------------------------------------------------
# paged KV block pool (PR 6): slot cache <-> pool blocks
# ---------------------------------------------------------------------------


def cache_to_blocks(cfg: ModelConfig, slot_cache: Cache, block_size: int):
    """Split a single-request ring cache into pool blocks.

    ``slot_cache``: the batch-1 cache :func:`prefill` /
    :func:`scratch_to_cache` builds (``k``/``v`` (L, 1, Hkv, sb, hd)).
    Returns ``(blocks_k, blocks_v, slot_pos_row, pos_row)`` with blocks
    shaped (L, sb/blk, Hkv, blk, hd) — a pure reshape of the ring layout
    (``block_size`` must divide ``sb``), so pushing the blocks into a
    pool and gathering them back via the block table reproduces the
    contiguous cache bit for bit.  These are the "finished chunk-blocks"
    a prefill rank PUTs into the decode pool (``core/pgas.BlockSegment``
    prices the one-sided writes).
    """
    k = slot_cache["k"]
    nl, b1, hkv, sb, hd = k.shape
    assert b1 == 1, k.shape
    if sb % block_size:
        raise ValueError(
            f"block_size {block_size} must divide the ring extent {sb}")
    npb = sb // block_size

    def split(a):
        blocks = a[:, 0].reshape(nl, hkv, npb, block_size, hd)
        return blocks.transpose(0, 2, 1, 3, 4)

    return (split(k), split(slot_cache["v"]),
            slot_cache["slot_pos"][0], slot_cache["pos"][0])


def scratch_to_blocks(cfg: ModelConfig, scratch: Cache, block_size: int,
                      cache_len: Optional[int] = None):
    """Ring-fill a completed prefill scratch straight into pool blocks
    (:func:`scratch_to_cache` composed with :func:`cache_to_blocks` —
    the paged flavor of the server's admission conversion)."""
    return cache_to_blocks(cfg, scratch_to_cache(cfg, scratch,
                                                 cache_len=cache_len),
                           block_size)


def seed_scratch_from_blocks(cfg: ModelConfig, scratch: Cache,
                             blocks_k: jnp.ndarray,
                             blocks_v: jnp.ndarray) -> Cache:
    """Seed a fresh prefill scratch with ``m`` cached prefix blocks.

    The prefix-cache hit path: positions ``[0, m·blk)`` of the scratch
    are restored from pool blocks instead of recomputed, and chunked
    prefill resumes at the first uncached chunk.  Valid only while the
    cached prefix never wrapped the ring (slot ``j`` == position ``j`` —
    the server's sharing guard), and bit-exact when the pool dtype equals
    the compute dtype (the reduced/test configs; otherwise the prefix
    K/V round-trips through the param dtype, ulp-level like any
    cross-program reshard).
    """
    nl, m, hkv, blk, hd = blocks_k.shape
    cd = jnp.dtype(cfg.compute_dtype)

    def merge(buf, blocks):
        flat = blocks.transpose(0, 2, 1, 3, 4).reshape(nl, hkv, m * blk, hd)
        return lax.dynamic_update_slice_in_dim(
            buf, flat[:, None].astype(cd), 0, axis=3)

    return dict(scratch, k=merge(scratch["k"], blocks_k),
                v=merge(scratch["v"], blocks_v))


def prefill_chunked(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,
    *,
    cache_len: Optional[int] = None,
    chunk_len: Optional[int] = None,
    n_chunks: Optional[int] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Chunked streamed prefill: :func:`prefill`, as an ART pipeline.

    The prompt runs in fixed-size chunks through
    ``pipeline.chunk_pipeline_carried``: chunk *k*'s forward (the carried
    compute) overlaps chunk *k−1*'s ring-cache scatter (the transfer — on a
    sequence-sharded cache that scatter is the wire write, the bulk
    ``gasnet_put`` of the paper's serving shape split into ART chunks).
    Cache and logits are bit-identical to bulk :func:`prefill` — every row
    runs the same blockwise recipe against the same key extent (module
    docstring) — asserted across odd chunk sizes by tests/test_serving.py
    and across the whole zoo by tests/test_zoo.py (MoE: exact under the
    no-overflow bound of :func:`moe_chunk_agree_mask`).

    The ``ring`` carry kinds run the pipelined schedule below (the growing
    K/V slab's ring scatter is the wire write worth overlapping); the
    other carries walk :func:`prefill_chunk` sequentially — their per-chunk
    hand-off is the carry itself, which the server streams anyway.  Cuts
    align to the carry's ``chunk_multiple`` (SSD state hand-off is exact
    on ``ssm_chunk`` boundaries).  Archs gated out by
    :func:`chunk_support` fall back to bulk.
    """
    from repro.models.model import _embed

    spec = chunk_carry_spec(cfg)
    s_total = (tokens.shape[1] + (cfg.frontend_tokens
                                  if cfg.frontend and cfg.family == "vlm"
                                  else 0))
    cuts = prefill_chunk_cuts(s_total, chunk_len, n_chunks,
                              multiple=spec.chunk_multiple)
    if len(cuts) <= 1 or not supports_chunked_prefill(cfg):
        return prefill(cfg, params, tokens, frontend_embeds,
                       cache_len=cache_len)

    if spec.kind != "ring":
        batch = tokens.shape[0]
        scratch = init_prefill_scratch(cfg, batch, s_total)
        logits = None
        for lo, hi in cuts:
            if cfg.family == "encdec":
                fe = frontend_embeds if lo == 0 else None
                sl = tokens[:, lo:hi]
            else:
                fe, sl = None, tokens[:, lo:hi]
            scratch, logits = prefill_chunk(cfg, params, scratch, sl, lo,
                                            frontend_embeds=fe)
        return scratch_to_cache(cfg, scratch, cache_len=cache_len), logits

    batch = tokens.shape[0]
    dt = jnp.dtype(cfg.param_dtype)
    sb = kv_buf_len(cfg, cache_len or s_total)
    x_full = constrain(_embed(cfg, params, tokens, frontend_embeds),
                       "residual")
    scratch = init_prefill_scratch(cfg, batch, s_total)

    def compute(k, carry):
        ks, vs = carry
        lo, hi = cuts[k]
        ks, vs, h = _chunk_body(cfg, params, ks, vs, x_full[:, lo:hi], lo)
        # the payload the "wire" carries: this chunk's K/V slab (+ the
        # residual tail that only the final chunk's logits consume)
        return (ks[:, :, :, lo:hi], vs[:, :, :, lo:hi], h), (ks, vs)

    def consume(state, k, arrived):
        ring_k, ring_v, _ = state
        ck, cv, h = arrived
        lo, hi = cuts[k]
        # ring slots of positions [lo, hi); a chunk longer than the ring
        # keeps only its last sb positions (earlier ones would be
        # overwritten within the chunk anyway)
        first = max(lo, hi - sb)
        slots = jnp.asarray([p % sb for p in range(first, hi)], jnp.int32)
        ring_k = ring_k.at[:, :, :, slots].set(
            ck[:, :, :, first - lo:].astype(dt))
        ring_v = ring_v.at[:, :, :, slots].set(
            cv[:, :, :, first - lo:].astype(dt))
        return ring_k, ring_v, h

    hd = cfg.resolved_head_dim
    ring_shape = (cfg.n_layers, batch, cfg.n_kv_heads, sb, hd)
    init = (jnp.zeros(ring_shape, dt), jnp.zeros(ring_shape, dt), None)
    (ring_k, ring_v, h_last), _ = pl.chunk_pipeline_carried(
        len(cuts), compute, lambda k, payload: payload, consume,
        carry=(scratch["k"], scratch["v"]), init=init)

    slot_pos, _ = _slot_map(s_total, sb)
    cache = _finish_cache(
        {"k": ring_k, "v": ring_v, "slot_pos": slot_pos}, batch, s_total)
    return cache, _chunk_logits(cfg, params, h_last)
