"""Prefill: forward over a prompt that also materializes the decode cache.

``prefill_32k`` cells lower exactly this — a forward pass that returns
(populated cache, next-token logits).  The cache layouts match
``decode.init_cache`` exactly, so ``decode_step`` continues from a prefill
without reshaping (asserted by tests/test_serving.py).

Ring-buffer fill: the cache keeps the last ``sb`` positions.  Position
``p`` lives at slot ``p % sb``; for ``S >= sb`` the slots hold positions
``[S−sb, S)`` as the permutation ``slot j ← pos S−sb+((j−S) mod sb)``, and
for ``S < sb`` slots ``[S, sb)`` stay empty (``slot_pos = −1`` masks them).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.model import (
    _lm_logits,
    _maybe_remat,
    encode,
)
from repro.models.shardctx import constrain

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _slot_map(s: int, sb: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (pos_for_slot (sb,) int32 with −1 empty, gather_idx (sb,))."""
    j = jnp.arange(sb)
    if s >= sb:
        pos = s - sb + ((j - s) % sb)
        return pos.astype(jnp.int32), pos.astype(jnp.int32)
    pos = jnp.where(j < s, j, -1)
    return pos.astype(jnp.int32), jnp.maximum(pos, 0).astype(jnp.int32)


def _ring_fill(seq_t: jnp.ndarray, sb: int, seq_axis: int):
    """Scatter a (..., S, ...) sequence tensor into its ring-buffer layout."""
    s = seq_t.shape[seq_axis]
    slot_pos, idx = _slot_map(s, sb)
    filled = jnp.take(seq_t, idx, axis=seq_axis)
    if s < sb:
        # zero the empty tail so the cache has no garbage (masked anyway)
        shape = [1] * seq_t.ndim
        shape[seq_axis] = sb
        mask = (slot_pos >= 0).reshape(shape)
        filled = jnp.where(mask, filled, jnp.zeros_like(filled))
    return filled, slot_pos


# ---------------------------------------------------------------------------
# family prefills
# ---------------------------------------------------------------------------


def _prefill_gqa(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        normed2 = L.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            h = h + L.moe(cfg, lp["moe"], normed2)
        else:
            h = h + L.mlp(cfg, lp["mlp"], normed2)
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (kc.astype(jnp.dtype(cfg.param_dtype)),
                                          vc.astype(jnp.dtype(cfg.param_dtype)))

    x, (ks, vs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"k": ks, "v": vs, "slot_pos": slot_pos}


def _prefill_mla(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (ckv, krope) = L.mla_attention(cfg, lp["attn"], normed, positions,
                                          return_cache=True)
        h = h + a
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        cc, _ = _ring_fill(ckv, sb, seq_axis=1)
        kr, _ = _ring_fill(krope, sb, seq_axis=1)
        return constrain(h, "residual"), (cc.astype(dt), kr.astype(dt))

    x, (cks, krs) = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ckv": cks, "krope": krs, "slot_pos": slot_pos}


def _prefill_ssm_stack(cfg: ModelConfig, stack: Params, x: jnp.ndarray):
    dt = jnp.dtype(cfg.param_dtype)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln"], h)
        o, (state, conv_tail) = L.mamba2_block(cfg, lp["mamba"], normed,
                                               return_state=True)
        return constrain(h + o, "residual"), (state, conv_tail.astype(dt))

    return lax.scan(_maybe_remat(cfg, body), x, stack)


def _prefill_hybrid(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    n_shared = max(cfg.n_shared_blocks, 1)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), params["layers"])
    rest = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    shared = params["shared_blocks"]

    def group_body(carry, glayers):
        h, g = carry
        h, (st, cv) = _prefill_ssm_stack(cfg, glayers, h)
        sel = jax.tree.map(lambda a: a[g % n_shared], shared)
        normed = L.apply_norm(cfg, sel["ln1"], h)
        a, (k, v) = L.attention(cfg, sel["attn"], normed, positions,
                                return_kv=True)
        h = h + a
        h = h + L.mlp(cfg, sel["mlp"], L.apply_norm(cfg, sel["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return (constrain(h, "residual"), g + 1), (
            st, cv, kc.astype(dt), vc.astype(dt))

    (x, _), (sts, cvs, ks, vs) = lax.scan(
        _maybe_remat(cfg, group_body), (x, jnp.int32(0)), grouped)
    ssm_state = sts.reshape((n_groups * period,) + sts.shape[2:])
    conv_state = cvs.reshape((n_groups * period,) + cvs.shape[2:])
    if n_rem:
        x, (rst, rcv) = _prefill_ssm_stack(cfg, rest, x)
        ssm_state = jnp.concatenate([ssm_state, rst], axis=0)
        conv_state = jnp.concatenate([conv_state, rcv], axis=0)
    slot_pos, _ = _slot_map(x.shape[1], sb)
    return x, {"ssm_state": ssm_state, "conv_state": conv_state,
               "attn_k": ks, "attn_v": vs, "slot_pos": slot_pos}


def _prefill_encdec(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                    frontend_embeds: jnp.ndarray, sb: int):
    dt = jnp.dtype(cfg.param_dtype)
    enc = encode(cfg, params, frontend_embeds)
    x = jnp.take(params["embed"], tokens, axis=0)
    s = x.shape[1]
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], 0, s, 0).astype(x.dtype)
    dpos = jnp.arange(s)

    def body(h, lp):
        normed = L.apply_norm(cfg, lp["ln1"], h)
        a, (k, v) = L.attention(cfg, lp["attn"], normed, dpos,
                                return_kv=True)
        h = h + a
        kv = L.cross_kv(cfg, lp["xattn"], enc)
        h = h + L.attention(cfg, lp["xattn"],
                            L.apply_norm(cfg, lp["ln_x"], h),
                            dpos, causal=False, kv_override=kv)
        h = h + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], h))
        kc, _ = _ring_fill(k, sb, seq_axis=2)
        vc, _ = _ring_fill(v, sb, seq_axis=2)
        return constrain(h, "residual"), (
            kc.astype(dt), vc.astype(dt),
            kv[0].astype(dt), kv[1].astype(dt))

    x, (ks, vs, xks, xvs) = lax.scan(_maybe_remat(cfg, body), x,
                                     params["dec_layers"])
    slot_pos, _ = _slot_map(s, sb)
    return x, {"k": ks, "v": vs, "cross_k": xks, "cross_v": xvs,
               "slot_pos": slot_pos}


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                       # (B, S)
    frontend_embeds: Optional[jnp.ndarray] = None,
    *,
    cache_len: Optional[int] = None,
) -> Tuple[Cache, jnp.ndarray]:
    """Run the prompt, build the decode cache, return next-token logits.

    ``cache_len``: ring-buffer capacity (default: prompt length); the SWA
    window caps it (h2o-danube long contexts keep a 4096-slot cache).
    """
    from repro.models.model import _embed

    if cfg.family == "encdec":
        s = tokens.shape[1]
        sb = min(cache_len or s, 4096)
        x, cache = _prefill_encdec(cfg, params, tokens, frontend_embeds, sb)
        s_total = s
    else:
        x = constrain(_embed(cfg, params, tokens, frontend_embeds), "residual")
        s_total = x.shape[1]
        cap = cache_len or s_total
        sb = min(cap, cfg.window) if cfg.window else cap
        positions = jnp.arange(s_total)
        if cfg.family in ("dense", "vlm", "moe") and cfg.attn_type != "mla":
            x, cache = _prefill_gqa(cfg, params, x, positions, sb)
        elif cfg.attn_type == "mla":
            x, cache = _prefill_mla(cfg, params, x, positions, sb)
        elif cfg.family == "ssm":
            x, (st, cv) = _prefill_ssm_stack(cfg, params["layers"], x)
            cache = {"ssm_state": st, "conv_state": cv}
        elif cfg.family == "hybrid":
            x, cache = _prefill_hybrid(cfg, params, x, positions, sb)
        else:
            raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    last = constrain(x[:, -1:, :], "logit_hidden")
    logits = _lm_logits(cfg, params, last)[:, 0]
    cache["pos"] = jnp.asarray(s_total, jnp.int32)
    return cache, logits
