"""Model assembly: init / forward / loss for every architecture family.

Layer stacks are ``lax.scan`` over parameter pytrees stacked on a leading
layer axis — compile time and HLO size are O(1) in depth, which is what
keeps the 512-device dry-run of 96-layer nemotron-340b tractable.

Families:
  dense / vlm      — [frontend] + decoder blocks (GQA or MLA, MLP)
  moe              — decoder blocks with MoE FFN (+ optional shared expert)
  ssm              — Mamba-2 (SSD) blocks
  hybrid           — Mamba-2 backbone, *shared* attention block every
                     ``hybrid_period`` layers (zamba2: 2 alternating sets)
  encdec           — whisper: encoder (bidirectional) + decoder (self+cross)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.shardctx import constrain, moe_ffn_runner, tp_block_runner

Params = Dict[str, Any]


def _maybe_remat(cfg: ModelConfig, fn):
    """Per-layer activation checkpointing (applied to scan bodies)."""
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(init_fn, cfg: ModelConfig, key, n: int) -> Params:
    """vmap an init over layer keys -> pytree with leading layer axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(cfg, k))(keys)


def _init_dense_layer(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    attn = (L.init_mla(cfg, k1) if cfg.attn_type == "mla"
            else L.init_attention(cfg, k1))
    return {
        "ln1": L.init_norm(cfg, k3),
        "attn": attn,
        "ln2": L.init_norm(cfg, k4),
        "mlp": L.init_mlp(cfg, k2),
    }


def _init_moe_layer(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": L.init_norm(cfg, k3),
        "attn": L.init_attention(cfg, k1),
        "ln2": L.init_norm(cfg, k4),
        "moe": L.init_moe(cfg, k2),
    }


def _init_ssm_layer(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln": L.init_norm(cfg, k2), "mamba": L.init_mamba2(cfg, k1)}


def _init_encdec_layer(cfg: ModelConfig, key, cross: bool) -> Params:
    ks = jax.random.split(key, 6)
    p = {
        "ln1": L.init_norm(cfg, ks[0]),
        "attn": L.init_attention(cfg, ks[1]),
        "ln2": L.init_norm(cfg, ks[2]),
        "mlp": L.init_mlp(cfg, ks[3]),
    }
    if cross:
        p["ln_x"] = L.init_norm(cfg, ks[4])
        p["xattn"] = L.init_attention(cfg, ks[5])
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embed": L._init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": L.init_norm(cfg, ks[1]),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(ks[2], (cfg.d_model, cfg.vocab_size), dt)

    if cfg.family in ("dense", "vlm"):
        p["layers"] = _stack_init(_init_dense_layer, cfg, ks[3], cfg.n_layers)
    elif cfg.family == "moe":
        p["layers"] = _stack_init(_init_moe_layer, cfg, ks[3], cfg.n_layers)
    elif cfg.family == "ssm":
        p["layers"] = _stack_init(_init_ssm_layer, cfg, ks[3], cfg.n_layers)
    elif cfg.family == "hybrid":
        p["layers"] = _stack_init(_init_ssm_layer, cfg, ks[3], cfg.n_layers)
        p["shared_blocks"] = _stack_init(
            _init_dense_layer, cfg, ks[4], max(cfg.n_shared_blocks, 1))
    elif cfg.family == "encdec":
        p["enc_layers"] = _stack_init(
            lambda c, k: _init_encdec_layer(c, k, cross=False),
            cfg, ks[3], cfg.n_encoder_layers)
        p["dec_layers"] = _stack_init(
            lambda c, k: _init_encdec_layer(c, k, cross=True),
            cfg, ks[4], cfg.n_layers)
        p["enc_norm"] = L.init_norm(cfg, ks[5])
        p["dec_pos"] = L._init(ks[6], (4096, cfg.d_model), dt, 0.01)
    else:
        raise ValueError(cfg.family)

    if cfg.frontend:
        p["frontend_proj"] = L._init(
            ks[7], (cfg.frontend_dim, cfg.d_model), dt)
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _dense_block(cfg, p, x, positions):
    runner = tp_block_runner()
    if runner is not None and cfg.use_art and cfg.attn_type != "mla":
        # the paper's technique: every TP collective of this block is an
        # ART ring schedule (models/artblock.py via the step builder)
        return runner(cfg, p, x, positions)
    attn_fn = L.mla_attention if cfg.attn_type == "mla" else L.attention
    a_in = constrain(L.apply_norm(cfg, p["ln1"], x), "block_input")
    h = x + attn_fn(cfg, p["attn"], a_in, positions)
    m_in = constrain(L.apply_norm(cfg, p["ln2"], h), "block_input")
    h = h + L.mlp(cfg, p["mlp"], m_in)
    return h


def _moe_block(cfg, p, x, positions):
    a_in = constrain(L.apply_norm(cfg, p["ln1"], x), "block_input")
    h = x + L.attention(cfg, p["attn"], a_in, positions)
    normed = constrain(L.apply_norm(cfg, p["ln2"], h), "block_input")
    ep = moe_ffn_runner()
    if ep is not None:
        # expert-parallel dispatch over the conduit all_to_all
        # (models/moe_ep.py, installed by dist/steps.build_train_step)
        h = h + ep(cfg, p["moe"], normed)
    else:
        h = h + L.moe(cfg, p["moe"], normed)
    aux = L.moe_aux_loss(cfg, normed, p["moe"])
    return h, aux


def _ssm_block(cfg, p, x):
    m_in = constrain(L.apply_norm(cfg, p["ln"], x), "block_input")
    return x + L.mamba2_block(cfg, p["mamba"], m_in)


def _embed(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
           frontend_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend and cfg.family != "encdec":
        assert frontend_embeds is not None, (
            f"{cfg.name} requires precomputed frontend embeddings")
        cd = jnp.dtype(cfg.compute_dtype)
        vis = (frontend_embeds.astype(cd)
               @ params["frontend_proj"].astype(cd)).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def forward_hidden(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                    # (B, S_text)
    frontend_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone only: returns (final-norm hidden (B, S, D), moe_aux scalar).
    The LM head is applied by :func:`forward` (tests) or by the *chunked*
    cross-entropy in ``dist/loss.py`` (training — full logits never
    materialize for large-vocab archs)."""
    if cfg.family == "encdec":
        return _forward_encdec_hidden(cfg, params, tokens, frontend_embeds)

    x = constrain(_embed(cfg, params, tokens, frontend_embeds), "residual")
    s = x.shape[1]
    positions = jnp.arange(s)
    aux = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm"):
        def body(h, lp):
            h = constrain(_dense_block(cfg, lp, h, positions), "residual")
            return h, None
        x, _ = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    elif cfg.family == "moe":
        def body(carry, lp):
            h, a = carry
            h, aux_l = _moe_block(cfg, lp, h, positions)
            return (constrain(h, "residual"), a + aux_l), None
        (x, aux), _ = lax.scan(_maybe_remat(cfg, body), (x, aux),
                               params["layers"])
    elif cfg.family == "ssm":
        def body(h, lp):
            return constrain(_ssm_block(cfg, lp, h), "residual"), None
        x, _ = lax.scan(_maybe_remat(cfg, body), x, params["layers"])
    elif cfg.family == "hybrid":
        x = _forward_hybrid(cfg, params, x, positions)
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    return constrain(x, "logit_hidden"), aux


def forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jnp.ndarray,                    # (B, S_text)
    frontend_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B, S, V), moe_aux_loss scalar)."""
    x, aux = forward_hidden(cfg, params, tokens, frontend_embeds)
    return _lm_logits(cfg, params, x), aux


def _lm_logits(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", x.astype(cd), head.astype(cd)).astype(
        jnp.float32)


def _forward_hybrid(cfg: ModelConfig, params: Params, x: jnp.ndarray,
                    positions: jnp.ndarray) -> jnp.ndarray:
    """Zamba2: scan groups of ``hybrid_period`` SSM layers, applying one of
    the ``n_shared_blocks`` alternating *shared* attention blocks after each
    group; leftover SSM layers run at the end."""
    period = cfg.hybrid_period
    n_groups = cfg.n_layers // period
    n_rem = cfg.n_layers - n_groups * period
    grouped = jax.tree.map(
        lambda a: a[: n_groups * period].reshape((n_groups, period) + a.shape[1:]),
        params["layers"])
    rest = jax.tree.map(lambda a: a[n_groups * period:], params["layers"])
    shared = params["shared_blocks"]
    n_shared = max(cfg.n_shared_blocks, 1)

    def group_body(carry, inp):
        h, g = carry
        glayers = inp

        def ssm_body(hh, lp):
            return constrain(_ssm_block(cfg, lp, hh), "residual"), None
        h, _ = lax.scan(_maybe_remat(cfg, ssm_body), h, glayers)
        # alternate shared blocks: select block g % n_shared
        sel = jax.tree.map(
            lambda a: a[g % n_shared] if n_shared > 1 else a[0], shared)
        h = constrain(_dense_block(cfg, sel, h, positions), "residual")
        return (h, g + 1), None

    (x, _), _ = lax.scan(_maybe_remat(cfg, group_body), (x, jnp.int32(0)),
                         grouped)
    if n_rem:
        def ssm_body(hh, lp):
            return constrain(_ssm_block(cfg, lp, hh), "residual"), None
        x, _ = lax.scan(_maybe_remat(cfg, ssm_body), x, rest)
    return x


def encode(cfg: ModelConfig, params: Params,
           frontend_embeds: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder: precomputed frame embeddings (stub frontend) ->
    encoder output (B, S_enc, D)."""
    cd = jnp.dtype(cfg.compute_dtype)
    enc = (frontend_embeds.astype(cd)
           @ params["frontend_proj"].astype(cd))
    enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(cd)
    enc = enc.astype(jnp.dtype(cfg.param_dtype))
    positions = jnp.arange(enc.shape[1])

    def enc_body(h, lp):
        hh = h + L.attention(cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], h),
                             positions, causal=False)
        hh = hh + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], hh))
        return constrain(hh, "residual"), None

    enc, _ = lax.scan(_maybe_remat(cfg, enc_body), enc, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_norm"], enc)


def _forward_encdec_hidden(cfg: ModelConfig, params: Params,
                           tokens: jnp.ndarray,
                           frontend_embeds: Optional[jnp.ndarray]):
    """Whisper backbone: frame embeddings (stub frontend) -> encoder;
    token embeddings + learned positions -> decoder with cross-attention."""
    assert frontend_embeds is not None, "whisper needs precomputed frames"
    enc = encode(cfg, params, frontend_embeds)

    x = jnp.take(params["embed"], tokens, axis=0)
    s = x.shape[1]
    pos_table = params["dec_pos"]
    x = x + lax.dynamic_slice_in_dim(pos_table, 0, s, 0).astype(x.dtype)
    dpos = jnp.arange(s)

    def dec_body(h, lp):
        hh = h + L.attention(cfg, lp["attn"], L.apply_norm(cfg, lp["ln1"], h),
                             dpos, causal=True)
        kv = L.cross_kv(cfg, lp["xattn"], enc)
        hh = hh + L.attention(cfg, lp["xattn"],
                              L.apply_norm(cfg, lp["ln_x"], hh),
                              dpos, causal=False, kv_override=kv)
        hh = hh + L.mlp(cfg, lp["mlp"], L.apply_norm(cfg, lp["ln2"], hh))
        return constrain(hh, "residual"), None

    x, _ = lax.scan(_maybe_remat(cfg, dec_body), x, params["dec_layers"])
    x = L.apply_norm(cfg, params["final_norm"], x)
    return constrain(x, "logit_hidden"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(
    cfg: ModelConfig,
    params: Params,
    batch: Dict[str, jnp.ndarray],
    *,
    z_loss: float = 1e-4,
    moe_aux_weight: float = 1e-2,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """batch: tokens (B,S), labels (B,S) with -1 = masked, plus optional
    frontend_embeds.  For vlm, logits over image positions are dropped."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("frontend_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:       # vlm: crop frontend positions
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    zl = z_loss * ((lse * mask) ** 2).sum() / denom
    total = ce + zl + moe_aux_weight * aux
    return total, {"ce": ce, "z_loss": zl, "moe_aux": aux,
                   "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# analytic parameter counts (validates init + feeds MODEL_FLOPS)
# ---------------------------------------------------------------------------


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    norm = 2 * d if cfg.family == "encdec" else d  # LayerNorm has a bias

    def attn_params():
        if cfg.attn_type == "mla":
            h = cfg.n_heads
            return (d * cfg.q_lora_rank + cfg.q_lora_rank
                    + cfg.q_lora_rank * h * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                    + d * (cfg.kv_lora_rank + cfg.qk_rope_dim) + cfg.kv_lora_rank
                    + cfg.kv_lora_rank * h * cfg.qk_nope_dim
                    + cfg.kv_lora_rank * h * cfg.v_head_dim
                    + h * cfg.v_head_dim * d)
        return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d

    def mlp_params(ff=None):
        ff = ff or f
        return (3 if cfg.gated_mlp else 2) * d * ff

    def moe_params():
        n_e = (cfg.experts_per_token if active_only else cfg.n_experts)
        total = d * cfg.n_experts  # router (always resident)
        total += n_e * (3 if cfg.gated_mlp else 2) * d * f
        if cfg.n_shared_experts:
            total += mlp_params(f * cfg.n_shared_experts)
        return total

    def ssm_params():
        d_in = cfg.ssm_heads * cfg.ssm_head_dim
        conv_ch = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
        proj_out = 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
        return (d * proj_out + cfg.ssm_conv * conv_ch + conv_ch
                + 3 * cfg.ssm_heads + d_in + d_in * d + d)  # + ln scale

    total = v * d + norm  # embed + final_norm
    if not cfg.tie_embeddings:
        total += d * v
    if cfg.frontend:
        total += cfg.frontend_dim * d

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params() + 2 * d)
    elif cfg.family == "moe":
        total += cfg.n_layers * (attn_params() + moe_params() + 2 * d)
    elif cfg.family == "ssm":
        total += cfg.n_layers * ssm_params()
    elif cfg.family == "hybrid":
        total += cfg.n_layers * ssm_params()
        total += max(cfg.n_shared_blocks, 1) * (
            attn_params() + mlp_params() + 2 * d)
    elif cfg.family == "encdec":
        per_enc = attn_params() + mlp_params() + 2 * norm
        per_dec = 2 * attn_params() + mlp_params() + 3 * norm
        total += cfg.n_encoder_layers * per_enc + cfg.n_layers * per_dec
        total += norm + 4096 * d  # enc_norm + learned decoder positions
    return int(total)
