"""AdamW as pure pytree functions (no optax dependency).

Numerics policy is explicit and per-size (DESIGN §6): bf16 params keep an
fp32 *master* copy; moments are fp32 by default and can be bf16 for ≥100 B
archs where optimizer-state HBM dominates.  Optimizer state is sharded
exactly like the parameters (ZeRO): every leaf here is elementwise, so the
update inherits whatever sharding pjit assigns the params — no extra
collectives are introduced by the optimizer itself.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak LR; scheduled value passed per-step
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"    # "bfloat16" for ≥100B archs
    master_fp32: bool = True         # keep fp32 master when params are bf16


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(
    grads,
    state: Dict[str, Any],
    params,
    cfg: AdamWConfig,
    lr: jnp.ndarray | float,
) -> Tuple[Any, Dict[str, Any]]:
    """Returns (new_params, new_state).  ``lr`` is the scheduled value."""
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def leaf(g, mu, nu, p, master):
        gf = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + gf * (1 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = mu32 / c1
        nhat = nu32 / c2
        base = master if master is not None else p.astype(jnp.float32)
        upd = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * upd
        return new_master.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt), new_master

    masters = state.get("master")
    if masters is None:
        masters = jax.tree.map(lambda _: None, params,
                               is_leaf=lambda x: x is None)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_m = (treedef.flatten_up_to(state["master"])
              if "master" in state else [None] * len(flat_p))

    outs = [leaf(g, mu, nu, p, m)
            for g, mu, nu, p, m in zip(flat_g, flat_mu, flat_nu, flat_p, flat_m)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "mu": treedef.unflatten([o[1] for o in outs]),
        "nu": treedef.unflatten([o[2] for o in outs]),
    }
    if "master" in state:
        new_state["master"] = treedef.unflatten([o[3] for o in outs])
    return new_p, new_state


def optimizer_state_bytes(params, cfg: AdamWConfig) -> int:
    """Analytic HBM footprint of the optimizer state (dry-run memory table)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    per = 2 * mdt.itemsize + (4 if cfg.master_fp32 else 0)
    return sum(x.size * per for x in jax.tree.leaves(params)) + 4
