"""8-bit gradient compression with error feedback — the distributed-
optimization trick for the cross-pod DCN hop (DESIGN §6).

The pod axis crosses data-center network, ~25× slower than ICI; the only
traffic that crosses it is the data-parallel gradient all-reduce, once per
step.  Quantizing that traffic to int8 with per-block scales cuts cross-pod
bytes 4× (bf16→int8 with a small scale overhead); the *error-feedback*
accumulator re-injects each step's quantization residual into the next
step's gradient, which keeps SGD/Adam convergence unbiased in practice
(Karimireddy et al., 2019).

Block layout: flatten the leaf, pad to ``block``, per-block max-abs scale.
``compress → all-reduce in int8-sum-space`` is not associative across scales,
so the intended wire pattern (runtime/train loop) is
reduce-scatter(fp) **within** the pod → compress → cross-pod all-reduce of
the compressed shard → decompress → all-gather(fp) within the pod; this
module provides the (de)compress + EF pieces and the step-level wrapper.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_8bit(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (q: int8 (padded_n,), scale: f32 (n_blocks,))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def decompress_8bit(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int = 256):
    blocks = q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape)


def ef_init(params):
    """Error-feedback residual accumulator, shaped like the gradients."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_update(grads, ef_state, block: int = 256):
    """Apply error feedback: g' = Q(g + e);  e' = (g + e) − g'.

    Returns (quantized-then-dequantized grads, new ef_state).  The caller
    all-reduces the returned grads across the compressed axis (the cross-pod
    hop); within-pod reduction should happen *before* this call so the
    residual tracks exactly what the wire carried.
    """

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_8bit(corrected, block)
        deq = decompress_8bit(q, s, g.shape, block)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def compressed_bytes(n_elements: int, block: int = 256) -> int:
    """Wire bytes for a compressed tensor (int8 payload + fp32 scales)."""
    n_blocks = -(-n_elements // block)
    return n_blocks * block + 4 * n_blocks
