"""Optimizer substrate (built here, no optax): AdamW + schedules + clipping
+ gradient compression for the cross-pod hop."""

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import (
    compress_8bit,
    decompress_8bit,
    ef_compress_update,
    ef_init,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "warmup_cosine",
    "clip_by_global_norm", "global_norm",
    "compress_8bit", "decompress_8bit", "ef_compress_update", "ef_init",
]
