"""mamba2-2.7b — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  d_inner = 2·2560 = 5120, 80 heads × 64
head-dim, d_state 128, 1 B/C group, conv4.  Attention-sharding aspects of
the paper's technique are moot here, but the sequence-parallel state
hand-off between shards is the cleanest possible ``fshmem_put`` (one
O(d_state·d_inner) message per chunk boundary) — see DESIGN §5.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attn_type="none",
    ssm_state=128,
    ssm_heads=80,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)
