"""smollm-360m — small llama-architecture LM.

[hf:HuggingFaceTB/SmolLM-360M; hf]  Also the end-to-end training example
(examples/train_lm.py trains this family at ~100M reduced scale).
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49_152,
    head_dim=64,
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
)
