"""The paper's own case-study workload (Sec. V): parallel matmul and
convolution on 2 nodes with a 16×8-PE DLA per node.

Matrix sizes 256/512/1024; conv 64×64 fmaps with (256,3×3), (192,5×5),
(128,7×7) kernel sets — reproduced by benchmarks/casestudy.py.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class CaseStudyConfig:
    n_nodes: int = 2
    dla_pes: int = 16 * 8          # PEs per DLA
    dla_clock_hz: float = 250e6    # DLA @ 250 MHz
    matmul_sizes: tuple = (256, 512, 1024)
    conv_fmap: int = 64
    conv_sets: tuple = ((256, 3), (192, 5), (128, 7))
    art_chunks: int = 8


config = CaseStudyConfig()
