"""llama4-scout-17b-a16e — MoE 16 routed experts (top-1) + 1 shared, GQA kv=8.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  Early-fusion MoE: every
layer is MoE (period 1).  The assigned config specifies full attention, so
long_500k is skipped (DESIGN §5) — Llama-4's chunked-attention variants are
not part of the assigned cell.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    n_experts=16,
    experts_per_token=1,
    n_shared_experts=1,
    moe_layer_period=1,
    activation="silu",
    gated_mlp=True,
    rope_theta=500_000.0,
)
