"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]  The 4096-token window bounds the KV cache, so this
is the one *attention* arch that runs long_500k (with a ring-buffer cache).
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32_000,
    head_dim=80,
    window=4096,
    activation="silu",
    gated_mlp=True,
)
