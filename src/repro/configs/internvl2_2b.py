"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf]  Per task spec the ViT frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (frontend_tokens ×
frontend_dim) which a linear projector maps into the LM sequence.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    head_dim=128,
    activation="silu",
    gated_mlp=True,
    frontend="vit_stub",
    frontend_tokens=256,
    frontend_dim=1024,
)
