"""grok-1-314b — MoE 8 experts top-2, GQA kv=8, GeGLU experts.

[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    head_dim=128,
    n_experts=8,
    experts_per_token=2,
    moe_layer_period=1,
    activation="gelu",
    gated_mlp=True,
)
