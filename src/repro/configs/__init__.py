"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeCell,
    cell_applicable,
    shape_cell,
)

_MODULES: Dict[str, str] = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "grok-1-314b": "grok_1_314b",
    "internvl2-2b": "internvl2_2b",
    "nemotron-4-340b": "nemotron_4_340b",
    "minicpm3-4b": "minicpm3_4b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "smollm-360m": "smollm_360m",
    "mamba2-2.7b": "mamba2_2p7b",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.config


# imported after get_config exists: get_ep_preset resolves presets against
# the registry (lazily, at call time)
from repro.configs.presets import (  # noqa: E402
    EP_PRESET_NAMES,
    EP_PRESETS,
    EPPreset,
    TP_PRESET_NAMES,
    TP_PRESETS,
    TPPreset,
    get_ep_preset,
    get_tp_preset,
)


__all__ = [
    "ARCH_NAMES", "SHAPES", "ModelConfig", "ShapeCell",
    "cell_applicable", "get_config", "shape_cell",
    "EPPreset", "EP_PRESETS", "EP_PRESET_NAMES", "get_ep_preset",
    "TPPreset", "TP_PRESETS", "TP_PRESET_NAMES", "get_tp_preset",
]
