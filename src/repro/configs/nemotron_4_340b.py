"""nemotron-4-340b — dense GQA kv=8, squared-ReLU MLP (not gated).

[arXiv:2402.16819; unverified]  The biggest assigned arch: 340B params.
Fits the 256-chip pod only under full FSDP×TP sharding with sequence-
parallel activations and bf16 optimizer moments (see EXPERIMENTS §Dry-run).
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73_728,
    vocab_size=256_000,
    head_dim=192,
    activation="relu2",
    gated_mlp=False,
)
