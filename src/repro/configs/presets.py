"""Expert-parallel (EP) presets for the MoE architectures.

A ``ModelConfig`` stays pure — expert parallelism is a *run* property
(mesh + :class:`~repro.dist.steps.TransportPolicy`), so an "EP-enabled
preset" here is the pairing a launcher needs: the arch, a ``StepConfig``
whose ``TransportPolicy.moe`` routes expert dispatch through the conduit
``all_to_all`` (``models/moe_ep.py``), and the expert-axis extent the
mesh should carry.

Usage::

    from repro.configs import get_ep_preset
    preset = get_ep_preset("grok-1-314b-ep")
    mesh = jax.make_mesh((n_data, preset.expert_axis), ("data", "expert"))
    bundle = build_train_step(preset.config, mesh, preset.step, bshape)

The expert-axis extents divide each arch's ``n_experts`` (asserted when a
preset is resolved via :func:`get_ep_preset`, and for every preset by
``tests/test_moe_ep.py``); ``moe="auto"`` defers the xla/ring/bidir
choice to the netmodel per dispatch size (docs/transports.md lists the
thresholds).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class EPPreset:
    """One EP-enabled run recipe: arch + step knobs + mesh shape hint."""

    arch: str                 # registry name of the ModelConfig
    expert_axis: int          # recommended ``expert`` mesh-axis extent
    moe_transport: str = "auto"   # TransportPolicy.moe
    stream_chunks: int = 4    # ART chunks per EP exchange (1: bulk); the
    #                           streamed dispatch is bit-identical to bulk,
    #                           so presets default to the overlapped
    #                           schedule (benchmarks/overlap_pipeline.py
    #                           records the modeled speedup per preset)

    @property
    def config(self) -> ModelConfig:
        from repro.configs import get_config

        return get_config(self.arch)

    @property
    def step(self):
        """A ``StepConfig`` with the EP transport policy bound."""
        from repro.dist.steps import StepConfig, TransportPolicy

        return StepConfig(
            transport=TransportPolicy(moe=self.moe_transport,
                                      moe_stream_chunks=self.stream_chunks))


#: EP recipes for every MoE arch in the registry.  ``expert_axis`` is the
#: largest power-of-two extent dividing ``n_experts`` that still leaves
#: ≥2 experts per shard (bucket payloads stay einsum-shaped, and odd
#: extents are covered by tests rather than presets).
EP_PRESETS: Dict[str, EPPreset] = {
    "llama4-scout-17b-a16e-ep": EPPreset(
        arch="llama4-scout-17b-a16e", expert_axis=8),
    "grok-1-314b-ep": EPPreset(arch="grok-1-314b", expert_axis=4),
}

EP_PRESET_NAMES: Tuple[str, ...] = tuple(EP_PRESETS)


def get_ep_preset(name: str) -> EPPreset:
    """Resolve an EP preset by name (``<arch>-ep``), validated against the
    arch it points at (lazy — arch modules load only when a preset is
    actually requested; ``tests/test_moe_ep.py`` validates all of them)."""
    if name not in EP_PRESETS:
        raise KeyError(
            f"unknown EP preset {name!r}; known: {sorted(EP_PRESETS)}")
    p = EP_PRESETS[name]
    cfg = p.config
    assert cfg.family == "moe", (name, cfg.family)
    assert cfg.n_experts % p.expert_axis == 0, (
        name, cfg.n_experts, p.expert_axis)
    assert cfg.n_experts // p.expert_axis >= 2, (name, p.expert_axis)
    return p


# ---------------------------------------------------------------------------
# TP presets (ART rings / fused collective matmuls at the dense TP edges)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPPreset:
    """One TP-enabled run recipe for a dense arch: the ``model``-axis
    extent and the transport the dense-block TP edges ride.

    ``tp_transport="fused"`` pins the in-kernel Pallas collective matmuls
    (``kernels/cc_matmul``) at the QKV/up all_gather and O/down
    reduce_scatter edges; any ring family keeps the XLA-level streamed
    schedules of ``core/overlap.py``; ``auto`` prices the families per
    payload (``Conduit.matmul_schedule``)."""

    arch: str                 # registry name of the ModelConfig
    tp_axis: int              # recommended ``model`` mesh-axis extent
    tp_transport: str = "fused"   # TransportPolicy.tp

    @property
    def config(self) -> ModelConfig:
        from repro.configs import get_config

        return get_config(self.arch)

    @property
    def step(self):
        """A ``StepConfig`` with the TP transport policy bound."""
        from repro.dist.steps import StepConfig, TransportPolicy

        return StepConfig(
            transport=TransportPolicy(tp=self.tp_transport))


#: TP recipes for dense archs whose head/ff/model extents divide cleanly
#: at the recommended axis (validated by :func:`get_tp_preset` and for
#: every preset by ``tests/test_overlap.py``).
TP_PRESETS: Dict[str, TPPreset] = {
    "nemotron-4-340b-tp": TPPreset(arch="nemotron-4-340b", tp_axis=8),
    "h2o-danube-1.8b-tp": TPPreset(arch="h2o-danube-1.8b", tp_axis=8),
}

TP_PRESET_NAMES: Tuple[str, ...] = tuple(TP_PRESETS)


def get_tp_preset(name: str) -> TPPreset:
    """Resolve a TP preset by name (``<arch>-tp``), validated against the
    arch's divisibility constraints (``models/artblock.supports_art_tp``)."""
    if name not in TP_PRESETS:
        raise KeyError(
            f"unknown TP preset {name!r}; known: {sorted(TP_PRESETS)}")
    p = TP_PRESETS[name]
    cfg = p.config
    from repro.models.artblock import supports_art_tp

    assert supports_art_tp(cfg, p.tp_axis), (name, p.tp_axis)
    return p


__all__ = [
    "EPPreset", "EP_PRESETS", "EP_PRESET_NAMES", "get_ep_preset",
    "TPPreset", "TP_PRESETS", "TP_PRESET_NAMES", "get_tp_preset",
]
