"""zamba2-7b — hybrid: Mamba2 backbone + 2 alternating *shared* attention
blocks applied every 6 SSM layers.

[arXiv:2411.15242; unverified]  The shared blocks reuse one parameter set
across applications (depth-sharing), so the attention params are counted
once but executed ~13 times.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14_336,
    vocab_size=32_000,
    head_dim=112,
    ssm_state=64,
    ssm_heads=112,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_conv=4,
    ssm_expand=2,
    hybrid_period=6,
    n_shared_blocks=2,
    activation="gelu",
    gated_mlp=True,
    # §Perf: "dots" remat measured best for the hybrid (memory 327.8→54.8 s,
    # collective 41.9→19.1 s, temp 12.3 GB < 16 GB; chunk64 variant refuted)
    remat="dots",
)
