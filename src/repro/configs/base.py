"""ModelConfig — one dataclass describing every assigned architecture.

Families: dense / moe / ssm / hybrid / encdec / vlm.  A config fully
determines parameter shapes, the forward pass, cache layout, and the
sharding rules; ``reduced()`` produces the small same-family variant used by
the per-arch CPU smoke tests (the full configs are only ever lowered via the
dry-run with ShapeDtypeStructs — no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default: d_model // n_heads

    # -- attention variant ---------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    window: Optional[int] = None    # sliding-window attention (h2o-danube)
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # -- MLP -------------------------------------------------------------------
    activation: str = "silu"        # silu | gelu | relu2
    gated_mlp: bool = True

    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_layer_period: int = 1       # 1 => every layer is MoE
    capacity_factor: float = 1.25

    # -- SSM (mamba2 / zamba2) --------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_stream_segments: int = 0    # >1: chunk-fed SSD scan (segments streamed
                                    # into the kernel, state carried — the
                                    # fused consume-in-pipeline discipline)
    hybrid_period: int = 0          # zamba2: shared attn block every k ssm layers
    n_shared_blocks: int = 0        # zamba2: number of alternating shared blocks

    # -- encoder-decoder (whisper) ----------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0            # whisper: 1500 frames
    decoder_max_seq: int = 448

    # -- modality frontend (stub per task spec) ---------------------------------
    frontend: Optional[str] = None  # vit_stub | audio_stub
    frontend_tokens: int = 0        # precomputed patch/frame embeddings count
    frontend_dim: int = 0

    # -- common ------------------------------------------------------------------
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # -- numerics / implementation ------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"         # auto | pallas | jnp | ref
    attn_q_chunk: int = 2048
    attn_kv_chunk: int = 2048
    causal_block_skip: bool = True  # skip fully-masked kv blocks (perf lever)
    remat: str = "full"             # none | dots | full (activation ckpt policy)

    # -- the paper's technique ------------------------------------------------------
    use_art: bool = True            # ART-chunked/overlapped TP collectives
    art_chunks: int = 4             # chunk count for overlapped schedules

    # ---------------------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence scaling: SSM/hybrid state or SWA window."""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def n_params(self) -> int:
        """Total parameter count (exact, mirrors init_params)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Same-family miniature for CPU smoke tests."""
        r = {
            "n_layers": min(self.n_layers, 2),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            "d_ff": 128,
            "vocab_size": 256,
            "head_dim": 16,
            "param_dtype": "float32",
            "compute_dtype": "float32",
            "attn_impl": "jnp",
            "attn_q_chunk": 16,
            "attn_kv_chunk": 16,
            "remat": "none",
        }
        if self.attn_type == "mla":
            r.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
                     qk_nope_dim=8, v_head_dim=16, head_dim=16)
        if self.window is not None:
            r["window"] = 8
        if self.n_experts:
            r.update(n_experts=4, experts_per_token=min(self.experts_per_token, 2))
        if self.family in ("ssm", "hybrid"):
            r.update(ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_chunk=8,
                     ssm_groups=1)
            if self.family == "hybrid":
                r.update(n_layers=5, hybrid_period=2,
                         n_shared_blocks=min(self.n_shared_blocks, 2))
        if self.family == "encdec":
            r.update(n_encoder_layers=2, encoder_seq=16, decoder_max_seq=32)
        if self.frontend:
            # encdec frontends feed the encoder: the frame count must equal
            # encoder_seq so the prefill cross-cache extent matches
            # decode.init_cache's (which sizes it from encoder_seq)
            r.update(frontend_tokens=16 if self.family == "encdec" else 8,
                     frontend_dim=32)
        return dataclasses.replace(self, **r)


# ---------------------------------------------------------------------------
# serving capability table (jax-free — tools/docs_check.py imports this)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkCarrySpec:
    """The per-architecture chunk-carry contract of streamed prefill.

    What a prefill chunk hands to the next one (``models/prefill.py``
    implements the matching ``init_prefill_scratch`` / ``prefill_chunk`` /
    ``scratch_to_cache`` triple per ``kind``):

    * ``ring`` — full-length K/V scratch rows (GQA dense / vlm / moe);
    * ``latent`` — full-length MLA latent (``ckv``) + shared rope key rows;
    * ``state`` — **constant-size** SSD state + conv tail (mamba2), riding
      the ``ssd`` kernel's ``init_state`` resume hook;
    * ``hybrid`` — the ``state`` pair per layer plus ring rows for the
      shared attention blocks (zamba2);
    * ``encdec`` — encoder output turned into cross-K/V once (chunk 0),
      then decoder ring rows (whisper).

    ``exact`` is the bit-identity claim: chunked ≡ bulk prefill, bit for
    bit.  MoE is the one documented exception (``exact=False``): expert
    capacity is bookkept per chunk, so the drop set may differ from bulk's
    — each MoE layer's output agrees bitwise at every token whose
    per-(token, expert) keep decisions match, and the whole forward is
    exact when they match everywhere, in particular when no row overflows
    either program (``models/prefill.moe_chunk_agree_mask`` states the
    bound; the zoo suite asserts it).

    ``chunk_multiple``: interior chunk cuts must land on multiples of this
    (the SSD chunk walk of ``ssm_chunk``-sized blocks must line up with
    bulk's for the state hand-off to be bit-exact); the server rounds its
    ``prefill_chunk`` up to it.
    """

    kind: str              # ring | latent | state | hybrid | encdec
    constant_size: bool    # carry size independent of the prompt length
    exact: bool            # chunked ≡ bulk bit-identical
    chunk_multiple: int    # interior cuts land on multiples of this
    note: str = ""


def chunk_carry_spec(cfg: ModelConfig) -> ChunkCarrySpec:
    """The chunk-carry contract of ``cfg`` — total over the config zoo."""
    if cfg.family == "ssm":
        return ChunkCarrySpec(
            "state", constant_size=True, exact=True,
            chunk_multiple=max(1, cfg.ssm_chunk),
            note="constant SSD state + conv tail per layer")
    if cfg.family == "hybrid":
        return ChunkCarrySpec(
            "hybrid", constant_size=False, exact=True,
            chunk_multiple=max(1, cfg.ssm_chunk),
            note="SSD state pair + shared-attention ring rows")
    if cfg.family == "encdec":
        return ChunkCarrySpec(
            "encdec", constant_size=False, exact=True, chunk_multiple=1,
            note="cross-K/V once at chunk 0, decoder ring rows after")
    if cfg.attn_type == "mla":
        return ChunkCarrySpec(
            "latent", constant_size=False, exact=True, chunk_multiple=1,
            note="latent ckv + shared rope key rows")
    if cfg.family == "moe":
        return ChunkCarrySpec(
            "ring", constant_size=False, exact=False, chunk_multiple=1,
            note="chunk-local expert capacity — exact iff no row drops")
    return ChunkCarrySpec("ring", constant_size=False, exact=True,
                          chunk_multiple=1, note="K/V ring rows")


def serving_features(cfg: ModelConfig) -> "dict[str, bool]":
    """Arch × serving-feature support row (the docs/serving.md matrix).

    ``chunked``: the chunk-carry contract exists (it is total — every arch
    chunks; the *runtime* gate ``models/prefill.chunk_support`` may still
    fall back to bulk when the resolved attention impl lacks the
    mid-sequence ``q_offset`` convention, with a build warning).
    ``chunked_exact``: the bit-identity claim of :func:`chunk_carry_spec`.
    ``paged`` / ``prefix_cache``: the paged KV block pool and its
    prompt-prefix sharing (ring K/V caches only; sharing additionally
    needs position-stable slots — no SWA wrap — and byte-keyable prompts,
    which frontend embeddings are not).  ``ep_decode``: expert-parallel
    decode dispatch over the conduit.
    """
    spec = chunk_carry_spec(cfg)
    paged = (cfg.family in ("dense", "vlm", "moe")
             and cfg.attn_type != "mla")
    return {
        "chunked": True,
        "chunked_exact": spec.exact,
        "paged": paged,
        "prefix_cache": (paged and cfg.window is None
                         and not cfg.frontend),
        "ep_decode": cfg.family == "moe",
    }


# Input-shape cells assigned to every LM arch (task spec).
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shape_cell(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether (arch × shape) runs, with the DESIGN.md skip reason if not."""
    if cell.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention — long_500k skipped (DESIGN §5)"
    if cell.name == "long_500k" and cfg.family == "encdec":
        return False, "enc-dec decoder context 448 — long_500k skipped"
    return True, ""
