"""minicpm3-4b — dense with Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B; hf]  MLA compresses K/V into a 256-dim latent
(+32-dim shared rope key), shrinking the decode-time KV cache by ~an order
of magnitude versus GQA — visible in the decode_32k roofline memory term.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    head_dim=96,           # qk_nope + qk_rope
    activation="silu",
    gated_mlp=True,
)
