"""whisper-tiny — encoder-decoder audio backbone; conv frontend is a STUB.

[arXiv:2212.04356; unverified]  Per task spec ``input_specs()`` provides
precomputed frame embeddings (1500 × 384) for the encoder; the decoder is a
standard causal transformer with cross-attention.  long_500k skipped
(decoder context 448).  Uses LayerNorm and sinusoidal/learned positions
rather than RMSNorm+RoPE.
"""
from repro.configs.base import ModelConfig

config = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    head_dim=64,
    n_encoder_layers=4,
    encoder_seq=1500,
    decoder_max_seq=448,
    activation="gelu",
    gated_mlp=False,
    frontend="audio_stub",
    frontend_tokens=1500,
    frontend_dim=384,
    tie_embeddings=True,
)
