"""Deterministic fault injection for the elastic runtime.

A :class:`FaultPlan` scripts failures against *step counters*, never
wall-clocks or RNGs, so every run of a plan is bit-reproducible — the
property the recovery tests lean on (a failed-and-recovered run must
produce tokens/losses identical to an unfailed run, which is only
checkable if the failure itself is deterministic).

Three fault kinds (:class:`FaultEvent`):

``kill_rank``
    From ``step`` on, rank ``rank`` is dead: every conduit collective and
    AM delivery raises :class:`~repro.core.conduit.RankFailure` until the
    plan is told the membership was repaired (:meth:`FaultPlan.repair`).
    This is the paper's node-loss case — a PGAS member stops answering.

``drop_op``
    The next ``count`` calls matching ``op`` (or any op when ``None``)
    at/after ``step`` raise — then traffic flows again.  A *transient*
    fault: this is what :meth:`~repro.core.conduit.Conduit.with_retry`
    exists to absorb.

``delay_am``
    AM deliveries at/after ``step`` sleep ``delay_s`` on the host — a
    slow-NIC model for straggler-path tests.  Never changes results, only
    timing.

``miss_lease``
    Rank ``rank`` skips its next ``count`` heartbeat publishes at/after
    ``step`` — a transient lease wobble for the membership detector
    (``runtime/membership.py``): fewer consecutive misses than the
    detector's K threshold must *not* change the membership.

**Delivery modes.**  ``deliver="raise"`` (default) is the scripted legacy
path: kills raise at :meth:`FaultPlan.on_step` and at the conduit hook.
``deliver="lease"`` turns the plan into a detector *input*: kills only
suppress the victim's heartbeat leases (:meth:`FaultPlan.lease_suppressed`)
and the membership detector does the declaring — ``on_step`` never raises
and the conduit hook passes dead ranks through (only transients fire).

Delivery has two surfaces:

* **trace/call time** — :meth:`FaultPlan.install` registers the plan as
  the conduit failure hook (``core/conduit.py`` /``core/am.py``), so any
  collective issued while a fault is active raises.
* **host step time** — jitted steps are traced once and cached, so
  steady-state training/serving never re-enters the conduit.  The
  runtime loops (``runtime/trainer.py``, ``runtime/server.py``) call
  :meth:`FaultPlan.on_step` once per host step, which both advances the
  plan's clock and raises for freshly-killed ranks.

``FaultPlan.from_cli(fail_at_step, fail_rank)`` builds the one-kill plan
the CI smoke drives through ``launch/serve.py --fail-at-step/--fail-rank``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

from repro.core.conduit import (RankFailure, clear_failure_hook,
                                install_failure_hook)

KINDS = ("kill_rank", "drop_op", "delay_am", "miss_lease")

DELIVER_MODES = ("raise", "lease")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: ``kind`` armed from ``step`` on.

    Fields beyond ``kind``/``step`` are kind-specific: ``rank`` for
    ``kill_rank``, ``op``/``count`` for ``drop_op``, ``delay_s`` for
    ``delay_am``.  Frozen — a plan's script never mutates, only its
    delivery state does.
    """

    kind: str
    step: int = 0
    rank: Optional[int] = None
    op: Optional[str] = None
    count: int = 1
    delay_s: float = 0.0

    def __post_init__(self):
        """Validate the kind and its kind-specific fields."""
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS})")
        if self.kind == "kill_rank" and self.rank is None:
            raise ValueError("kill_rank needs a rank")
        if self.kind == "drop_op" and self.count < 1:
            raise ValueError("drop_op needs count >= 1")
        if self.kind == "miss_lease":
            if self.rank is None:
                raise ValueError("miss_lease needs a rank")
            if self.count < 1:
                raise ValueError("miss_lease needs count >= 1")


class FaultPlan:
    """A deterministic script of :class:`FaultEvent` s plus its delivery
    state (current step, remaining drop budgets, repaired ranks, a log).

    Build with the chainable helpers::

        plan = (FaultPlan()
                .kill_rank(2, at_step=5)
                .drop_op("all_reduce", at_step=0, count=2)
                .delay_am(1e-3, at_step=3))

    then either ``plan.install()`` it as the conduit hook (trace-time
    faults) or hand it to a runtime loop that calls :meth:`on_step`
    (host-time faults) — usually both, via the context manager::

        with plan:
            trainer.train(mesh)
    """

    def __init__(self, events: Sequence[FaultEvent] = (),
                 deliver: str = "raise"):
        """Start a plan with ``events`` (more can be chained on).

        ``deliver``: ``"raise"`` (scripted legacy — kills raise) or
        ``"lease"`` (kills only suppress heartbeats; the membership
        detector declares).
        """
        if deliver not in DELIVER_MODES:
            raise ValueError(f"unknown deliver mode {deliver!r} "
                             f"(one of {DELIVER_MODES})")
        self.deliver = deliver
        self.events: List[FaultEvent] = list(events)
        self.step = 0
        self._drops_left = {id(e): e.count for e in self.events
                            if e.kind == "drop_op"}
        self._misses_left = {id(e): e.count for e in self.events
                             if e.kind == "miss_lease"}
        self._repaired: set = set()     # ranks the runtime has recovered
        self._announced: set = set()    # kills already raised at host level
        self.log: List[Tuple[int, str, str]] = []

    # -- script builders ------------------------------------------------------

    def _add(self, ev: FaultEvent) -> "FaultPlan":
        self.events.append(ev)
        if ev.kind == "drop_op":
            self._drops_left[id(ev)] = ev.count
        if ev.kind == "miss_lease":
            self._misses_left[id(ev)] = ev.count
        return self

    def kill_rank(self, rank: int, *, at_step: int = 0) -> "FaultPlan":
        """Script a permanent rank death at ``at_step``."""
        return self._add(FaultEvent("kill_rank", step=at_step, rank=rank))

    def drop_op(self, op: Optional[str] = None, *, at_step: int = 0,
                count: int = 1) -> "FaultPlan":
        """Script ``count`` transient drops of ``op`` (any op if ``None``)."""
        return self._add(FaultEvent("drop_op", step=at_step, op=op,
                                    count=count))

    def delay_am(self, delay_s: float, *, at_step: int = 0) -> "FaultPlan":
        """Script a per-delivery host sleep on AM traffic from ``at_step``."""
        return self._add(FaultEvent("delay_am", step=at_step,
                                    delay_s=delay_s))

    def miss_lease(self, rank: int, *, at_step: int = 0,
                   count: int = 1) -> "FaultPlan":
        """Script ``count`` skipped heartbeat publishes for ``rank`` —
        a transient lease wobble below the detector's K threshold."""
        return self._add(FaultEvent("miss_lease", step=at_step, rank=rank,
                                    count=count))

    @classmethod
    def from_cli(cls, fail_at_step: Optional[int],
                 fail_rank: Optional[int]) -> Optional["FaultPlan"]:
        """The ``--fail-at-step N --fail-rank R`` plan (CI smoke), or
        ``None`` when no failure was requested."""
        if fail_at_step is None or fail_at_step < 0:
            return None
        return cls().kill_rank(fail_rank or 0, at_step=fail_at_step)

    # -- membership view ------------------------------------------------------

    def dead_ranks(self) -> frozenset:
        """Ranks whose ``kill_rank`` has fired and is not yet repaired."""
        return frozenset(e.rank for e in self.events
                         if e.kind == "kill_rank" and self.step >= e.step
                         and e.rank not in self._repaired)

    def repair(self, *ranks: int) -> None:
        """Tell the plan the runtime excluded ``ranks`` and re-formed —
        their kill events stop firing (the membership no longer includes
        them, so there is nothing left to kill)."""
        self._repaired.update(ranks)

    # -- detector inputs (lease mode) -----------------------------------------

    def tick(self, step: int) -> None:
        """Advance the plan clock to ``step`` without any raise path —
        the detector's way of keeping the script on the shared host-step
        clock while it does the declaring itself."""
        self.step = max(self.step, int(step))

    def lease_suppressed(self, rank: int, step: int) -> bool:
        """Whether ``rank``'s heartbeat publish at ``step`` is suppressed.

        True while a ``kill_rank`` for ``rank`` is active (a dead rank
        publishes nothing), and for the next ``count`` queries of an armed
        ``miss_lease`` (transient — each query at/after its step consumes
        one unit of budget, mirroring ``drop_op``).  The detector calls
        this exactly once per (rank, publish step), so budget consumption
        is deterministic.
        """
        step = int(step)
        for e in self.events:
            if (e.kind == "kill_rank" and e.rank == rank and step >= e.step
                    and rank not in self._repaired):
                return True
        for e in self.events:
            if (e.kind == "miss_lease" and e.rank == rank
                    and step >= e.step
                    and self._misses_left.get(id(e), 0) > 0):
                self._misses_left[id(e)] -= 1
                self.log.append((step, "miss_lease", f"rank {rank}"))
                return True
        return False

    def am_delay_at(self, step: int) -> float:
        """Total scripted AM delivery delay (seconds) active at ``step`` —
        the jitter the detector converts into heartbeat arrival lag."""
        return sum(e.delay_s for e in self.events
                   if e.kind == "delay_am" and int(step) >= e.step)

    # -- delivery -------------------------------------------------------------

    def on_step(self, step: int, op: str = "step") -> None:
        """Host-level delivery: advance the plan clock to ``step`` and
        raise for any freshly-fired ``kill_rank``.

        Runtime loops call this once per host step *before* running the
        jitted step — the cached-executable analogue of the trace-time
        hook (a compiled step never re-enters the conduit, so the loop
        has to ask).  Each kill announces at host level exactly once;
        conduit-level traffic keeps raising until :meth:`repair`.

        In ``deliver="lease"`` mode this only advances the clock: kills
        suppress leases and the membership detector declares.
        """
        self.step = max(self.step, int(step))
        if self.deliver == "lease":
            return
        for e in self.events:
            if (e.kind == "kill_rank" and self.step >= e.step
                    and e.rank not in self._repaired
                    and id(e) not in self._announced):
                self._announced.add(id(e))
                self.log.append((self.step, "kill_rank",
                                 f"rank {e.rank} op {op}"))
                raise RankFailure(e.rank, op,
                                  f"scripted kill at step {e.step}")

    def __call__(self, op: str, axis: str) -> None:
        """The conduit failure probe (``install_failure_hook`` target).

        Checks, in order: dead ranks (permanent, every call raises;
        skipped in ``deliver="lease"`` mode — an undetected death is
        invisible to the wire until the detector declares it), armed
        ``drop_op`` budgets (transient, raises ``count`` times then
        passes), ``delay_am`` sleeps (AM deliveries only).
        """
        dead = self.dead_ranks() if self.deliver == "raise" else frozenset()
        if dead:
            rank = min(dead)
            self.log.append((self.step, "kill_rank", f"{op}@{axis}"))
            raise RankFailure(rank, op, f"peer dead on axis {axis!r}")
        for e in self.events:
            if (e.kind == "drop_op" and self.step >= e.step
                    and e.op in (None, op)
                    and self._drops_left.get(id(e), 0) > 0):
                self._drops_left[id(e)] -= 1
                self.log.append((self.step, "drop_op", f"{op}@{axis}"))
                raise RankFailure(None, op, "scripted transient drop")
        if op == "am_deliver":
            for e in self.events:
                if e.kind == "delay_am" and self.step >= e.step:
                    self.log.append((self.step, "delay_am", f"{e.delay_s}s"))
                    time.sleep(e.delay_s)

    # -- hook lifecycle -------------------------------------------------------

    def install(self) -> "FaultPlan":
        """Register this plan as the conduit/AM failure hook."""
        install_failure_hook(self)
        return self

    def uninstall(self) -> None:
        """Deregister the conduit/AM failure hook."""
        clear_failure_hook()

    def __enter__(self) -> "FaultPlan":
        """Context manager: install on entry."""
        return self.install()

    def __exit__(self, *exc) -> None:
        """Context manager: uninstall on exit (exceptions propagate)."""
        self.uninstall()


__all__ = ["FaultEvent", "FaultPlan", "RankFailure", "KINDS",
           "DELIVER_MODES"]
