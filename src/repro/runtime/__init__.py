"""Fault-tolerant runtime: training loop, elastic re-meshing, serving."""

from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.elastic import ElasticMesh, remesh
from repro.runtime.server import BlockPool, Server, ServerConfig

__all__ = ["Trainer", "TrainerConfig", "ElasticMesh", "remesh",
           "BlockPool", "Server", "ServerConfig"]
