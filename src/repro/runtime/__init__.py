"""Fault-tolerant runtime: training loop, elastic membership, fault
injection, live failure detection, serving."""

from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.elastic import (ElasticMesh, ElasticRuntime,
                                   RecoveryReport, reform_conduits, remesh,
                                   scaled_microbatches, viable_mesh_shapes)
from repro.runtime.faults import FaultEvent, FaultPlan, RankFailure
from repro.runtime.membership import (LeaseConfig, MembershipEvent,
                                      MembershipService, MembershipView,
                                      StaleEpoch)
from repro.runtime.server import BlockPool, Server, ServerConfig

__all__ = ["Trainer", "TrainerConfig", "ElasticMesh", "ElasticRuntime",
           "RecoveryReport", "reform_conduits", "remesh",
           "scaled_microbatches", "viable_mesh_shapes",
           "FaultEvent", "FaultPlan", "RankFailure",
           "LeaseConfig", "MembershipEvent", "MembershipService",
           "MembershipView", "StaleEpoch",
           "BlockPool", "Server", "ServerConfig"]
