"""Elastic re-meshing: rebuild the device mesh after failures and reshard.

On a real multi-host deployment a device/host failure surfaces as an XLA
error (or a missed heartbeat in the coordination service); recovery is:

  1. drop the failed hosts from the device set,
  2. rebuild the largest mesh of the same *shape family* that fits,
  3. restore the last checkpoint **resharded** onto the new mesh
     (``checkpoint.load_checkpoint`` takes the new NamedShardings —
     checkpoints store logical arrays, the mesh maps them physically),
  4. resume from the checkpointed step; the data pipeline is stateless
     (step-indexed PRNG) so no data is lost or repeated.

The mesh-shape policy keeps the "model" (TP) extent fixed — param shards
must keep dividing — and shrinks the data axes, which only changes the
gradient all-reduce span and per-shard batch (grad accumulation grows to
hold the global batch constant).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh


def viable_mesh_shapes(n_devices: int, model: int) -> List[Tuple[int, int]]:
    """(data, model) shapes with fixed TP extent, largest data first."""
    shapes = []
    d = n_devices // model
    while d >= 1:
        shapes.append((d, model))
        d -= 1
    return shapes


def remesh(devices: Sequence, model: int,
           axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh over the surviving devices."""
    usable = (len(devices) // model) * model
    if usable == 0:
        raise RuntimeError(
            f"cannot keep TP={model} with {len(devices)} devices")
    data = usable // model
    import numpy as np
    arr = np.array(devices[:usable]).reshape(data, model)
    return Mesh(arr, axis_names)


@dataclasses.dataclass
class ElasticMesh:
    """Tracks the live device set; ``fail(i)`` simulates a device loss and
    returns the rebuilt mesh (tests drive this; production wires it to the
    runtime error path)."""

    model: int
    axis_names: Tuple[str, ...] = ("data", "model")
    devices: Optional[List] = None

    def __post_init__(self):
        if self.devices is None:
            self.devices = list(jax.devices())

    def mesh(self) -> Mesh:
        return remesh(self.devices, self.model, self.axis_names)

    def fail(self, *indices: int) -> Mesh:
        dead = {self.devices[i].id for i in indices}
        self.devices = [d for d in self.devices if d.id not in dead]
        return self.mesh()
