"""Elastic membership: rebuild mesh, conduits, buckets and state on rank loss.

On a real multi-host deployment a device/host failure surfaces as an XLA
error (or a missed heartbeat in the coordination service); recovery is:

  1. drop the failed hosts from the device set,
  2. rebuild the largest mesh of the same *shape family* that fits,
  3. **re-form every conduit** over the surviving axes — axis sizes
     changed, so the netmodel-driven transport choices
     (``conduit.auto_select``) and the collective-matmul schedule family
     (``conduit.matmul_edge_estimate``) must be re-negotiated, exactly as
     "A PGAS Communication Library for Heterogeneous Clusters" re-picks
     algorithms when the topology changes,
  4. **re-fit the gradient buckets** (``dist/bucketing.bucket_plan``) —
     the sync span (data extent) changed, so per-bucket wire accounting
     (``dist/grad_sync.bucket_wire_bytes``) changes with it,
  5. restore the last checkpoint **resharded** onto the new mesh
     (``checkpoint.load_checkpoint`` takes the new NamedShardings —
     checkpoints store logical arrays, the mesh maps them physically),
  6. resume from the checkpointed step with **grad accumulation scaled**
     to hold the global batch constant; the data pipeline is stateless
     (step-indexed PRNG) so no data is lost or repeated and the loss
     trajectory continues exactly where the unfailed run would be.

The mesh-shape policy keeps the "model" (TP) extent fixed — param shards
must keep dividing — and shrinks the data axes, which only changes the
gradient all-reduce span and per-shard batch.

:class:`ElasticRuntime` is the orchestrator that runs 1–6 as one
membership-change operation (:meth:`ElasticRuntime.on_failure`), driven
by the typed :class:`~repro.core.conduit.RankFailure` the conduit/AM
failure surface raises (``runtime/faults.py`` scripts it in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.core.conduit import (LINKS, Conduit, RankFailure, auto_select,
                                matmul_edge_estimate)
from repro.dist.bucketing import (DEFAULT_BUCKET_BYTES, BucketPlan,
                                  bucket_plan, span_scaled_target)


def viable_mesh_shapes(n_devices: int, model: int) -> List[Tuple[int, int]]:
    """(data, model) shapes with fixed TP extent, largest data first.

    Only shapes whose data extent divides cleanly into the surviving
    device pool are viable — a non-divisor data extent would strand
    devices *and* break the even per-rank batch split the data pipeline
    assumes.  ``model > n_devices`` raises the same typed error as
    :func:`remesh` (TP shards must keep dividing; there is no viable
    shape at all).
    """
    if model < 1 or n_devices // model == 0:
        raise RuntimeError(
            f"cannot keep TP={model} with {n_devices} devices")
    d_max = n_devices // model
    return [(d, model) for d in range(d_max, 0, -1) if d_max % d == 0]


def remesh(devices: Sequence, model: int,
           axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh over the surviving devices."""
    usable = (len(devices) // model) * model
    if usable == 0:
        raise RuntimeError(
            f"cannot keep TP={model} with {len(devices)} devices")
    data = usable // model
    import numpy as np
    arr = np.array(devices[:usable]).reshape(data, model)
    return Mesh(arr, axis_names)


@dataclasses.dataclass
class ElasticMesh:
    """Tracks the live device set; ``fail(i)`` simulates a device loss and
    returns the rebuilt mesh (tests drive this; production wires it to the
    runtime error path)."""

    model: int
    axis_names: Tuple[str, ...] = ("data", "model")
    devices: Optional[List] = None

    def __post_init__(self):
        if self.devices is None:
            self.devices = list(jax.devices())

    def mesh(self) -> Mesh:
        """The current largest viable mesh over the live devices."""
        return remesh(self.devices, self.model, self.axis_names)

    def fail(self, *indices: int) -> Mesh:
        """Drop the devices at ``indices`` and return the rebuilt mesh."""
        dead = {self.devices[i].id for i in indices}
        self.devices = [d for d in self.devices if d.id not in dead]
        return self.mesh()

    def join(self, *devices) -> Mesh:
        """Admit ``devices`` into the pool (ignoring ones already live)
        and return the rebuilt — grown — mesh (the scale-out path)."""
        have = {d.id for d in self.devices}
        for d in devices:
            if d.id not in have:
                self.devices.append(d)
                have.add(d.id)
        return self.mesh()

    def spares(self) -> List:
        """Host devices not currently in the pool — join candidates (a
        previously-failed device coming back, or fresh capacity)."""
        have = {d.id for d in self.devices}
        return [d for d in jax.devices() if d.id not in have]


# ---------------------------------------------------------------------------
# Conduit re-formation: transport choices are per-topology, not per-process
# ---------------------------------------------------------------------------

#: collective ops re-priced per axis on re-formation (barrier always xla)
_REFORM_OPS = ("all_gather", "reduce_scatter", "all_reduce", "all_to_all")


@dataclasses.dataclass(frozen=True)
class ConduitPlan:
    """One axis's re-formed conduit: the handle plus the transport the
    cost model picked for each collective at the *new* axis size, and the
    collective-matmul schedule family for its TP edges."""

    axis: str
    size: int
    conduit: Conduit
    op_transports: Dict[str, Tuple[str, Optional[int]]]
    matmul_family: str


def reform_conduits(mesh: Mesh, *, link: str = "qsfp",
                    payload_bytes: int = 4 << 20,
                    compute_time: float = 1e-4) -> Dict[str, ConduitPlan]:
    """Re-negotiate every axis's conduit against the shrunk topology.

    A transport choice is a function of (op, payload, **axis size**, link)
    — so a membership change invalidates it.  For each mesh axis this
    re-runs :func:`~repro.core.conduit.auto_select` per collective op at
    the surviving axis size and re-prices the collective-matmul schedule
    family (ring/bidir/fused) via
    :func:`~repro.core.conduit.matmul_edge_estimate`, returning fresh
    ``auto`` :class:`~repro.core.conduit.Conduit` handles (axis size
    resolves per call inside ``shard_map``) *plus* the resolved decisions
    for logging/benchmarks.  Size-1 axes need no conduit and are skipped.
    """
    lp = LINKS[link]
    plans: Dict[str, ConduitPlan] = {}
    for axis, size in mesh.shape.items():
        n = int(size)
        if n <= 1:
            continue
        ops = {op: auto_select(op, size_bytes=payload_bytes, axis_size=n,
                               link=lp) for op in _REFORM_OPS}
        best, best_t = "ring", float("inf")
        for fam in ("ring", "bidir", "fused"):
            t = matmul_edge_estimate(
                "all_gather", fam, size_bytes=payload_bytes, axis_size=n,
                compute_time=compute_time, link=lp)
            if t < best_t:
                best, best_t = fam, t
        plans[axis] = ConduitPlan(
            axis=axis, size=n, conduit=Conduit(axis, "auto", link=link),
            op_transports=ops, matmul_family=best)
    return plans


def scaled_microbatches(microbatches: int, old_data: int,
                        new_data: int) -> int:
    """Grad-accumulation steps after the data axis changed, holding the
    global batch (and per-microbatch per-rank rows) constant.

    The global batch is a *training* invariant (it sets the loss
    trajectory); the data pipeline keeps serving it, so a shrink grows
    per-rank rows by ``old_data / new_data`` and accumulation absorbs the
    growth; a scale-out *join* divides accumulation by
    ``new_data / old_data`` instead (more ranks, fewer passes — the
    speedup a join buys).  Either direction requires the clean divisor
    relationship :func:`viable_mesh_shapes` guarantees; growth further
    requires ``microbatches`` divisible by the factor (otherwise the
    global batch cannot be re-split exactly and the caller must keep the
    old accumulation).
    """
    if old_data % new_data == 0:                 # shrink (or no change)
        return int(microbatches) * (old_data // new_data)
    if new_data % old_data == 0:                 # growth (scale-out join)
        factor = new_data // old_data
        if int(microbatches) % factor != 0:
            raise RuntimeError(
                f"microbatches {microbatches} not divisible by growth "
                f"factor {factor} ({old_data} -> {new_data} ranks): the "
                f"global batch cannot be re-split exactly")
        return int(microbatches) // factor
    raise RuntimeError(
        f"data extent {old_data} -> {new_data} is not a clean shrink or "
        f"growth (viable_mesh_shapes only yields divisors)")


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """What one membership change did — the audit record
    :meth:`ElasticRuntime.on_failure` returns (and benchmarks price)."""

    dead_rank: Optional[int]
    old_shape: Tuple[Tuple[str, int], ...]
    new_shape: Tuple[Tuple[str, int], ...]
    conduits: Dict[str, ConduitPlan]
    bucket_plan: Optional[BucketPlan]
    microbatches: int
    restored_step: Optional[int]
    #: every rank excluded by this change (multi-rank batches share one
    #: report — one remesh, one re-form); ``(dead_rank,)`` for singles
    dead_ranks: Tuple[int, ...] = ()
    #: device index admitted by a scale-out join (None for failures)
    joined_rank: Optional[int] = None


class ElasticRuntime:
    """The membership-change orchestrator (module steps 1–6 as one call).

    Owns the live device set (an :class:`ElasticMesh`), the link class the
    re-formed conduits are priced against, and an optional
    :class:`~repro.runtime.faults.FaultPlan` to notify of repairs (so the
    scripted kill stops firing once its rank is excluded — matching a real
    coordination service marking the member left).
    """

    def __init__(self, model: int, axis_names=("data", "model"),
                 devices: Optional[List] = None, link: str = "qsfp",
                 fault_plan=None):
        """Bind the TP extent, axis names, device pool and link class."""
        self.members = ElasticMesh(model=model, axis_names=tuple(axis_names),
                                   devices=devices)
        self.link = link
        self.fault_plan = fault_plan
        self.reports: List[RecoveryReport] = []

    def mesh(self) -> Mesh:
        """The current mesh over the live membership."""
        return self.members.mesh()

    def on_failure(self, failure: Optional[RankFailure] = None, *,
                   rank: Optional[int] = None,
                   ranks: Optional[Sequence[int]] = None,
                   params_tree=None,
                   grad_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                   microbatches: int = 1,
                   ckpt_dir: Optional[str] = None,
                   template=None, shardings=None) -> RecoveryReport:
        """Run the full recovery for one *batch* of dead ranks; returns
        the report.

        ``failure`` (or an explicit ``rank``/``ranks``) names the dead
        members — a :class:`RankFailure` carrying several ``.ranks``
        (the membership detector batches every rank that missed the same
        deadline) is excluded in **one** membership change: one remesh,
        one conduit re-formation, one restore — never N sequential
        recoveries.  ``None`` means unattributed, and the policy excludes
        device 0 of the current list (a heartbeat sweep would identify
        it; the *shape* outcome is identical for any single loss).
        Steps: exclude → remesh → re-form conduits → re-fit buckets (when
        a ``params_tree`` is given) → scale accumulation → optionally
        restore resharded state (when ``ckpt_dir``/``template``/
        ``shardings`` are given; the restored ``(state, manifest)`` is
        stashed on ``self.restored``).
        """
        if ranks is None:
            if rank is not None:
                ranks = [rank]
            elif failure is not None and len(failure.ranks) > 0:
                ranks = list(failure.ranks)
            else:
                ranks = [0]
        limit = len(self.members.devices) - 1
        dead = sorted({min(int(r), limit) for r in ranks})
        old_shape = tuple(self.mesh().shape.items())
        old_data = dict(old_shape).get("data", 1)
        mesh = self.members.fail(*dead)
        if self.fault_plan is not None:
            self.fault_plan.repair(*dead)
        report = self._refit(mesh, old_shape, old_data,
                             params_tree=params_tree,
                             grad_bucket_bytes=grad_bucket_bytes,
                             microbatches=microbatches, ckpt_dir=ckpt_dir,
                             template=template, shardings=shardings,
                             dead_rank=dead[0], dead_ranks=tuple(dead))
        return report

    def on_failures(self, ranks: Sequence[int], **kw) -> RecoveryReport:
        """Batch convenience: :meth:`on_failure` with explicit ``ranks``
        (all excluded atomically — one epoch of recovery work)."""
        return self.on_failure(ranks=ranks, **kw)

    def on_join(self, device=None, *, params_tree=None,
                grad_bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                microbatches: int = 1,
                ckpt_dir: Optional[str] = None,
                template=None, shardings=None) -> RecoveryReport:
        """Scale-out: admit a joining device and re-expand the data axis.

        The joiner is ``device`` (or the first spare host device when
        ``None``); the mesh re-expands via the same
        :func:`viable_mesh_shapes` family recovery shrinks through,
        conduits re-form at the grown axis size, buckets re-fit to the
        wider sync span, grad accumulation *divides* by the growth factor
        (global batch held constant), and — when checkpoint args are
        given — the checkpoint is resharded back **out** over the grown
        mesh, handing the joiner its shard.  Raises ``RuntimeError`` when
        no spare device exists.
        """
        if device is None:
            pool = self.members.spares()
            if not pool:
                raise RuntimeError("no spare device to join")
            device = pool[0]
        old_shape = tuple(self.mesh().shape.items())
        old_data = dict(old_shape).get("data", 1)
        mesh = self.members.join(device)
        joined = next(i for i, d in enumerate(self.members.devices)
                      if d.id == device.id)
        return self._refit(mesh, old_shape, old_data,
                           params_tree=params_tree,
                           grad_bucket_bytes=grad_bucket_bytes,
                           microbatches=microbatches, ckpt_dir=ckpt_dir,
                           template=template, shardings=shardings,
                           dead_rank=None, dead_ranks=(),
                           joined_rank=joined)

    def _refit(self, mesh: Mesh, old_shape, old_data: int, *, params_tree,
               grad_bucket_bytes: int, microbatches: int, ckpt_dir,
               template, shardings, dead_rank, dead_ranks,
               joined_rank: Optional[int] = None) -> RecoveryReport:
        """Steps 3–6 shared by failure and join: re-form, re-fit, restore."""
        new_data = mesh.shape.get("data", 1)
        plans = reform_conduits(mesh, link=self.link)
        # keep the per-hop ring message constant across the span change
        target = span_scaled_target(grad_bucket_bytes, old_data, new_data)
        bplan = (bucket_plan(params_tree, target_bytes=target)
                 if params_tree is not None else None)
        micro = scaled_microbatches(microbatches, old_data, new_data)
        restored_step = None
        self.restored = None
        if ckpt_dir is not None and template is not None:
            from repro.checkpoint import load_checkpoint
            state, manifest = load_checkpoint(ckpt_dir, template,
                                              shardings=shardings)
            self.restored = (state, manifest)
            restored_step = manifest["step"]
        report = RecoveryReport(
            dead_rank=dead_rank, old_shape=old_shape,
            new_shape=tuple(mesh.shape.items()), conduits=plans,
            bucket_plan=bplan, microbatches=micro,
            restored_step=restored_step, dead_ranks=dead_ranks,
            joined_rank=joined_rank)
        self.reports.append(report)
        return report


__all__ = ["viable_mesh_shapes", "remesh", "ElasticMesh", "ElasticRuntime",
           "ConduitPlan", "RecoveryReport", "reform_conduits",
           "scaled_microbatches"]
