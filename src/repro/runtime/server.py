"""Serving runtime: continuous batching with chunked streamed prefill.

The serving analogue of the paper's case study: prefill is the one-sided
bulk transfer of the prompt into the cache (the ``gasnet_put``), decode is
the ART pattern of many small transfers.  PR 5 rebuilds both on the
pipeline scheduler:

* **Admission** is per slot: a request's prompt is prefilled into a
  full-length K/V scratch by incremental *chunk steps*
  (``dist/steps.build_prefill_chunk_step`` over
  ``models/prefill.prefill_chunk``), at most one chunk per server step, so
  prefill work interleaves with decode steps instead of blocking them —
  chunked prefill admission kills the head-of-line blocking a long prompt
  used to impose on every decoding request.  The finished scratch is
  ring-filled into a single-request cache and written into its batch row
  with one donated ``dynamic_update_slice`` per leaf
  (``build_slot_write_step`` — the per-slot PUT).  Archs outside
  ``supports_chunked_prefill`` (and ``prefill_chunk=None``) admit with one
  bulk per-slot prefill instead — same numerics, whole-prompt latency.
* **Decode** runs the donated ``build_serve_step`` with ``sample=True``:
  per-slot positions let every cache row advance independently, argmax
  runs on device, and the server fetches one stacked ``(B,)`` id vector
  per step instead of per-slot logits syncs.

TTFT accounting: ``Request.first_token`` is stamped when the request's
first *decode token id* has actually been sampled and fetched — never at
prefill completion — and stays correct under chunked admission because the
stamp rides the token append, not the scheduler phase.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.steps import (
    StepConfig,
    build_prefill_chunk_step,
    build_prefill_step,
    build_serve_step,
    build_slot_write_step,
)
from repro.models.decode import init_cache
from repro.models.prefill import (
    init_prefill_scratch,
    prefill_chunk_cuts,
    scratch_to_cache,
    supports_chunked_prefill,
)


@dataclasses.dataclass
class ServerConfig:
    """Continuous-batching knobs (see docs/serving.md)."""

    max_batch: int = 8
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: disabled (synthetic workloads)
    greedy: bool = True
    #: tokens per admitted prefill chunk (the streamed-prefill ART chunk);
    #: None/0 admits with one bulk per-slot prefill instead
    prefill_chunk: Optional[int] = 16


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    frontend_embeds: Optional[np.ndarray] = None   # frontend (vlm) archs
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None
    # scheduler state (not part of the public result surface)
    phase: str = "queued"          # queued | prefill | decode
    _scratch: Optional[dict] = None
    _cursor: int = 0               # next prompt position to prefill


class Server:
    """Fixed-slot continuous-batching server over the serve step bundles."""

    def __init__(self, cfg: ModelConfig, params, mesh, scfg=None,
                 srv: ServerConfig = ServerConfig()):
        self.cfg, self.params, self.srv = cfg, params, srv
        self.mesh = mesh
        self.scfg = scfg or StepConfig()
        assert srv.greedy, "only greedy sampling is implemented"
        self.bundle = build_serve_step(cfg, mesh, self.scfg,
                                       batch=srv.max_batch,
                                       max_seq=srv.max_seq, sample=True)
        self.writer = build_slot_write_step(cfg, mesh, srv.max_batch,
                                            srv.max_seq)
        from repro.dist.sharding import to_shardings
        self._cache_sh = to_shardings(mesh, self.bundle.in_specs[1])
        self._slot_sh = to_shardings(mesh, self.writer.in_specs[1])
        self.cache = jax.jit(
            lambda: init_cache(cfg, srv.max_batch, srv.max_seq),
            out_shardings=self._cache_sh)()
        self._chunkable = (supports_chunked_prefill(cfg)
                           and not cfg.frontend
                           and bool(srv.prefill_chunk))
        self._chunk_bundles: Dict[tuple, object] = {}   # (S, lo, C) -> bundle
        self._bulk_bundles: Dict[int, object] = {}      # S -> fn
        self._scratch_inits: Dict[int, object] = {}     # S -> jitted init
        self._finish_fns: Dict[int, object] = {}        # S -> jitted convert
        self.slots: List[Optional[Request]] = [None] * srv.max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_tok = np.zeros((srv.max_batch,), np.int32)

    @property
    def chunked_admission(self) -> bool:
        """Whether admission actually runs as streamed prefill chunks
        (archs outside ``supports_chunked_prefill`` — and frontend archs —
        admit with one bulk per-slot prefill regardless of
        ``ServerConfig.prefill_chunk``)."""
        return self._chunkable

    # -- request intake -------------------------------------------------------

    def submit(self, prompt: np.ndarray,
               frontend_embeds: Optional[np.ndarray] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        eff = prompt.size + (self.cfg.frontend_tokens
                             if self.cfg.frontend else 0)
        assert prompt.ndim == 1 and 0 < eff <= self.srv.max_seq, (
            prompt.shape, self.srv.max_seq)
        if self.cfg.frontend:
            assert self.cfg.family != "encdec", \
                "encdec serving is not implemented"
            assert frontend_embeds is not None, (
                f"{self.cfg.name} requires frontend embeddings per request")
            frontend_embeds = np.asarray(frontend_embeds, np.float32)
            assert frontend_embeds.shape == (self.cfg.frontend_tokens,
                                             self.cfg.frontend_dim), \
                frontend_embeds.shape
        rid = len(self.queue) + len(self.done) + sum(s is not None
                                                     for s in self.slots)
        req = Request(rid=rid, prompt=prompt,
                      frontend_embeds=frontend_embeds,
                      submitted=time.perf_counter())
        self.queue.append(req)
        return rid

    def _admit(self):
        """Assign queued requests to free slots (state only — their prompts
        are prefilled chunk-by-chunk between the following decode steps)."""
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                req.phase = "prefill"
                req._cursor = 0
                if self._chunkable:
                    req._scratch = self._scratch_init(int(req.prompt.size))()
                self.slots[i] = req

    # -- prefill scheduling ---------------------------------------------------

    def _chunk_bundle(self, s: int, lo: int, c: int):
        key = (s, lo, c)
        if key not in self._chunk_bundles:
            self._chunk_bundles[key] = build_prefill_chunk_step(
                self.cfg, self.mesh, self.scfg, batch=1, prompt_len=s,
                lo=lo, chunk_len=c)
        return self._chunk_bundles[key]

    def _scratch_init(self, s: int):
        """Jitted scratch allocator, sharded like the chunk step's input
        (committed arrays must match the bundle's in-sharding exactly)."""
        if s not in self._scratch_inits:
            from repro.dist.sharding import to_shardings
            bundle = self._chunk_bundle(s, 0, min(
                self.srv.prefill_chunk or s, s))
            cfg = self.cfg
            self._scratch_inits[s] = jax.jit(
                lambda: init_prefill_scratch(cfg, 1, s),
                out_shardings=to_shardings(self.mesh, bundle.in_specs[1]))
        return self._scratch_inits[s]

    def _bulk_fn(self, s: int):
        if s not in self._bulk_bundles:
            wf = ((self.cfg.frontend_tokens, self.cfg.frontend_dim)
                  if self.cfg.frontend else None)
            self._bulk_bundles[s] = build_prefill_step(
                self.cfg, self.mesh, self.scfg, batch=1, seq_len=s,
                with_frontend=wf, cache_len=self.srv.max_seq).fn
        return self._bulk_bundles[s]

    def _finish_fn(self, s: int):
        """Jitted scratch→ring-cache conversion, sharded like the slot
        writer's slot-cache input."""
        if s not in self._finish_fns:
            cfg, max_seq = self.cfg, self.srv.max_seq
            self._finish_fns[s] = jax.jit(
                lambda scr: scratch_to_cache(cfg, scr, cache_len=max_seq),
                out_shardings=self._slot_sh)
        return self._finish_fns[s]

    def _emit_first_token(self, i: int, req: Request, logits):
        """Sample the request's first decode token from the final prefill
        logits and move the slot to the decode phase.  ``first_token`` is
        stamped *here* — after the id has been computed and fetched, i.e.
        at the first decode token, not at prefill completion."""
        tok = int(jnp.argmax(logits[0], axis=-1))
        req.first_token = time.perf_counter()
        req.out_tokens.append(tok)
        req.phase = "decode"
        self._next_tok[i] = tok
        if (len(req.out_tokens) >= self.srv.max_new_tokens
                or tok == self.srv.eos_id):
            self._retire(i, req)

    def _prefill_tick(self):
        """Run at most one prefill chunk (or one bulk per-slot prefill) for
        the earliest-admitted slot still in the prefill phase — the
        admission work a server step interleaves between decode steps."""
        pending = [(req.rid, i, req) for i, req in enumerate(self.slots)
                   if req is not None and req.phase == "prefill"]
        if not pending:
            return
        _, i, req = min(pending)
        s = int(req.prompt.size)
        toks = jnp.asarray(req.prompt[None, :])

        if not self._chunkable:
            args = (self.params, toks)
            if self.cfg.frontend:
                args += (jnp.asarray(req.frontend_embeds[None, :]),)
            cache1, logits = self._bulk_fn(s)(*args)
            self.cache = self.writer.fn(self.cache, cache1, jnp.int32(i))
            self._emit_first_token(i, req, logits)
            return

        cuts = prefill_chunk_cuts(s, chunk_len=self.srv.prefill_chunk)
        lo, hi = cuts[req._cursor]
        fn = self._chunk_bundle(s, lo, hi - lo).fn
        req._scratch, logits = fn(self.params, req._scratch,
                                  toks[:, lo:hi])
        req._cursor += 1
        if req._cursor < len(cuts):
            return                          # more chunks; decode proceeds
        cache1 = self._finish_fn(s)(req._scratch)
        req._scratch = None
        self.cache = self.writer.fn(self.cache, cache1, jnp.int32(i))
        self._emit_first_token(i, req, logits)

    def _retire(self, i: int, req: Request,
                now: Optional[float] = None):
        req.finished = time.perf_counter() if now is None else now
        req.phase = "done"
        self.done.append(req)
        self.slots[i] = None

    # -- decode loop ----------------------------------------------------------

    def step(self):
        """One scheduler tick: admit, run one prefill chunk, decode."""
        self._admit()
        self._prefill_tick()
        if not any(r is not None and r.phase == "decode"
                   for r in self.slots):
            return
        toks = jnp.asarray(self._next_tok)
        self.cache, ids = self.bundle.fn(self.params, self.cache, toks)
        choice = np.asarray(ids)            # ONE stacked host transfer
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None or req.phase != "decode":
                continue
            tok = int(choice[i])
            req.out_tokens.append(tok)
            self._next_tok[i] = tok
            if (len(req.out_tokens) >= self.srv.max_new_tokens
                    or tok == self.srv.eos_id):
                self._retire(i, req, now)

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- metrics ---------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        lat = [r.finished - r.submitted for r in self.done if r.finished]
        ttft = [r.first_token - r.submitted for r in self.done
                if r.first_token]
        itl = [(r.finished - r.first_token) / (len(r.out_tokens) - 1)
               for r in self.done
               if r.finished and r.first_token and len(r.out_tokens) > 1]
        toks = sum(len(r.out_tokens) for r in self.done)
        wall = (max(r.finished for r in self.done)
                - min(r.submitted for r in self.done)) if self.done else 0.0
        return {
            "requests": len(self.done),
            "tokens": toks,
            "throughput_tok_s": toks / wall if wall else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
            "mean_itl_s": float(np.mean(itl)) if itl else 0.0,
        }


def drive_arrivals(server: Server, prompts, every: int,
                   max_steps: int = 10_000) -> int:
    """Run ``server`` under synthetic arrivals: one prompt up front, one
    more every ``every`` scheduler ticks, until the queue drains.  The one
    arrival loop both the CLI (``launch/serve.py --arrive-every``) and the
    measured benchmark section (``benchmarks/serve_bench.py``) drive, so
    they always measure the same workload.  Returns the tick count.
    """
    pending = list(prompts)
    server.submit(pending.pop(0))
    steps = 0
    while ((pending or server.queue
            or any(s is not None for s in server.slots))
           and steps < max_steps):
        server.step()
        steps += 1
        if pending and steps % max(1, every) == 0:
            server.submit(pending.pop(0))
    return steps
