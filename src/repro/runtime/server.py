"""Serving runtime: continuous-batching decode loop over a prefilled cache.

The serving analogue of the paper's case study: requests arrive, are
prefilled (one-sided bulk transfer of the prompt into the cache — the
gasnet_put), then decode steps stream tokens with the batched ``serve_step``
(the ART pattern: many small result transfers instead of one big one).

Batching model: a fixed-size slot table (``max_batch``).  Requests occupy a
slot until EOS/len-limit; new requests fill free slots between decode steps
(continuous batching).  Each slot has its own ring cache region because the
cache is batched on axis 1 of every leaf — slot admission just writes that
row (a per-slot prefill into a batch-row is itself a PUT).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.steps import StepConfig, build_serve_step
from repro.models.decode import init_cache


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 8
    max_seq: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1               # -1: disabled (synthetic workloads)
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) int32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted: float = 0.0
    first_token: Optional[float] = None
    finished: Optional[float] = None


class Server:
    def __init__(self, cfg: ModelConfig, params, mesh, scfg=None,
                 srv: ServerConfig = ServerConfig()):
        self.cfg, self.params, self.srv = cfg, params, srv
        scfg = scfg or StepConfig()
        self.bundle = build_serve_step(cfg, mesh, scfg,
                                       batch=srv.max_batch,
                                       max_seq=srv.max_seq)
        from repro.dist.sharding import to_shardings
        csh = to_shardings(mesh, self.bundle.in_specs[1])
        self.cache = jax.jit(
            lambda: init_cache(cfg, srv.max_batch, srv.max_seq),
            out_shardings=csh)()
        self.slots: List[Optional[Request]] = [None] * srv.max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_tok = np.zeros((srv.max_batch,), np.int32)

    # -- request intake --------------------------------------------------------

    def submit(self, prompt: np.ndarray) -> int:
        rid = len(self.queue) + len(self.done) + sum(s is not None
                                                     for s in self.slots)
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      submitted=time.perf_counter())
        self.queue.append(req)
        return rid

    def _admit(self):
        """Fill free slots (continuous batching).  The shared ``pos`` counter
        makes this a synchronous-batch simplification: slots admitted
        together decode together; production would keep per-slot positions
        (noted in DESIGN §6)."""
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # teacher-forced prompt: feed prompt tokens step by step
                req._prompt_cursor = 0
                self._next_tok[i] = req.prompt[0]

    # -- decode loop ------------------------------------------------------------

    def step(self):
        self._admit()
        toks = jnp.asarray(self._next_tok)
        self.cache, logits = self.bundle.fn(self.params, self.cache, toks)
        choice = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = getattr(req, "_prompt_cursor", 0)
            if cur + 1 < len(req.prompt):       # still consuming the prompt
                req._prompt_cursor = cur + 1
                self._next_tok[i] = req.prompt[cur + 1]
                continue
            tok = int(choice[i])
            if req.first_token is None:
                req.first_token = now
            req.out_tokens.append(tok)
            self._next_tok[i] = tok
            if (len(req.out_tokens) >= self.srv.max_new_tokens
                    or tok == self.srv.eos_id):
                req.finished = now
                self.done.append(req)
                self.slots[i] = None

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- metrics -----------------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        lat = [r.finished - r.submitted for r in self.done if r.finished]
        ttft = [r.first_token - r.submitted for r in self.done if r.first_token]
        toks = sum(len(r.out_tokens) for r in self.done)
        wall = (max(r.finished for r in self.done)
                - min(r.submitted for r in self.done)) if self.done else 0.0
        return {
            "requests": len(self.done),
            "tokens": toks,
            "throughput_tok_s": toks / wall if wall else 0.0,
            "mean_latency_s": float(np.mean(lat)) if lat else 0.0,
            "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        }
